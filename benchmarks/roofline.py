"""§Roofline: three-term roofline per (arch x shape) from dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s/link)

The dry-run emits PER-DEVICE cost terms (the SPMD module is per-device), so
global = per_device x chips and the chip count cancels; we keep the global
convention of the assignment.  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D
(MoE) for train (2·N·D inference), giving the useful-compute ratio.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.common import ART, emit
from repro.configs.registry import ARCHS, all_pairs, get_config
from repro.core.metrics import TPU_V5E, roofline


def model_flops(arch: str, shape_kind: str, seq: int, batch: int) -> float:
    cfg = get_config(arch)
    n = cfg.active_param_count()
    if shape_kind == "train":
        tokens = batch * seq
        return 6.0 * n * tokens
    if shape_kind == "prefill":
        tokens = batch * seq
        return 2.0 * n * tokens
    return 2.0 * n * batch          # decode: one token per sequence


def load_artifact(arch: str, shape: str, pods: int = 1) -> dict | None:
    fn = os.path.join(ART, "dryrun", f"{arch}__{shape}__pod{pods}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        return json.load(f)


SHAPE_META = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
              "decode_32k": (32768, 128), "long_500k": (524288, 1)}


def run() -> list[dict]:
    rows = []
    for arch, shape in all_pairs():
        art = load_artifact(arch, shape)
        if art is None:
            continue
        if art["status"] == "skip":
            rows.append({"arch": arch, "shape": shape, "status": "SKIP(design)"})
            continue
        if art["status"] != "ok" or "cost" not in art:
            rows.append({"arch": arch, "shape": shape,
                         "status": art.get("status", "?")})
            continue
        chips = art["chips"]
        cost = art["cost"]
        # per-device -> global
        flops = cost["flops"] * chips
        hbytes = cost["bytes_accessed"] * chips
        cbytes = sum(cost["collective_bytes"].values()) * chips
        terms = roofline(flops, hbytes, cbytes, chips, TPU_V5E)
        seq, batch = SHAPE_META[shape]
        mflops = model_flops(arch, art["kind"], seq, batch)
        mem = art["memory"]
        hbm_gb = (mem["argument_bytes"] + mem["temp_bytes"]
                  + mem["output_bytes"]) / 1e9
        rows.append({
            "arch": arch, "shape": shape, "status": "ok",
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "bound_s": terms.bound_s,
            "model_flops": mflops,
            "useful_ratio": mflops / flops if flops else 0.0,
            "hbm_gb_per_dev": hbm_gb,
            "fits_16gb": hbm_gb <= 16.0,
        })
    return rows


def main() -> None:
    rows = run()
    emit("roofline", rows)
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        from collections import Counter
        doms = Counter(r["dominant"] for r in ok)
        print(f"# dominant-term counts: {dict(doms)}")
        worst = sorted(ok, key=lambda r: r["useful_ratio"])[:3]
        print("# worst useful-compute ratios:",
              [(r["arch"], r["shape"], round(r["useful_ratio"], 3))
               for r in worst])


if __name__ == "__main__":
    main()
