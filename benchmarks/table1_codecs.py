"""Table I: energy / overhead / network payload for ResNet50 with 4 compute
nodes, per payload type (architecture / weights / data) x codec config."""
from __future__ import annotations

from benchmarks.common import emit, graph_and_params
from repro.core.emulator import CodecConfig, emulate, emulate_config_step


def run() -> list[dict]:
    g, _ = graph_and_params("resnet50")
    rows = []
    configs = [("json", "lz4"), ("json", "none"), ("zfp", "lz4"),
               ("zfp", "none")]
    for ser, comp in configs:
        cfg = CodecConfig(serializer=ser, compression=comp, zfp_rate=16)
        reports = emulate_config_step(g, 4, cfg)
        for kind in ("architecture", "weights", "data"):
            # architecture is always JSON-serialized (it's a layer spec);
            # the paper's Table I varies only its compression
            if kind == "architecture" and ser == "zfp":
                continue
            r = reports[kind]
            rows.append({
                "type": kind, "serialization": ser.upper(),
                "compression": "LZ4" if comp == "lz4" else "Uncompressed",
                "energy_j": r.energy_j, "overhead_s": r.overhead_s,
                "payload_mb": r.payload_mb,
            })
    return rows


def main() -> None:
    emit("table1_codecs", run())


if __name__ == "__main__":
    main()
