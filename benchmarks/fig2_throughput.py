"""Fig 2: inference throughput for {VGG16, VGG19, ResNet50} x {1, 4, 6, 8}
compute nodes under the emulated DEFER chain (paper's CORE setting)."""
from __future__ import annotations

from benchmarks.common import emit, graph_and_params
from repro.core.emulator import CodecConfig, emulate


def run(models=("vgg16", "vgg19", "resnet50"), nodes=(4, 6, 8)) -> list[dict]:
    rows = []
    cfg = CodecConfig(serializer="zfp", compression="none", zfp_rate=16)
    for model in models:
        g, _ = graph_and_params(model)
        single = None
        for n in nodes:
            rep = emulate(g, n, cfg)
            single = rep.single_device_cps
            rows.append({
                "model": model, "nodes": n,
                "throughput_cps": rep.throughput_cps,
                "single_device_cps": rep.single_device_cps,
                "speedup": rep.speedup,
            })
        rows.append({"model": model, "nodes": 1, "throughput_cps": single,
                     "single_device_cps": single, "speedup": 1.0})
    return rows


def main() -> None:
    emit("fig2_throughput", run())


if __name__ == "__main__":
    main()
