"""Codec hot-path microbenchmark: vectorized vs pure-Python-baseline encode
throughput, so codec perf regressions are visible in BENCH output.

Measures MB/s for the LZ4 block compressor (NumPy bulk-skip match finder
vs the PR 1 byte-at-a-time reference) and the ZFP transform coder (batched
(4,4,B)-layout lift vs the per-axis copying reference) on three payload
classes the wire actually carries: incompressible random bytes, a real ZFP
activation stream (what ZFP/LZ4 compresses in the chain), and tiled
repetitive data.  Also measures the wire codec's small-payload bypass on
a one-token decode-step frame (ISSUE 9): raw magic-prefixed .npy vs the
full serializer/LZ4 path, where the setup cost dominates at a few hundred
bytes.  Exits nonzero if the vectorized path loses to the baseline beyond
tolerance.

    PYTHONPATH=src python benchmarks/codec_microbench.py --min-speedup 1.0
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit
from repro.core import codecs


def _mbs(fn, payload_bytes: int, reps: int) -> float:
    fn()                                    # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return payload_bytes * reps / 1e6 / (time.perf_counter() - t0)


def run(reps: int = 3) -> list[dict]:
    rng = np.random.default_rng(0)
    acts = rng.normal(size=(256, 512)).astype(np.float32)
    payloads = {
        "random": bytes(rng.integers(0, 256, 1 << 19).astype(np.uint8)),
        "zfp_stream": codecs.ZfpCodec(rate=16).encode(acts),
        "tiled": bytes(rng.integers(0, 256, 1024).astype(np.uint8)) * 256,
    }
    ref_lz4 = codecs.Lz4Codec(vectorized=False)
    vec_lz4 = codecs.Lz4Codec()
    rows = []
    for name, data in payloads.items():
        assert vec_lz4.compress(data) == ref_lz4.compress(data)
        ref = _mbs(lambda: ref_lz4.compress(data), len(data), 1)
        vec = _mbs(lambda: vec_lz4.compress(data), len(data), reps)
        rows.append({"codec": "lz4_compress", "payload": name,
                     "mb": len(data) / 1e6, "ref_mb_s": ref, "vec_mb_s": vec,
                     "speedup": vec / ref})
    blob = vec_lz4.compress(payloads["tiled"])
    ref = _mbs(lambda: ref_lz4.decompress(blob), len(payloads["tiled"]), 1)
    vec = _mbs(lambda: vec_lz4.decompress(blob), len(payloads["tiled"]), reps)
    rows.append({"codec": "lz4_decompress", "payload": "tiled",
                 "mb": len(payloads["tiled"]) / 1e6, "ref_mb_s": ref,
                 "vec_mb_s": vec, "speedup": vec / ref})

    # the decode-step fast path: a one-token activation frame is a few
    # hundred bytes, where ZFP/LZ4 setup cost dwarfs any transfer saving —
    # the size-threshold bypass ships it as magic-prefixed raw .npy.
    # ref = the full codec path on the same frame, vec = the bypass.
    from repro.runtime.wire import WireCodec
    step = rng.normal(size=(1, 1, 128)).astype(np.float32)
    for ser, comp in (("zfp", "lz4"), ("q8", "none")):
        full = WireCodec(ser, comp, zfp_rate=16)
        fast = WireCodec(ser, comp, zfp_rate=16, small_bypass=4096)
        np.testing.assert_array_equal(
            fast.decode_array(fast.encode_array(step)), step)
        ref = _mbs(lambda: full.encode_array(step), step.nbytes, reps * 100)
        vec = _mbs(lambda: fast.encode_array(step), step.nbytes, reps * 100)
        rows.append({"codec": f"small_bypass[{ser}_{comp}]",
                     "payload": "token_step_512B",
                     "mb": step.nbytes / 1e6, "ref_mb_s": ref,
                     "vec_mb_s": vec, "speedup": vec / ref})

    ref_zfp = codecs.ZfpCodec(rate=16, vectorized=False)
    vec_zfp = codecs.ZfpCodec(rate=16)
    zblob = vec_zfp.encode(acts)
    assert zblob == ref_zfp.encode(acts)
    for op, ref_fn, vec_fn in (
            ("zfp_encode", lambda: ref_zfp.encode(acts),
             lambda: vec_zfp.encode(acts)),
            ("zfp_decode", lambda: ref_zfp.decode(zblob),
             lambda: vec_zfp.decode(zblob))):
        ref = _mbs(ref_fn, acts.nbytes, reps)
        vec = _mbs(vec_fn, acts.nbytes, reps)
        rows.append({"codec": op, "payload": "activations",
                     "mb": acts.nbytes / 1e6, "ref_mb_s": ref,
                     "vec_mb_s": vec, "speedup": vec / ref})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if the geomean vectorized/baseline "
                         "speedup falls below this")
    args = ap.parse_args()
    rows = run(args.reps)
    emit("codec_microbench", rows)
    geomean = float(np.exp(np.mean([np.log(r["speedup"]) for r in rows])))
    print(f"geomean vectorized/baseline speedup: {geomean:.2f}x")
    if args.min_speedup and geomean < args.min_speedup:
        raise SystemExit(f"codec speedup {geomean:.2f}x < "
                         f"required {args.min_speedup}x")


if __name__ == "__main__":
    main()
