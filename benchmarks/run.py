"""Benchmark runner: one harness per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only name,name]

Emits CSV blocks to stdout and artifacts/bench/*.csv.  The roofline table
reads artifacts/dryrun/*.json (produced by ``repro.launch.dryrun --all``);
missing artifacts are reported, not fatal.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (fig2_throughput, fig3_energy, pipeline_wire,
                            roofline, table1_codecs, table2_codec_throughput)
    benches = {
        "fig2_throughput": fig2_throughput.main,
        "table1_codecs": table1_codecs.main,
        "table2_codec_throughput": table2_codec_throughput.main,
        "fig3_energy": fig3_energy.main,
        "pipeline_wire": pipeline_wire.main,
        "roofline": roofline.main,
    }
    names = args.only.split(",") if args.only else list(benches)
    failed = []
    for name in names:
        t0 = time.perf_counter()
        try:
            benches[name]()
            print(f"# {name} done in {time.perf_counter()-t0:.1f}s\n")
        except Exception as e:  # deferlint: swallow(recorded in failed[]; run exits nonzero below)
            failed.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}\n")
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
