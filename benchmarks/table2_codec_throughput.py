"""Table II: inference throughput of ResNet50/4-nodes per data-socket codec
configuration (the steady-state pipeline rate including codec overhead)."""
from __future__ import annotations

from benchmarks.common import emit, graph_and_params
from repro.core.emulator import CodecConfig, emulate


def run() -> list[dict]:
    g, _ = graph_and_params("resnet50")
    rows = []
    for ser, comp in [("json", "lz4"), ("json", "none"), ("zfp", "lz4"),
                      ("zfp", "none")]:
        cfg = CodecConfig(serializer=ser, compression=comp, zfp_rate=16)
        rep = emulate(g, 4, cfg)
        rows.append({
            "serialization": ser.upper(),
            "compression": "LZ4" if comp == "lz4" else "Uncompressed",
            "throughput_cps": rep.throughput_cps,
            "payload_mb": rep.total_payload_mb,
            "overhead_s": rep.overhead_s,
        })
    return rows


def main() -> None:
    emit("table2_codec_throughput", run())


if __name__ == "__main__":
    main()
