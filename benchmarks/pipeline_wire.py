"""TPU-path wire benchmark: relay bytes + quantization error of the DEFER
pipeline's compressed relay (the ZFP adaptation), per assigned arch.

This is the TPU analogue of Table I's "Data" rows: raw bf16 relay vs int8
block-quant relay, bytes per microbatch hop and end-to-end logit error on
the smoke configs.  Also measures the same kernel through the serving
runtime's ``WireCodec("q8")`` wire path (the q8 serializer the staged
relay threads ship between nodes): payload ratio and worst-case error vs
the codec's stated bound on a full-width activation slab."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import ARCHS
from repro.kernels import ops as kops
from repro.launch.mesh import make_mesh_compat
from repro.launch.serve import build_pipeline_lm
from repro.models import transformer as T
from repro.runtime.wire import WireCodec


def run(archs=("phi3-mini-3.8b", "gemma3-4b", "dbrx-132b", "mamba2-2.7b"),
        stages: int = 2) -> list[dict]:
    rows = []
    for arch in archs:
        from repro.configs.registry import get_smoke, get_config
        cfg = get_smoke(arch)
        full = get_config(arch)
        params = T.init_lm(cfg, jax.random.PRNGKey(0))
        mesh = make_mesh_compat((1,), ("stage",))
        B, S, M = 4, 32, 2
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab)
        kw = {}
        if cfg.num_prefix_embeds and not cfg.encoder_layers:
            kw["prefix_embeds"] = jnp.zeros((B, cfg.num_prefix_embeds,
                                             cfg.d_model))
        if cfg.encoder_layers:
            kw["encoder_embeds"] = jnp.zeros((B, cfg.num_prefix_embeds,
                                              cfg.d_model))
        # sanity: the (single-stage) pipeline must reproduce forward exactly
        ref, _ = T.forward(params, cfg, tokens, **kw)
        lm = build_pipeline_lm(cfg, params, mesh, 1, M, compress=False)
        with mesh:
            out = jax.jit(lambda t: lm(t, **kw))(tokens)
        assert float(jnp.abs(out - ref).max()) < 1e-4
        # full-size wire bytes for one relay hop (mb=8, seq=4096); the
        # multi-stage compressed-relay error is asserted in
        # tests/test_pipeline.py (needs >=2 devices)
        raw, wire = kops.quant_bytes((8 * 4096, full.d_model), jnp.bfloat16)
        # the serving runtime's q8 wire path over the same kernel: one
        # activation slab (256 rows x d_model) through WireCodec("q8")
        q8 = WireCodec("q8", "none")
        slab = np.random.default_rng(0).normal(
            size=(256, full.d_model)).astype(np.float32)
        q8_blob = q8.encode_array(slab)
        q8_err = float(np.abs(q8.decode_array(q8_blob) - slab).max())
        q8_bound = q8.error_bound(float(np.abs(slab).max()))
        rows.append({
            "arch": arch, "relay_raw_mb": raw / 1e6,
            "relay_quant_mb": wire / 1e6, "ratio": wire / raw,
            "q8_wire_ratio": len(q8_blob) / slab.nbytes,
            "q8_max_err": q8_err, "q8_err_bound": q8_bound,
            "q8_within_bound": q8_err <= q8_bound,
        })
    return rows


def main() -> None:
    emit("pipeline_wire", run())


if __name__ == "__main__":
    main()
