"""Closed-loop multi-client load test: staged codec/compute-overlap runtime
vs the PR 1 baseline and the synchronous engine, on the same DEFER chain.

N concurrent clients each send M samples closed-loop (a client admits its
next request only after receiving the previous result).

* ``sync``     — the seed's serving model: blocking submit with ONE request
  in the chain at a time (global lock, max_batch=1), PR 1 codecs.
* ``async``    — the PR 1 async runtime, faithfully: continuous batching,
  but each node runs decode -> apply -> encode sequentially on one worker
  thread, re-encodes every request separately (``staged=False``), and uses
  the PR 1 codec implementations (``WireCodec(vectorized=False)``: the
  copy-per-axis ZFP lift and the byte-at-a-time Python LZ4).
* ``staged``   — this PR's runtime: 3-stage per-node pipeline (ingress /
  compute / egress threads) overlapping codec with compute, batch-level
  wire encoding (one codec pass per bucket with row-extent framing in the
  envelope), and the vectorized codec hot paths.

Acceptance bars: async >= 1.5x sync (ISSUE 1, raw codec), and staged >=
1.5x async with a zfp or q8 data codec at >= 4 nodes x 8 clients (ISSUE 2).

    PYTHONPATH=src python benchmarks/serve_load.py --nodes 4 --clients 8 \
        --codec zfp --min-staged-speedup 1.5
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import threading
import time

# Each DEFER node models a SEPARATE edge device: give XLA one intra-op
# thread so per-node compute is serial and the chain's parallelism comes
# from the runtime (pipelining + batching), not from one GEMM grabbing
# every host core.  Must happen before jax initializes.
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                               "intra_op_parallelism_threads=1")

import jax

# execute jitted computations on the calling (per-node) thread instead of
# funneling every node's apply through the CPU client's single dispatch
# stream — the chain's node parallelism is real, as on separate devices
jax.config.update("jax_cpu_enable_async_dispatch", False)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.graph import LayerGraph
from repro.runtime import InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

D = 256
SEQ = 64
DEPTH = 16

CODECS = {
    "raw": WireCodec("raw", "none"),
    "zfp": WireCodec("zfp", "none", zfp_rate=16),
    "zfp_lz4": WireCodec("zfp", "lz4", zfp_rate=16),
    "q8": WireCodec("q8", "none"),
}


def serving_mlp(depth: int = DEPTH, d: int = D, seq: int = SEQ) -> LayerGraph:
    """A chain deep enough that a 4+ node partition has real per-stage
    compute (each hop is a [seq, d] x [d, d] GEMM, not a matvec), small
    enough that CPU jit stays in seconds."""
    g = LayerGraph("serve-mlp", jax.ShapeDtypeStruct((1, seq, d), np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, seq, d), np.float32),
                flops=2.0 * seq * d * d)
        prev = f"fc{i}"
    return g


def sample(i: int) -> np.ndarray:
    rng = np.random.default_rng(i)
    return rng.normal(size=(1, SEQ, D)).astype(np.float32)


def build_engine(g: LayerGraph, params, nodes: int, max_batch: int,
                 clients: int, codec: WireCodec,
                 staged: bool) -> InferenceEngine:
    eng = InferenceEngine(
        g, nodes,
        DispatcherCodecs(data=codec, weights=WireCodec("raw", "none")),
        max_batch=max_batch, admission_depth=max(16, 4 * clients),
        staged=staged)
    eng.configure(params)
    eng.precompile()
    eng.start()
    return eng


def warmup(eng: InferenceEngine, clients: int,
           serialize: bool = False) -> None:
    """Run the same closed-loop pattern untimed so every batch-size jit
    specialization the load will hit is compiled before the clock starts."""
    for burst in (1, 2, clients):
        futs = [eng.submit(sample(10_000 + i), client_id=i)
                for i in range(burst)]
        for f in futs:
            f.result()
    run_load(eng, clients, 4, serialize=serialize)
    eng.dispatcher.drain()


def run_load(eng: InferenceEngine, clients: int, samples: int,
             serialize: bool = False) -> float:
    """Closed-loop: each client thread awaits result i before sending i+1.
    ``serialize`` emulates the synchronous engine (one in flight, ever)."""
    lock = threading.Lock() if serialize else None
    barrier = threading.Barrier(clients + 1)

    def client(c: int) -> None:
        barrier.wait()
        for i in range(samples):
            x = sample(1000 * c + i)
            if lock is not None:
                with lock:
                    eng.submit(x, client_id=c).result()
            else:
                eng.submit(x, client_id=c).result()

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


MODES = (
    # (mode, max_batch multiplier?, serialize clients, staged)
    ("sync", 1, True, False),
    ("async", 8, False, False),
    ("staged", 8, False, True),
)


def run(nodes: int = 4, clients: int = 8, samples: int = 16,
        codec: str = "zfp", repeats: int = 2) -> list[dict]:
    g = serving_mlp()
    params = g.init(jax.random.PRNGKey(0))
    wire = CODECS[codec]
    # the PR 1 modes run the PR 1 codec implementations; `staged` runs the
    # vectorized hot paths (both sides of the A/B are the code they claim)
    wire_pr1 = dataclasses.replace(wire, vectorized=False)
    rows = []
    for mode, max_batch, serialize, staged in MODES:
        eng = build_engine(g, params, nodes, max_batch, clients,
                           wire if staged else wire_pr1, staged)
        warmup(eng, clients, serialize=serialize)
        # repeat the measured window and keep the fastest: scheduler jitter
        # on an oversubscribed box only ever *adds* time, so min-wall is
        # the lowest-noise estimator of each mode's real service rate
        best = None
        for _ in range(max(1, repeats)):
            eng.reset_window()
            wall = run_load(eng, clients, samples, serialize=serialize)
            rep = eng.report(samples=clients * samples, wall_s=wall)
            if best is None or wall < best[0]:
                best = (wall, rep)
        wall, rep = best
        eng.shutdown()
        rows.append({
            "mode": mode, "codec": rep.codec, "nodes": nodes,
            "clients": clients, "samples": clients * samples,
            "wall_s": wall,
            "throughput_rps": rep.throughput_cps,
            "p50_ms": rep.p50_latency_s * 1e3,
            "p99_ms": rep.p99_latency_s * 1e3,
            "util_compute": float(np.mean([pn["util_compute"]
                                           for pn in rep.per_node])),
            "util_decode": float(np.mean([pn["util_decode"]
                                          for pn in rep.per_node])),
            "util_encode": float(np.mean([pn["util_encode"]
                                          for pn in rep.per_node])),
            "batch_mean": float(np.mean([pn["batch_mean"]
                                         for pn in rep.per_node])),
            "encodes_per_batch": float(np.mean([pn["encodes_per_batch"]
                                                for pn in rep.per_node])),
        })
    by_mode = {r["mode"]: r for r in rows}
    for r in rows:
        r["speedup_vs_sync"] = (r["throughput_rps"]
                                / by_mode["sync"]["throughput_rps"])
        r["speedup_vs_async"] = (r["throughput_rps"]
                                 / by_mode["async"]["throughput_rps"])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples", type=int, default=16)
    ap.add_argument("--codec", choices=sorted(CODECS), default="zfp")
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured windows per mode; fastest is reported")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if async/sync < this (ISSUE 1 bar)")
    ap.add_argument("--min-staged-speedup", type=float, default=0.0,
                    help="exit nonzero if staged/async < this (ISSUE 2 bar)")
    args = ap.parse_args()
    rows = run(args.nodes, args.clients, args.samples, args.codec,
               args.repeats)
    emit("serve_load", rows)
    by_mode = {r["mode"]: r for r in rows}
    s_async = by_mode["async"]["speedup_vs_sync"]
    s_staged = by_mode["staged"]["speedup_vs_async"]
    print(f"async/sync speedup:   {s_async:.2f}x "
          f"({by_mode['async']['throughput_rps']:.1f} vs "
          f"{by_mode['sync']['throughput_rps']:.1f} req/s)")
    print(f"staged/async speedup: {s_staged:.2f}x "
          f"({by_mode['staged']['throughput_rps']:.1f} vs "
          f"{by_mode['async']['throughput_rps']:.1f} req/s, "
          f"codec {by_mode['staged']['codec']})")
    if args.min_speedup and s_async < args.min_speedup:
        raise SystemExit(
            f"async speedup {s_async:.2f}x < required {args.min_speedup}x")
    if args.min_staged_speedup and s_staged < args.min_staged_speedup:
        raise SystemExit(f"staged speedup {s_staged:.2f}x < "
                         f"required {args.min_staged_speedup}x")


if __name__ == "__main__":
    main()
