"""Closed-loop multi-client load test: staged codec/compute-overlap runtime
vs the PR 1 baseline and the synchronous engine, on the same DEFER chain —
plus the PR 3 skewed-chain scenario where the serving-time controller
recalibrates costs online and hot-repartitions a mis-planned chain.

N concurrent clients each send M samples closed-loop (a client admits its
next request only after receiving the previous result).

Classic A/B (``run``):

* ``sync``     — the seed's serving model: blocking submit with ONE request
  in the chain at a time (global lock, max_batch=1), PR 1 codecs.
* ``async``    — the PR 1 async runtime, faithfully: continuous batching,
  but each node runs decode -> apply -> encode sequentially on one worker
  thread, re-encodes every request separately (``staged=False``), and uses
  the PR 1 codec implementations (``WireCodec(vectorized=False)``).
* ``staged``   — the PR 2 runtime: 3-stage per-node pipeline overlapping
  codec with compute, batch-level wire encoding, vectorized codecs.

Rebalance scenario (``run_rebalance``, PR 3): a chain whose first layers
are wide-FFN blocks, so the paper's ``equal_layers`` plan dumps ~all the
compute on node 0 — while the *balanced* plan gives the light-layer node
~3x the layers.  ``static`` serves on the equal_layers plan with fixed
knobs; ``controller`` starts from the SAME bad plan and lets the feedback
controller calibrate real costs, hot-migrate the cuts behind an epoch
fence (zero requests dropped), and adapt max_batch / coalesce_s online.

Elastic scenario (``run_elastic``, ISSUE 4): a 2-stage topology whose
stage 0 is a single widening layer that must ENCODE a 16x-wide activation
for the hop — with ZFP/LZ4 that encode saturates the stage (its egress
measures ~0.98 busy) while the decode side is ~6x cheaper, so stage 0 is
the bottleneck and the cut CANNOT move to fix it (one layer is already
minimal).  Replicas are the only lever: serving starts with 1 replica on
the bottleneck stage and ``Engine.scale()``s it to 2..N **under
closed-loop load** (the epoch fence keeps zero requests dropped —
asserted, every in-flight future must resolve); each membership is then
measured.  The codec is single-threaded per replica, so replication
parallelizes the wire encode — the honest in-process analogue of SEIFER
replicating a bottleneck partition across devices.  Results (throughput
before/after, dropped counts) land in BENCH_elastic.json.

Acceptance bars: async >= 1.5x sync (ISSUE 1, raw codec), staged >= 1.5x
async with zfp/q8 at >= 4 nodes x 8 clients (ISSUE 2), controller >=
1.3x static on the skewed chain with ZFP/LZ4 (ISSUE 3), and replicated
bottleneck measurably above the 1-replica plan with zero drops (ISSUE 4).

Procs scenario (``run_procs``, ISSUE 7): the elastic chain again, but
every replica is a SUPERVISED WORKER PROCESS (own OS process, loopback
sockets, byte framing) — then one stage-0 worker is SIGKILLed under
closed-loop load.  The bar is failure *semantics*, not speed: stranded
batches fail fast with NodeError (zero hangs, asserted — every future
resolves), the chain keeps serving on the survivor, and the supervisor
respawns the replica through the same epoch-fenced scale() a planned
resize uses, back to a numerically-correct full stage.  Results land in
BENCH_elastic_procs.json.

Every scenario accepts ``--transport`` (ISSUE 5): ``inproc`` (default),
``tcp`` (every chain hop over real loopback sockets with byte framing and
credit-window backpressure), or an emulated link such as
``link:10mbit,20ms`` reproducing the paper's CORE network conditions.
(``--procs`` always serves over the supervisor's own loopback sockets —
the processes make the transport.)

    PYTHONPATH=src python benchmarks/serve_load.py --nodes 4 --clients 8 \
        --codec zfp --min-staged-speedup 1.5
    PYTHONPATH=src python benchmarks/serve_load.py --rebalance \
        --codec zfp_lz4 --min-rebalance-speedup 1.3
    PYTHONPATH=src python benchmarks/serve_load.py --elastic --transport tcp
    PYTHONPATH=src:. python benchmarks/serve_load.py --procs
    PYTHONPATH=src python benchmarks/serve_load.py --smoke --transport tcp
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import threading
import time

# Each DEFER node models a SEPARATE edge device: give XLA one intra-op
# thread so per-node compute is serial and the chain's parallelism comes
# from the runtime (pipelining + batching), not from one GEMM grabbing
# every host core.  Must happen before jax initializes.
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                               "intra_op_parallelism_threads=1")

import jax

# execute jitted computations on the calling (per-node) thread instead of
# funneling every node's apply through the CPU client's single dispatch
# stream — the chain's node parallelism is real, as on separate devices
jax.config.update("jax_cpu_enable_async_dispatch", False)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.graph import LayerGraph
from repro.runtime import ControllerConfig, InferenceEngine, TopologySpec
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

D = 256
SEQ = 64
DEPTH = 16

CODECS = {
    "raw": WireCodec("raw", "none"),
    "zfp": WireCodec("zfp", "none", zfp_rate=16),
    "zfp_lz4": WireCodec("zfp", "lz4", zfp_rate=16),
    "q8": WireCodec("q8", "none"),
}


def serving_mlp(depth: int = DEPTH, d: int = D, seq: int = SEQ) -> LayerGraph:
    """A chain deep enough that a 4+ node partition has real per-stage
    compute (each hop is a [seq, d] x [d, d] GEMM, not a matvec), small
    enough that CPU jit stays in seconds."""
    g = LayerGraph("serve-mlp", jax.ShapeDtypeStruct((1, seq, d), np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, seq, d), np.float32),
                flops=2.0 * seq * d * d)
        prev = f"fc{i}"
    return g


def skewed_chain(d: int = D, wide: int = 2 * D, narrow: int = D // 4,
                 seq: int = SEQ) -> LayerGraph:
    """A 16-layer encoder-style chain whose activation widths pinch and
    flare: three blocks of [d -> narrow -> wide -> wide -> d] plus a tail.
    The paper's ``equal_layers`` plan (cuts after layers 3 / 7 / 11) lands
    every inter-node hop on a WIDE activation, so the chain pays maximum
    codec + transfer per request; the cost-aware plan cuts at the narrow
    pinch points (after layers 1 / 5 / 9 — ``wide/narrow``x fewer bytes
    per hop) and hands the light tail node ~3x the layers of the head
    node.  The static planner cannot see this: its LinkModel knows wire
    bandwidth, not the measured per-byte codec cost that dominates a real
    chain — exactly what the serving controller calibrates online."""
    g = LayerGraph("skewed-chain",
                   jax.ShapeDtypeStruct((1, seq, narrow), np.float32))

    def fc(i: int, din: int, dout: int, prev: str) -> str:
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((din, dout), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, seq, dout), np.float32),
                flops=2.0 * seq * din * dout)
        return f"fc{i}"

    dims = [narrow, d]                              # L0: narrow -> d
    for _ in range(3):                              # 3 pinch/flare blocks
        dims += [narrow, wide, wide, d]
    dims += [d, d, narrow]                          # tail, narrow output
    prev = ""
    for i, (din, dout) in enumerate(zip(dims, dims[1:])):
        prev = fc(i, din, dout, prev)
    return g


def sample(i: int, seq: int = SEQ, d: int = D) -> np.ndarray:
    rng = np.random.default_rng(i)
    return rng.normal(size=(1, seq, d)).astype(np.float32)


def build_engine(g: LayerGraph, params, topology, max_batch: int,
                 clients: int, codec: WireCodec, staged: bool,
                 **engine_kw) -> InferenceEngine:
    """``topology``: a TopologySpec, or an int for the classic 1-replica
    equal_layers chain (TopologySpec.chain sugar)."""
    eng = InferenceEngine(
        g, topology,
        DispatcherCodecs(data=codec, weights=WireCodec("raw", "none")),
        max_batch=max_batch, admission_depth=max(16, 4 * clients),
        staged=staged, **engine_kw)
    eng.configure(params)
    eng.precompile()
    eng.start()
    return eng


def warmup(eng: InferenceEngine, clients: int, seq: int, d: int,
           serialize: bool = False) -> None:
    """Run the same closed-loop pattern untimed so every batch-size jit
    specialization the load will hit is compiled before the clock starts."""
    for burst in (1, 2, clients):
        futs = [eng.submit(sample(10_000 + i, seq, d), client_id=i)
                for i in range(burst)]
        for f in futs:
            f.result()
    run_load(eng, clients, 4, seq, d, serialize=serialize)
    eng.dispatcher.drain()


def run_load(eng: InferenceEngine, clients: int, samples: int,
             seq: int, d: int, serialize: bool = False
             ) -> tuple[float, list]:
    """Closed-loop: each client thread awaits result i before sending i+1.
    ``serialize`` emulates the synchronous engine (one in flight, ever).
    Returns (wall_s, errors) — an empty error list certifies zero dropped
    or failed requests in the window."""
    lock = threading.Lock() if serialize else None
    barrier = threading.Barrier(clients + 1)
    errors: list = []

    def client(c: int) -> None:
        barrier.wait()
        try:
            for i in range(samples):
                x = sample(1000 * c + i, seq, d)
                if lock is not None:
                    with lock:
                        eng.submit(x, client_id=c).result()
                else:
                    eng.submit(x, client_id=c).result()
        except Exception as e:                  # pragma: no cover  # deferlint: swallow(recorded in errors[]; asserted after join)
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, errors


MODES = (
    # (mode, max_batch multiplier?, serialize clients, staged)
    ("sync", 1, True, False),
    ("async", 8, False, False),
    ("staged", 8, False, True),
)


def run(nodes: int = 4, clients: int = 8, samples: int = 16,
        codec: str = "zfp", repeats: int = 2, depth: int = DEPTH,
        d: int = D, seq: int = SEQ,
        transport: str = "inproc") -> list[dict]:
    g = serving_mlp(depth, d, seq)
    params = g.init(jax.random.PRNGKey(0))
    wire = CODECS[codec]
    spec = TopologySpec.chain(g, nodes, transport=transport)
    # the PR 1 modes run the PR 1 codec implementations; `staged` runs the
    # vectorized hot paths (both sides of the A/B are the code they claim)
    wire_pr1 = dataclasses.replace(wire, vectorized=False)
    rows = []
    for mode, max_batch, serialize, staged in MODES:
        eng = build_engine(g, params, spec, max_batch, clients,
                           wire if staged else wire_pr1, staged)
        warmup(eng, clients, seq, d, serialize=serialize)
        wall, rep, errs = _measure(eng, clients, samples, seq, d, repeats,
                                   serialize=serialize)
        eng.shutdown()
        assert not errs, errs
        rows.append({
            "mode": mode, "codec": rep.codec, "nodes": nodes,
            "transport": transport,
            "clients": clients, "samples": clients * samples,
            "wall_s": wall,
            "throughput_rps": rep.throughput_cps,
            "p50_ms": rep.p50_latency_s * 1e3,
            "p99_ms": rep.p99_latency_s * 1e3,
            "util_compute": float(np.mean([pn["util_compute"]
                                           for pn in rep.per_node])),
            "util_decode": float(np.mean([pn["util_decode"]
                                          for pn in rep.per_node])),
            "util_encode": float(np.mean([pn["util_encode"]
                                          for pn in rep.per_node])),
            "batch_mean": float(np.mean([pn["batch_mean"]
                                         for pn in rep.per_node])),
            "encodes_per_batch": float(np.mean([pn["encodes_per_batch"]
                                                for pn in rep.per_node])),
        })
    by_mode = {r["mode"]: r for r in rows}
    for r in rows:
        r["speedup_vs_sync"] = (r["throughput_rps"]
                                / by_mode["sync"]["throughput_rps"])
        r["speedup_vs_async"] = (r["throughput_rps"]
                                 / by_mode["async"]["throughput_rps"])
    return rows


# -- PR 3: controller vs static plan on a skewed chain -----------------------

def _measure(eng: InferenceEngine, clients: int, samples: int, seq: int,
             d: int, repeats: int,
             serialize: bool = False) -> tuple[float, "object", list]:
    """Best-of-N measured windows.  Scheduler jitter on an oversubscribed
    box only ever *adds* time, so min-wall is the lowest-noise estimator
    of a mode's real service rate."""
    best = None
    all_errs: list = []
    for _ in range(max(1, repeats)):
        eng.reset_window()
        wall, errs = run_load(eng, clients, samples, seq, d,
                              serialize=serialize)
        all_errs.extend(errs)
        rep = eng.report(samples=clients * samples, wall_s=wall)
        if best is None or wall < best[0]:
            best = (wall, rep)
    return best[0], best[1], all_errs


def _row(mode: str, wall: float, rep, nodes: int, clients: int,
         samples: int) -> dict:
    return {
        "mode": mode, "codec": rep.codec, "nodes": nodes,
        "clients": clients, "samples": clients * samples, "wall_s": wall,
        "throughput_rps": rep.throughput_cps,
        "p50_ms": rep.p50_latency_s * 1e3,
        "p99_ms": rep.p99_latency_s * 1e3,
        "epoch": rep.epoch, "cuts": "/".join(map(str, rep.cuts)),
        "batch_mean": float(np.mean([pn["batch_mean"]
                                     for pn in rep.per_node])),
        "util_compute_raw_max": max(pn["util_compute_raw"]
                                    for pn in rep.per_node),
        "coalesce_ms_mean": float(np.mean([pn["coalesce_s"]
                                           for pn in rep.per_node])) * 1e3,
        "max_batch_mean": float(np.mean([pn["max_batch"]
                                         for pn in rep.per_node])),
    }


def run_rebalance(nodes: int = 4, clients: int = 8, samples: int = 16,
                  codec: str = "zfp_lz4", repeats: int = 2,
                  d: int = D, wide: int = 2 * D, narrow: int = D // 4,
                  seq: int = SEQ, converge_s: float = 90.0,
                  smoke: bool = False, transport: str = "inproc") -> dict:
    """Static equal_layers vs controller-enabled serving on the skewed
    chain.  Both start from the SAME (bad) plan; only the controller may
    calibrate, migrate, and retune knobs.  Returns the full result dict
    (also written to BENCH_rebalance.json by main)."""
    g = skewed_chain(d, wide, narrow, seq)
    params = g.init(jax.random.PRNGKey(0))
    wire = CODECS[codec]
    rows = []

    # the paper's 1-replica equal_layers chain — the deliberately bad
    # static plan — on the selected transport backend
    spec = TopologySpec.chain(g, nodes, transport=transport)
    eng = build_engine(g, params, spec, 8, clients, wire, True)
    static_cuts = tuple(eng.dispatcher.partition.cuts)
    warmup(eng, clients, seq, narrow)
    wall, rep, errs = _measure(eng, clients, samples, seq, narrow, repeats)
    eng.shutdown()
    assert not errs, errs
    rows.append(_row("static", wall, rep, nodes, clients, samples))

    cfg = ControllerConfig(interval_s=0.25, min_requests=2 * clients,
                           cooldown_s=1.0, hysteresis=0.25,
                           ewma_alpha=0.5)
    eng = build_engine(g, params, spec, 8, clients, wire, True,
                       max_batch_cap=32, controller=cfg)
    warmup(eng, clients, seq, narrow)
    # convergence phase: serve until the controller commits a migration
    # (epoch > 0) — the untimed analogue of a warmed-up production chain
    conv_errs: list = []
    t0 = time.perf_counter()
    while (eng.dispatcher.epoch == 0
           and time.perf_counter() - t0 < converge_s):
        _, errs = run_load(eng, clients, 2, seq, narrow)
        conv_errs.extend(errs)
    converged_in = time.perf_counter() - t0
    if smoke and eng.dispatcher.epoch == 0:
        # the tiny raw-codec config may legitimately hold (costs nearly
        # balanced); the smoke gate still must exercise the live-migration
        # plumbing, so force a one-layer fence through the running chain
        eng.dispatcher.reconfigure(
            tuple(c + 1 for c in eng.dispatcher.partition.cuts))
    wall, rep, errs = _measure(eng, clients, samples, seq, narrow, repeats)
    reconfigs = list(eng.dispatcher.reconfig_records)
    eng.shutdown()
    assert not errs and not conv_errs, (errs, conv_errs)
    rows.append(_row("controller", wall, rep, nodes, clients, samples))

    speedup = (rows[1]["throughput_rps"] / rows[0]["throughput_rps"]
               if rows[0]["throughput_rps"] > 0 else 0.0)
    rows[1]["speedup_vs_static"] = speedup
    rows[0]["speedup_vs_static"] = 1.0
    emit("serve_rebalance", rows)
    return {
        "config": {"nodes": nodes, "clients": clients,
                   "samples_per_client": samples, "codec": codec,
                   "transport": transport,
                   "model": f"skewed-chain d={d} wide={wide} "
                            f"narrow={narrow} seq={seq} depth=16",
                   "static_cuts": static_cuts,
                   "protocol": "both modes best-of-N measured windows; "
                               "controller measured AFTER convergence "
                               "(epoch > 0 or timeout)"},
        "rows": rows,
        "speedup": speedup,
        "migrations": reconfigs,
        "converge_s": converged_in,
        "zero_dropped": True,        # asserted: no client saw an error
        "smoke": smoke,
        "notes": [
            "Both modes precompile and warm up identically and start from "
            "the same equal_layers plan; only the controller mode runs the "
            "feedback loop (cost calibration -> calibrated DP -> epoch-"
            "fenced migration + adaptive max_batch/coalesce_s).",
            "equal_layers cuts after layers 3/7/11 — all WIDE activations "
            "— so every hop pays maximum codec; the calibrated plan cuts "
            "the narrow pinch points after layers 1/5/9 (wide/narrow x "
            "fewer bytes per hop) and gives the tail node 3x the head "
            "node's layer count.",
            "The static planner cannot find the thin cuts: its LinkModel "
            "prices wire bandwidth, not the measured per-byte codec cost "
            "that dominates the chain — the controller calibrates that "
            "rate online from BatchTrace telemetry.",
            "zero_dropped is asserted, not sampled: every closed-loop "
            "client result is awaited through the migration and any "
            "failed/unresolved future fails the run.",
        ],
    }


# -- ISSUE 4: elastic membership on the bottleneck stage ----------------------

def _pound_while(eng, clients: int, seq: int, d: int, action,
                 settle_s: float = 0.2) -> tuple[dict, list, int]:
    """Closed-loop background load; run ``action()`` mid-flight; stop.
    Returns (action result, errors, requests completed) — the errors list
    must stay empty for the zero-dropped claim."""
    errors: list = []
    done = [0] * clients
    stop = threading.Event()

    def pound(c: int) -> None:
        i = 0
        try:
            while not stop.is_set():
                eng.submit(sample(777_000 + 1000 * c + i, seq, d),
                           client_id=("bg", c)).result(timeout=120)
                done[c] += 1
                i += 1
        except Exception as e:                  # pragma: no cover  # deferlint: swallow(recorded in errors[]; asserted after join)
            errors.append(e)

    threads = [threading.Thread(target=pound, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    time.sleep(settle_s)                        # real in-flight traffic
    rec = action()
    time.sleep(settle_s)                        # post-fence traffic too
    stop.set()
    for t in threads:
        t.join()
    return rec, errors, sum(done)


def elastic_chain(narrow: int = 64, wide: int = 1024, seq: int = SEQ,
                  tail: int = 3) -> LayerGraph:
    """A chain built to have an UNSPLITTABLE codec-bound bottleneck: fc0
    widens narrow -> wide (stage 0, one layer, so no thinner cut exists),
    the first hop carries the wide activation (stage 0 must encode it),
    and the tail immediately narrows back so every other hop is cheap."""
    g = LayerGraph("elastic-chain",
                   jax.ShapeDtypeStruct((1, seq, narrow), np.float32))
    dims = [narrow, wide] + [narrow] * tail
    prev = ""
    for i, (din, dout) in enumerate(zip(dims, dims[1:])):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((din, dout), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, seq, dout), np.float32),
                flops=2.0 * seq * din * dout)
        prev = f"fc{i}"
    return g


def run_elastic(clients: int = 24, samples: int = 8,
                codec: str = "zfp_lz4", repeats: int = 2,
                narrow: int = 64, wide: int = 1024, seq: int = SEQ,
                max_replicas: int = 3, transport: str = "inproc") -> dict:
    """1 -> N replicas on the bottleneck stage, scaled under load.

    Stage 0 is one widening layer whose egress ENCODES the wide
    activation: with ZFP/LZ4 that encode saturates the stage (~0.98 busy
    measured) while the receiving decode is ~6x cheaper, and the cut
    cannot move (a single layer is already minimal) — exactly the
    situation where the controller's replica dimension (and this
    scenario's explicit ``scale()``) is the remaining lever.  The numpy
    codec is single-threaded per replica, so replicas genuinely
    parallelize the wire encode.

    Closed-loop clients must OVERSUBSCRIBE the 1-replica capacity
    (default 24): replication raises a stage's service *rate*, never a
    request's own latency, so an unsaturated closed loop would measure no
    change by construction."""
    g = elastic_chain(narrow, wide, seq)
    d = narrow
    params = g.init(jax.random.PRNGKey(0))
    wire = CODECS[codec]
    spec = TopologySpec.chain(g, 2, cuts=(1,), transport=transport)
    eng = build_engine(g, params, spec, 8, clients, wire, True)
    bottleneck = 0                              # the wide-encoding stage
    warmup(eng, clients, seq, d)

    rows = []
    scale_recs = []

    def measure(label: str) -> None:
        wall, rep, errs = _measure(eng, clients, samples, seq, d, repeats)
        assert not errs, errs
        row = _row(label, wall, rep, sum(rep.replicas), clients, samples)
        row["replicas"] = "x".join(map(str, rep.replicas))
        rows.append(row)

    # membership ladder 1 -> 2 -> .. -> N -> 1: measuring the 1-replica
    # plan at BOTH ends and taking its best window makes the baseline
    # symmetric to box drift over the minutes the run takes, and the
    # final step exercises DRAIN under load in the recorded benchmark
    ladder = list(range(2, max_replicas + 1)) + [1]
    measure("replicas=1")
    for n in ladder:
        # the scale itself happens UNDER closed-loop load: the epoch
        # fence must lose nothing while membership changes.  precompile
        # traces the spawned replicas' batch shapes BEFORE they join the
        # routing set — a cold replica would otherwise serve its first
        # waves through XLA compiles and read as slower than no replica
        rec, errs, completed = _pound_while(
            eng, clients, seq, d,
            lambda n=n: eng.scale(bottleneck, n, precompile=True))
        # zero-drop is ASSERTED, not sampled: any client error during a
        # live scale aborts the benchmark instead of being counted
        assert not errs, errs
        rec["requests_during_scale"] = completed
        scale_recs.append(rec)
        measure(f"replicas={n}" + ("-drained" if n == 1 else ""))
    eng.shutdown()

    base = max(r["throughput_rps"] for r in rows
               if r["mode"].startswith("replicas=1"))
    for r in rows:
        r["speedup_vs_1_replica"] = (r["throughput_rps"] / base
                                     if base > 0 else 0.0)
    best = max((r for r in rows if not r["mode"].startswith("replicas=1")),
               key=lambda r: r["throughput_rps"])
    emit("serve_elastic", rows)
    return {
        "config": {"clients": clients, "samples_per_client": samples,
                   "codec": codec, "transport": transport,
                   "model": f"elastic-chain narrow={narrow} wide={wide} "
                            f"seq={seq}",
                   "topology": f"2 stages, cut after layer 1 (stage 0 = "
                               f"the single widening layer encoding the "
                               f"{wide}-wide hop), scale stage "
                               f"{bottleneck} 1->{max_replicas}",
                   "protocol": "membership ladder 1->2->..->N->1, each "
                               "scale() executed under closed-loop load "
                               "(zero-drop asserted on every in-flight "
                               "future), best-of-N measured windows per "
                               "membership; baseline = best 1-replica "
                               "window from either end of the ladder "
                               "(drift-symmetric)"},
        "rows": rows,
        "scales": scale_recs,
        "speedup": best["speedup_vs_1_replica"],
        "best_replicas": best["replicas"],
        "zero_dropped": True,   # asserted: any drop aborts the run above
        "notes": [
            "Stage 0 is a single layer, so no cut migration can shrink "
            "it: the wide-hop encode it pays is irreducible by the DP, "
            "which isolates the replica dimension.",
            "Each scale() rides the epoch fence: spawned replicas are "
            "configured over the wire with the stage's full weights and "
            "fenced into the routing set; every request in flight during "
            "the fence resolves (asserted, not sampled).",
            "Host ceiling: this container has 2 cores and one XLA apply "
            "already spends ~1.3 of them (two concurrent jitted GEMM "
            "loops aggregate only ~1.33x one loop, measured), so "
            "compute-bound stages cannot demonstrate replication "
            "in-process; the codec-bound stage can because the numpy "
            "codec is strictly single-threaded per replica.  LZ4's "
            "Python-level match loops still serialize part of each "
            "encode under the GIL, which is why 2-3 replicas land at "
            "~1.2-1.5x rather than 2-3x; on separate devices (the "
            "paper's setting) the same fence/routing machinery scales "
            "with the hardware.",
        ],
    }


# -- ISSUE 7: process-per-replica serving + self-healing drill ----------------

def run_procs(clients: int = 8, samples: int = 8, codec: str = "raw",
              repeats: int = 2, narrow: int = 16, wide: int = 64,
              seq: int = 16, replay: bool = False) -> dict:
    """Serve the elastic chain with every replica in its OWN OS process
    (supervised workers over loopback sockets), then SIGKILL a stage-0
    worker under closed-loop load and measure across the self-heal.

    ``replay=False`` (ISSUE 7 contract): the stranded batches fail fast
    (NodeError, never a hang), the chain keeps answering on the
    survivor, and the supervisor respawns the replica through the same
    epoch-fenced scale() a planned resize uses.

    ``replay=True`` (ISSUE 8 contract): a RetryPolicy is installed, so
    the dispatcher retains every request's encoded input and replays
    the stranded batches through the healed chain — the kill window
    must produce ZERO client-visible failures (asserted: the error list
    stays empty), and the record gains replay-rate and added-latency
    columns (kill-window p50 vs the undisturbed baseline p50).

    Either way zero-hang is asserted (every future resolves) and the
    healed chain must reproduce reference numerics."""
    from repro.runtime import NodeError, RetryPolicy
    from repro.runtime.supervisor import SupervisorConfig, supervised_engine
    from tools.chaos import Chaos
    g = elastic_chain(narrow, wide, seq)
    d = narrow
    params = g.init(jax.random.PRNGKey(0))
    wire = CODECS[codec]
    topo = TopologySpec.chain(g, 2, cuts=(1,)).with_replicas(0, 2)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # workers rebuild the graph from THIS file (code pre-installed on
    # every node, the paper's model); they import repro + benchmarks, so
    # their PYTHONPATH needs the repo root alongside src
    pyp = [root, os.path.join(root, "src")]
    if os.environ.get("PYTHONPATH"):
        pyp.append(os.environ["PYTHONPATH"])
    cfg = SupervisorConfig(
        graph_factory=os.path.abspath(__file__) + ":elastic_chain",
        graph_args={"narrow": narrow, "wide": wide, "seq": seq},
        heartbeat_s=0.2, backoff_initial_s=0.2, backoff_max_s=1.0,
        env={"PYTHONPATH": os.pathsep.join(pyp)})
    policy = RetryPolicy(max_attempts=5, backoff_s=0.05,
                         retry_budget=64.0, refill_per_s=32.0) \
        if replay else None
    eng, sup = supervised_engine(
        g, params, topo, cfg,
        codecs=DispatcherCodecs(data=wire, weights=WireCodec("raw", "none")),
        max_batch=8, admission_depth=max(16, 4 * clients),
        retry_policy=policy)
    chaos = Chaos(sup)
    rows = []
    try:
        eng.start()
        warmup(eng, clients, seq, d)

        def measure(label: str) -> None:
            wall, rep, errs = _measure(eng, clients, samples, seq, d,
                                       repeats)
            assert not errs, errs
            row = _row(label, wall, rep, sum(rep.replicas), clients,
                       samples)
            row["replicas"] = "x".join(map(str, rep.replicas))
            rows.append(row)

        measure("procs=2x1")
        # the drill: SIGKILL one stage-0 worker while closed-loop load is
        # in flight.  Replay OFF: NodeError on the stranded batches is
        # the contract (fail fast, never a hang).  Replay ON: the
        # dispatcher re-admits the retained inputs through the healed
        # stage, so the contract tightens to ZERO client-visible
        # failures.  Either way a hang or a foreign exception aborts.
        def kill() -> dict:
            pid = chaos.kill(chaos.pick(stage=0))
            chaos.wait_death(stage=0, timeout=30)
            return {"killed_pid": pid}

        eng.reset_window()              # isolate the kill-window latency
        rec, errors, completed = _pound_while(eng, clients, seq, d, kill)
        kill_rep = eng.report()
        if replay:
            assert not errors, errors   # exactly-once: no failure leaks
            failed = 0
        else:
            hard = [e for e in errors if not isinstance(e, NodeError)]
            assert not hard, hard
            failed = len(errors) - len(hard)
        chaos.wait_respawn(stage=0, timeout=60)
        assert chaos.wait_stage_full(eng.dispatcher, 0, timeout=60) == 2
        rec["requests_during_kill"] = completed
        rec["failed_fast"] = failed
        if replay:
            st = eng.dispatcher.replay_stats
            rec["replays"] = st.replays
            rec["replay_rate"] = st.replays / max(1, completed)
            rec["kill_window_p50_ms"] = kill_rep.p50_latency_s * 1e3
            rec["baseline_p50_ms"] = rows[0]["p50_ms"]
            rec["added_latency_p50_ms"] = (rec["kill_window_p50_ms"]
                                           - rec["baseline_p50_ms"])
        measure("healed=2x1")
        # reference numerics through the healed (respawned) chain
        x = sample(424_242, seq, d)
        np.testing.assert_allclose(
            eng.submit(x).result(timeout=120),
            np.asarray(g.apply(params, x)), atol=1e-4)
    finally:
        eng.shutdown()
        sup.close()
    kinds = [e["kind"] for e in sup.events]
    assert kinds.count("death") == 1 and kinds.count("respawn") >= 1, kinds
    base = rows[0]["throughput_rps"]
    for r in rows:
        r["vs_baseline"] = r["throughput_rps"] / base if base > 0 else 0.0
    emit("serve_procs", rows)
    notes = [
        "Workers rebuild the layer graph locally from the factory "
        "spec (code is pre-installed on every device, as in the "
        "paper); only topology and weights travel, as NodePlan "
        "framing over the control socket.",
    ]
    if replay:
        notes.append(
            "Replay ON: the dispatcher retained every request's encoded "
            "input, classified the kill's stranded batches as "
            "infrastructure failures, and re-admitted them under an "
            "incremented attempt tag — zero client-visible failures is "
            "asserted, not sampled.  added_latency_p50_ms is the price "
            "of exactly-once during the kill window (detection + "
            "backoff + re-serve) vs the undisturbed baseline.")
    else:
        notes.append(
            "The kill window's failures are exactly the batches inside "
            "the dead worker's pipeline (failed_fast above) — at-most-"
            "once on a crash, never a hang; survivors keep serving "
            "through the heal and the respawn rides the standard epoch-"
            "fenced scale() path.")
    return {
        "config": {"clients": clients, "samples_per_client": samples,
                   "codec": codec, "replay": replay,
                   "model": f"elastic-chain narrow={narrow} wide={wide} "
                            f"seq={seq}",
                   "topology": "2 stages, stage 0 x2 replicas, every "
                               "replica a supervised worker process "
                               "(loopback sockets, byte framing)",
                   "protocol": "measure 2-proc baseline; SIGKILL one "
                               "stage-0 worker under closed-loop load "
                               + ("(retained inputs replay through the "
                                  "healed stage: zero client-visible "
                                  "failures asserted)" if replay else
                                  "(stranded batches must fail fast, "
                                  "nothing may hang)")
                               + "; wait for the supervisor's respawn; "
                                 "measure healed"},
        "rows": rows,
        "kill": rec,
        "events": [e for e in sup.events
                   if e["kind"] in ("death", "respawn", "degraded")],
        "zero_hangs": True,     # asserted: every future resolved
        "notes": notes,
    }


def run_decode(sessions: int = 8, rounds: int = 2, new_tokens: int = 32,
               codec: str = "raw", transport: str = "inproc",
               smoke: bool = False) -> dict:
    """Autoregressive decode serving (ISSUE 9): N concurrent sessions
    greedy-decode closed-loop through a 2-stage chain with per-stage
    resident KV caches.  Reports tokens/s, per-step latency, and the
    decode contract's whole point — the per-step cross-hop payload
    (O(d_model), the newest token only) against what resending the full
    sequence through the same codec would cost every step."""
    from repro.models.lm_graph import (decode_lm_graph,
                                       pipeline_decode_reference)
    if smoke:
        cfg = dict(vocab=32, d_model=16, n_layers=2, num_heads=2,
                   kv_heads=2, head_dim=8, d_ff=32)
    else:
        cfg = dict(vocab=256, d_model=128, n_layers=4, num_heads=4,
                   kv_heads=4, head_dim=32, d_ff=256)
    prompt_len = 8
    cfg["cache_len"] = prompt_len + new_tokens + 2
    g = decode_lm_graph(**cfg)
    params = g.init(jax.random.PRNGKey(0))
    # lossless data path (greedy decode must be bit-identical across
    # hops) with the small-frame bypass sized to catch every token step
    wire = dataclasses.replace(CODECS[codec], small_bypass=4096)
    topo = TopologySpec.chain(g, 2, transport=transport)
    eng = InferenceEngine(
        g, topo, DispatcherCodecs(data=wire, weights=WireCodec("raw", "none")),
        max_batch=max(4, sessions), admission_depth=max(16, 4 * sessions))
    eng.configure(params)
    eng.start()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg["vocab"], size=prompt_len).tolist()
               for _ in range(sessions)]
    try:
        # warm every jit specialization the load will hit (prefill at the
        # prompt shape, the batched step at 1..pow2(sessions) rows)
        warm = [eng.generate(p, 3) for p in prompts]
        for gen in warm:
            next(gen)
        for gen in warm:
            list(gen)

        step_ms: list[float] = []
        lock = threading.Lock()

        def one_client(i: int) -> None:
            for _ in range(rounds):
                gen = eng.generate(prompts[i], new_tokens)
                next(gen)                   # prefill
                while True:
                    t0 = time.perf_counter()
                    try:
                        next(gen)
                    except StopIteration:
                        break
                    with lock:
                        step_ms.append((time.perf_counter() - t0) * 1e3)

        threads = [threading.Thread(target=one_client, args=(i,))
                   for i in range(sessions)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        toks = sessions * rounds * new_tokens

        # the payload contract, measured on the stage-0 hop: steps only
        # (open and close bracketed out), against a full-sequence resend
        # of the final-prefix boundary activations through the SAME codec
        gen = eng.generate(prompts[0], new_tokens)
        next(gen)
        node = eng.dispatcher.stages[0].live_replicas()[0]
        node.reset_stats()
        toks_meas = [next(gen) for _ in range(new_tokens - 1)]
        per_step = node.snapshot()["payload_bytes"] / (new_tokens - 1)
        gen.close()
        full = np.zeros((1, prompt_len + new_tokens, cfg["d_model"]),
                        np.float32)
        full_bytes = len(wire.encode_array(full))
        ref = pipeline_decode_reference(g, params, prompts[0], new_tokens)
        assert toks_meas == ref[1:], \
            "decode diverged from the single-device reference"
    finally:
        eng.shutdown()
    return {
        "sessions": sessions, "rounds": rounds, "new_tokens": new_tokens,
        "prompt_len": prompt_len, "model": cfg, "codec": wire.label,
        "transport": transport, "wall_s": wall,
        "tokens_per_s": toks / wall,
        "step_p50_ms": float(np.percentile(step_ms, 50)),
        "step_p99_ms": float(np.percentile(step_ms, 99)),
        "per_step_hop_bytes": per_step,
        "full_resend_hop_bytes": full_bytes,
        "hop_savings_x": full_bytes / per_step,
        "reference_bit_identical": True,    # asserted above
    }


def _bench_suffix(transport: str, procs: bool = False) -> str:
    """Per-scenario BENCH file suffix: 'inproc' keeps the bare name, any
    other binding (including distinct link shapes) records side by side
    — link:10mbit,20ms -> '_link_10mbit_20ms' — and process-backed runs
    append '_procs' so in-process and multi-process results coexist."""
    s = ""
    if transport != "inproc":
        s = "_" + re.sub(r"[^A-Za-z0-9]+", "_", transport).strip("_")
    if procs:
        s += "_procs"
    return s


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--clients", type=int, default=None,
                    help="closed-loop clients (default 8; 24 for "
                         "--elastic, which must oversubscribe the "
                         "1-replica capacity to see a rate change)")
    ap.add_argument("--samples", type=int, default=None,
                    help="samples per client (default 16; 8 for "
                         "--elastic)")
    ap.add_argument("--codec", choices=sorted(CODECS), default=None,
                    help="wire codec (default zfp; zfp_lz4 for --elastic, "
                         "whose bottleneck is the asymmetric wide-hop "
                         "encode)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured windows per mode; fastest is reported")
    ap.add_argument("--transport", default="inproc",
                    help="channel backend for every stage: inproc "
                         "(default), tcp (real loopback sockets), or an "
                         "emulated link like link:10mbit,20ms — the "
                         "paper's CORE network conditions (ISSUE 5)")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if async/sync < this (ISSUE 1 bar)")
    ap.add_argument("--min-staged-speedup", type=float, default=0.0,
                    help="exit nonzero if staged/async < this (ISSUE 2 bar)")
    ap.add_argument("--rebalance", action="store_true",
                    help="run the PR 3 skewed-chain controller scenario")
    ap.add_argument("--min-rebalance-speedup", type=float, default=0.0,
                    help="exit nonzero if controller/static < this "
                         "(ISSUE 3 bar)")
    ap.add_argument("--elastic", action="store_true",
                    help="run the ISSUE 4 replica-elasticity scenario "
                         "(scale the bottleneck stage 1->3 under load)")
    ap.add_argument("--min-elastic-speedup", type=float, default=0.0,
                    help="exit nonzero if best-replicated/1-replica < "
                         "this (ISSUE 4 bar)")
    ap.add_argument("--procs", action="store_true",
                    help="run the ISSUE 7 process-per-replica scenario: "
                         "supervised worker processes, SIGKILL one under "
                         "load, measure across the self-heal")
    ap.add_argument("--replay", action="store_true",
                    help="with --procs: install a RetryPolicy so the "
                         "SIGKILL drill must be invisible to clients "
                         "(ISSUE 8 exactly-once semantics: stranded "
                         "batches replay through the healed stage); "
                         "records BENCH_elastic_replay.json")
    ap.add_argument("--decode", action="store_true",
                    help="run the ISSUE 9 autoregressive decode scenario: "
                         "concurrent sessions generating closed-loop "
                         "through a 2-stage chain with resident KV "
                         "caches; records tokens/s and per-step hop "
                         "bytes vs a full-sequence resend")
    ap.add_argument("--sessions", type=int, default=None,
                    help="with --decode: concurrent decode sessions "
                         "(default 8; 2 with --smoke)")
    ap.add_argument("--new-tokens", type=int, default=None,
                    help="with --decode: tokens generated per session "
                         "per round (default 32)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny raw-codec config (seconds): plumbing gate "
                         "for CI, including one live reconfiguration")
    args = ap.parse_args()

    if args.decode:
        smoke = args.smoke
        res = run_decode(sessions=args.sessions or (2 if smoke else 8),
                         rounds=1 if smoke else args.repeats,
                         new_tokens=args.new_tokens or 32,
                         codec=args.codec or "raw",
                         transport=args.transport, smoke=smoke)
        if smoke:
            # CI gate: tokens flowed, greedy output matched the
            # single-device reference (asserted inside run_decode), and
            # the per-step hop payload beat a full-sequence resend 10x
            assert res["hop_savings_x"] >= 10.0, res
            print(f"decode smoke ok ({args.transport}): "
                  f"{res['tokens_per_s']:.1f} tok/s across "
                  f"{res['sessions']} sessions, per-step hop "
                  f"{res['per_step_hop_bytes']:.0f} B vs full resend "
                  f"{res['full_resend_hop_bytes']} B "
                  f"({res['hop_savings_x']:.1f}x), reference "
                  "bit-identity asserted")
            return
        res = {"benchmark": "benchmarks/serve_load.py --decode",
               "date": time.strftime("%Y-%m-%d"),
               "host": f"{os.cpu_count()}-core CPU container, "
                       f"jax {jax.__version__} cpu, XLA intra_op=1, "
                       "cpu async dispatch off",
               "acceptance": {
                   "bar": "concurrent sessions decode through the chain "
                          "with resident KV caches: per-step cross-hop "
                          "payload >= 10x smaller than a full-sequence "
                          "resend, greedy output bit-identical to the "
                          "single-device reference",
                   "result": f"{'PASS' if res['hop_savings_x'] >= 10 else 'FAIL'}"
                             f" at {res['hop_savings_x']:.1f}x hop "
                             f"savings, {res['tokens_per_s']:.1f} tok/s, "
                             "bit-identity asserted",
               },
               **res}
        with open(f"BENCH_decode{_bench_suffix(args.transport)}.json",
                  "w") as f:
            json.dump(res, f, indent=2, default=str)
        print(f"decode: {res['tokens_per_s']:.1f} tok/s "
              f"({res['sessions']} sessions x {res['rounds']} rounds x "
              f"{res['new_tokens']} tokens, {res['codec']}, "
              f"{res['transport']})")
        print(f"  step p50 {res['step_p50_ms']:.1f} ms  "
              f"p99 {res['step_p99_ms']:.1f} ms")
        print(f"  per-step hop {res['per_step_hop_bytes']:.0f} B vs "
              f"full-sequence resend {res['full_resend_hop_bytes']} B "
              f"= {res['hop_savings_x']:.1f}x smaller")
        return

    if args.smoke and args.procs:
        # tiny process-mode gate (seconds): two worker processes on
        # stage 0, SIGKILL one under closed-loop load.  With --replay
        # the kill must be INVISIBLE to clients (zero failures, the CI
        # replay leg); without, the stranded batches must fail fast.
        res = run_procs(clients=2, samples=2, codec="raw", repeats=1,
                        replay=args.replay)
        k = res["kill"]
        extra = (f", {k['replays']} replay(s)" if args.replay
                 else f", {k['failed_fast']} failed fast")
        print(f"procs smoke ok ({'replay' if args.replay else 'fail-fast'}):"
              f" killed pid {k['killed_pid']}, "
              f"{k['requests_during_kill']} requests in the kill window"
              + extra + ", healed to full stage (asserted)")
        return

    if args.smoke:
        # small model, 2 nodes, raw codec: exercises admission, staging,
        # batch wire framing, the controller step, and a live repartition
        # (--transport tcp runs the whole gate over real loopback sockets)
        rows = run(nodes=2, clients=2, samples=3, codec="raw", repeats=1,
                   depth=6, d=64, seq=16, transport=args.transport)
        emit("serve_load_smoke", rows)
        res = run_rebalance(nodes=2, clients=2, samples=3, codec="raw",
                            repeats=1, d=64, wide=128, narrow=16, seq=16,
                            converge_s=10.0, smoke=True,
                            transport=args.transport)
        assert res["zero_dropped"]
        # a live repartition MUST have happened (controller-decided or the
        # forced smoke fence) and lost nothing — this is the plumbing the
        # CI gate exists to catch
        assert res["rows"][1]["epoch"] >= 1, res["rows"][1]
        # the elastic plumbing too: spawn + drain a replica under load
        # (tiny config, seconds) with zero dropped requests
        eres = run_elastic(clients=2, samples=3, codec="raw", repeats=1,
                           narrow=16, wide=64, seq=16, max_replicas=2,
                           transport=args.transport)
        assert eres["zero_dropped"], eres
        # the ladder went 1 -> 2 -> 1: a spawn AND a drain both fenced
        # through a loaded chain
        assert any(r["replicas"] == "2x1" for r in eres["rows"]), eres
        assert eres["rows"][-1]["replicas"] == "1x1", eres["rows"][-1]
        assert eres["rows"][-1]["epoch"] == 2, eres["rows"][-1]
        print(f"smoke ok ({args.transport}): "
              f"staged {rows[-1]['throughput_rps']:.1f} req/s, "
              f"rebalance epoch {res['rows'][1]['epoch']}, "
              f"controller {res['rows'][1]['throughput_rps']:.1f} req/s, "
              f"elastic {eres['rows'][0]['throughput_rps']:.1f} -> "
              f"{eres['rows'][-1]['throughput_rps']:.1f} req/s")
        return

    if args.elastic:
        res = run_elastic(args.clients or 24, args.samples or 8,
                          args.codec or "zfp_lz4", args.repeats,
                          transport=args.transport)
        res = {"benchmark": "benchmarks/serve_load.py --elastic",
               "date": time.strftime("%Y-%m-%d"),
               "host": f"{os.cpu_count()}-core CPU container, "
                       f"jax {jax.__version__} cpu, XLA intra_op=1, "
                       "cpu async dispatch off",
               "acceptance": {
                   "bar": "a replicated bottleneck stage yields measurably "
                          "higher throughput than the 1-replica plan, with "
                          "zero requests dropped during the live scale()s",
                   "result": f"{'PASS' if res['speedup'] > 1.0 and res['zero_dropped'] else 'FAIL'}"
                             f" at {res['speedup']:.2f}x "
                             f"({res['best_replicas']} replicas), "
                             f"zero_dropped (asserted)",
               },
               **res}
        with open(f"BENCH_elastic{_bench_suffix(args.transport)}.json",
                  "w") as f:
            json.dump(res, f, indent=2, default=str)
        print(f"elastic speedup: {res['speedup']:.2f}x at "
              f"{res['best_replicas']} replicas (zero dropped: asserted)")
        for r in res["rows"]:
            print(f"  {r['mode']:<12} {r['throughput_rps']:6.1f} req/s  "
                  f"p50 {r['p50_ms']:6.1f} ms  "
                  f"({r['speedup_vs_1_replica']:.2f}x)")
        if args.min_elastic_speedup \
                and res["speedup"] < args.min_elastic_speedup:
            raise SystemExit(
                f"elastic speedup {res['speedup']:.2f}x < required "
                f"{args.min_elastic_speedup}x")
        return

    if args.procs:
        res = run_procs(args.clients or 8, args.samples or 8,
                        args.codec or "raw", args.repeats,
                        replay=args.replay)
        k = res["kill"]
        if args.replay:
            acceptance = {
                "bar": "with a RetryPolicy installed, a SIGKILLed worker "
                       "process is invisible to clients: zero failures, "
                       "zero hangs, stranded batches replayed through "
                       "the healed stage, reference numerics",
                "result": "PASS (asserted: zero client-visible failures; "
                          f"{k['replays']} replay(s), replay_rate "
                          f"{k['replay_rate']:.3f}, kill-window p50 "
                          f"{k['added_latency_p50_ms']:+.1f} ms vs "
                          "baseline)",
            }
            out = "BENCH_elastic_replay.json"
        else:
            acceptance = {
                "bar": "a SIGKILLed worker process fails its stranded "
                       "batches fast (NodeError, zero hangs), the "
                       "chain keeps serving on the survivor, and the "
                       "supervisor respawns the replica to a full, "
                       "numerically-correct stage",
                "result": "PASS (all asserted: fail-fast, respawn, "
                          f"stage full, reference numerics; "
                          f"{k['failed_fast']} batches "
                          "failed fast during the kill window)",
            }
            out = (f"BENCH_elastic"
                   f"{_bench_suffix(args.transport, procs=True)}.json")
        res = {"benchmark": "benchmarks/serve_load.py --procs"
                            + (" --replay" if args.replay else ""),
               "date": time.strftime("%Y-%m-%d"),
               "host": f"{os.cpu_count()}-core CPU container, "
                       f"jax {jax.__version__} cpu, XLA intra_op=1, "
                       "cpu async dispatch off",
               "acceptance": acceptance,
               **res}
        with open(out, "w") as f:
            json.dump(res, f, indent=2, default=str)
        if args.replay:
            print(f"procs+replay: killed pid {k['killed_pid']}, "
                  f"{k['requests_during_kill']} requests in the kill "
                  f"window, 0 client-visible failures (asserted), "
                  f"{k['replays']} replay(s) "
                  f"(rate {k['replay_rate']:.3f}), kill-window p50 "
                  f"{k['kill_window_p50_ms']:.1f} ms vs baseline "
                  f"{k['baseline_p50_ms']:.1f} ms "
                  f"({k['added_latency_p50_ms']:+.1f} ms)")
        else:
            print(f"procs: killed pid {k['killed_pid']}, "
                  f"{k['failed_fast']} failed fast of "
                  f"{k['requests_during_kill']} in the kill window, "
                  "healed to full stage (asserted)")
        for r in res["rows"]:
            print(f"  {r['mode']:<12} {r['throughput_rps']:6.1f} req/s  "
                  f"p50 {r['p50_ms']:6.1f} ms  "
                  f"({r['vs_baseline']:.2f}x vs baseline)")
        return

    if args.rebalance:
        res = run_rebalance(args.nodes, args.clients or 8,
                            args.samples or 16, args.codec or "zfp_lz4",
                            args.repeats, transport=args.transport)
        res = {"benchmark": "benchmarks/serve_load.py --rebalance",
               "date": time.strftime("%Y-%m-%d"),
               "host": f"{os.cpu_count()}-core CPU container, "
                       f"jax {jax.__version__} cpu, XLA intra_op=1, "
                       "cpu async dispatch off",
               "acceptance": {
                   "bar": "controller >= 1.3x static equal_layers on the "
                          "skewed chain (ZFP/LZ4, 4 nodes x 8 clients), "
                          "zero in-flight requests dropped by the hot "
                          "repartition",
                   "result": f"{'PASS' if res['speedup'] >= 1.3 else 'FAIL'}"
                             f" at {res['speedup']:.2f}x, zero_dropped="
                             f"{res['zero_dropped']}",
               },
               **res}
        with open(f"BENCH_rebalance{_bench_suffix(args.transport)}.json",
                  "w") as f:
            json.dump(res, f, indent=2, default=str)
        print(f"controller/static speedup: {res['speedup']:.2f}x "
              f"(epoch {res['rows'][1]['epoch']}, "
              f"cuts {res['rows'][0]['cuts']} -> {res['rows'][1]['cuts']}, "
              f"zero dropped: {res['zero_dropped']})")
        if args.min_rebalance_speedup \
                and res["speedup"] < args.min_rebalance_speedup:
            raise SystemExit(
                f"rebalance speedup {res['speedup']:.2f}x < required "
                f"{args.min_rebalance_speedup}x")
        return

    rows = run(args.nodes, args.clients or 8, args.samples or 16,
               args.codec or "zfp", args.repeats,
               transport=args.transport)
    emit("serve_load", rows)
    by_mode = {r["mode"]: r for r in rows}
    s_async = by_mode["async"]["speedup_vs_sync"]
    s_staged = by_mode["staged"]["speedup_vs_async"]
    print(f"async/sync speedup:   {s_async:.2f}x "
          f"({by_mode['async']['throughput_rps']:.1f} vs "
          f"{by_mode['sync']['throughput_rps']:.1f} req/s)")
    print(f"staged/async speedup: {s_staged:.2f}x "
          f"({by_mode['staged']['throughput_rps']:.1f} vs "
          f"{by_mode['async']['throughput_rps']:.1f} req/s, "
          f"codec {by_mode['staged']['codec']})")
    if args.min_speedup and s_async < args.min_speedup:
        raise SystemExit(
            f"async speedup {s_async:.2f}x < required {args.min_speedup}x")
    if args.min_staged_speedup and s_staged < args.min_staged_speedup:
        raise SystemExit(f"staged speedup {s_staged:.2f}x < "
                         f"required {args.min_staged_speedup}x")


if __name__ == "__main__":
    main()
