"""Closed-loop multi-client load test: async continuous-batching runtime
vs the synchronous engine, on the same DEFER chain.

N concurrent clients each send M samples closed-loop (a client admits its
next request only after receiving the previous result).

* ``sync``  — the seed's serving model: blocking submit with ONE request
  in the chain at a time (global lock, max_batch=1).
* ``async`` — the serving runtime: all clients admit concurrently through
  the bounded admission queue; compute nodes batch continuously.

The async engine must sustain >= 1.5x the synchronous throughput at
>= 4 nodes and >= 8 clients (ISSUE 1 acceptance bar).

    PYTHONPATH=src python benchmarks/serve_load.py --nodes 4 --clients 8
"""
from __future__ import annotations

import argparse
import os
import threading
import time

# Each DEFER node models a SEPARATE edge device: give XLA one intra-op
# thread so per-node compute is serial and the chain's parallelism comes
# from the runtime (pipelining + batching), not from one GEMM grabbing
# every host core.  Must happen before jax initializes.
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                               "intra_op_parallelism_threads=1")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.graph import LayerGraph
from repro.runtime import InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

D = 256
SEQ = 64
DEPTH = 16


def serving_mlp(depth: int = DEPTH, d: int = D, seq: int = SEQ) -> LayerGraph:
    """A chain deep enough that a 4+ node partition has real per-stage
    compute (each hop is a [seq, d] x [d, d] GEMM, not a matvec), small
    enough that CPU jit stays in seconds."""
    g = LayerGraph("serve-mlp", jax.ShapeDtypeStruct((1, seq, d), np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, seq, d), np.float32),
                flops=2.0 * seq * d * d)
        prev = f"fc{i}"
    return g


def sample(i: int) -> np.ndarray:
    rng = np.random.default_rng(i)
    return rng.normal(size=(1, SEQ, D)).astype(np.float32)


RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))


def build_engine(g: LayerGraph, params, nodes: int, max_batch: int,
                 clients: int) -> InferenceEngine:
    eng = InferenceEngine(g, nodes, RAW, max_batch=max_batch,
                          admission_depth=max(16, 4 * clients))
    eng.configure(params)
    eng.start()
    return eng


def warmup(eng: InferenceEngine, clients: int,
           serialize: bool = False) -> None:
    """Run the same closed-loop pattern untimed so every batch-size jit
    specialization the load will hit is compiled before the clock starts."""
    for burst in (1, 2, clients):
        futs = [eng.submit(sample(10_000 + i), client_id=i)
                for i in range(burst)]
        for f in futs:
            f.result()
    run_load(eng, clients, 4, serialize=serialize)
    eng.dispatcher.drain()


def run_load(eng: InferenceEngine, clients: int, samples: int,
             serialize: bool = False) -> float:
    """Closed-loop: each client thread awaits result i before sending i+1.
    ``serialize`` emulates the synchronous engine (one in flight, ever)."""
    lock = threading.Lock() if serialize else None
    barrier = threading.Barrier(clients + 1)

    def client(c: int) -> None:
        barrier.wait()
        for i in range(samples):
            x = sample(1000 * c + i)
            if lock is not None:
                with lock:
                    eng.submit(x, client_id=c).result()
            else:
                eng.submit(x, client_id=c).result()

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def run(nodes: int = 4, clients: int = 8, samples: int = 16) -> list[dict]:
    g = serving_mlp()
    params = g.init(jax.random.PRNGKey(0))
    rows = []
    reports = {}
    for mode, max_batch, serialize in (("sync", 1, True),
                                       ("async", 8, False)):
        eng = build_engine(g, params, nodes, max_batch, clients)
        warmup(eng, clients, serialize=serialize)
        eng.reset_window()
        wall = run_load(eng, clients, samples, serialize=serialize)
        rep = eng.report(samples=clients * samples, wall_s=wall)
        eng.shutdown()
        reports[mode] = rep
        rows.append({
            "mode": mode, "nodes": nodes, "clients": clients,
            "samples": clients * samples, "wall_s": wall,
            "throughput_rps": rep.throughput_cps,
            "p50_ms": rep.p50_latency_s * 1e3,
            "p99_ms": rep.p99_latency_s * 1e3,
            "util_mean": float(np.mean([pn["utilization"]
                                        for pn in rep.per_node])),
            "batch_mean": float(np.mean([pn["batch_mean"]
                                         for pn in rep.per_node])),
        })
    speedup = rows[1]["throughput_rps"] / rows[0]["throughput_rps"]
    for r in rows:
        r["speedup_vs_sync"] = (1.0 if r["mode"] == "sync" else speedup)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples", type=int, default=16)
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if async/sync < this")
    args = ap.parse_args()
    rows = run(args.nodes, args.clients, args.samples)
    emit("serve_load", rows)
    speedup = rows[1]["speedup_vs_sync"]
    print(f"async/sync speedup: {speedup:.2f}x "
          f"({rows[1]['throughput_rps']:.1f} vs "
          f"{rows[0]['throughput_rps']:.1f} req/s)")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"speedup {speedup:.2f}x < required {args.min_speedup}x")


if __name__ == "__main__":
    main()
