"""Closed-loop multi-client load test: staged codec/compute-overlap runtime
vs the PR 1 baseline and the synchronous engine, on the same DEFER chain —
plus the PR 3 skewed-chain scenario where the serving-time controller
recalibrates costs online and hot-repartitions a mis-planned chain.

N concurrent clients each send M samples closed-loop (a client admits its
next request only after receiving the previous result).

Classic A/B (``run``):

* ``sync``     — the seed's serving model: blocking submit with ONE request
  in the chain at a time (global lock, max_batch=1), PR 1 codecs.
* ``async``    — the PR 1 async runtime, faithfully: continuous batching,
  but each node runs decode -> apply -> encode sequentially on one worker
  thread, re-encodes every request separately (``staged=False``), and uses
  the PR 1 codec implementations (``WireCodec(vectorized=False)``).
* ``staged``   — the PR 2 runtime: 3-stage per-node pipeline overlapping
  codec with compute, batch-level wire encoding, vectorized codecs.

Rebalance scenario (``run_rebalance``, PR 3): a chain whose first layers
are wide-FFN blocks, so the paper's ``equal_layers`` plan dumps ~all the
compute on node 0 — while the *balanced* plan gives the light-layer node
~3x the layers.  ``static`` serves on the equal_layers plan with fixed
knobs; ``controller`` starts from the SAME bad plan and lets the feedback
controller calibrate real costs, hot-migrate the cuts behind an epoch
fence (zero requests dropped), and adapt max_batch / coalesce_s online.

Acceptance bars: async >= 1.5x sync (ISSUE 1, raw codec), staged >= 1.5x
async with zfp/q8 at >= 4 nodes x 8 clients (ISSUE 2), and controller >=
1.3x static on the skewed chain with ZFP/LZ4 (ISSUE 3).

    PYTHONPATH=src python benchmarks/serve_load.py --nodes 4 --clients 8 \
        --codec zfp --min-staged-speedup 1.5
    PYTHONPATH=src python benchmarks/serve_load.py --rebalance \
        --codec zfp_lz4 --min-rebalance-speedup 1.3
    PYTHONPATH=src python benchmarks/serve_load.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import threading
import time

# Each DEFER node models a SEPARATE edge device: give XLA one intra-op
# thread so per-node compute is serial and the chain's parallelism comes
# from the runtime (pipelining + batching), not from one GEMM grabbing
# every host core.  Must happen before jax initializes.
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                               "intra_op_parallelism_threads=1")

import jax

# execute jitted computations on the calling (per-node) thread instead of
# funneling every node's apply through the CPU client's single dispatch
# stream — the chain's node parallelism is real, as on separate devices
jax.config.update("jax_cpu_enable_async_dispatch", False)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.graph import LayerGraph
from repro.runtime import ControllerConfig, InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

D = 256
SEQ = 64
DEPTH = 16

CODECS = {
    "raw": WireCodec("raw", "none"),
    "zfp": WireCodec("zfp", "none", zfp_rate=16),
    "zfp_lz4": WireCodec("zfp", "lz4", zfp_rate=16),
    "q8": WireCodec("q8", "none"),
}


def serving_mlp(depth: int = DEPTH, d: int = D, seq: int = SEQ) -> LayerGraph:
    """A chain deep enough that a 4+ node partition has real per-stage
    compute (each hop is a [seq, d] x [d, d] GEMM, not a matvec), small
    enough that CPU jit stays in seconds."""
    g = LayerGraph("serve-mlp", jax.ShapeDtypeStruct((1, seq, d), np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, seq, d), np.float32),
                flops=2.0 * seq * d * d)
        prev = f"fc{i}"
    return g


def skewed_chain(d: int = D, wide: int = 2 * D, narrow: int = D // 4,
                 seq: int = SEQ) -> LayerGraph:
    """A 16-layer encoder-style chain whose activation widths pinch and
    flare: three blocks of [d -> narrow -> wide -> wide -> d] plus a tail.
    The paper's ``equal_layers`` plan (cuts after layers 3 / 7 / 11) lands
    every inter-node hop on a WIDE activation, so the chain pays maximum
    codec + transfer per request; the cost-aware plan cuts at the narrow
    pinch points (after layers 1 / 5 / 9 — ``wide/narrow``x fewer bytes
    per hop) and hands the light tail node ~3x the layers of the head
    node.  The static planner cannot see this: its LinkModel knows wire
    bandwidth, not the measured per-byte codec cost that dominates a real
    chain — exactly what the serving controller calibrates online."""
    g = LayerGraph("skewed-chain",
                   jax.ShapeDtypeStruct((1, seq, narrow), np.float32))

    def fc(i: int, din: int, dout: int, prev: str) -> str:
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((din, dout), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, seq, dout), np.float32),
                flops=2.0 * seq * din * dout)
        return f"fc{i}"

    dims = [narrow, d]                              # L0: narrow -> d
    for _ in range(3):                              # 3 pinch/flare blocks
        dims += [narrow, wide, wide, d]
    dims += [d, d, narrow]                          # tail, narrow output
    prev = ""
    for i, (din, dout) in enumerate(zip(dims, dims[1:])):
        prev = fc(i, din, dout, prev)
    return g


def sample(i: int, seq: int = SEQ, d: int = D) -> np.ndarray:
    rng = np.random.default_rng(i)
    return rng.normal(size=(1, seq, d)).astype(np.float32)


def build_engine(g: LayerGraph, params, nodes: int, max_batch: int,
                 clients: int, codec: WireCodec, staged: bool,
                 **engine_kw) -> InferenceEngine:
    eng = InferenceEngine(
        g, nodes,
        DispatcherCodecs(data=codec, weights=WireCodec("raw", "none")),
        max_batch=max_batch, admission_depth=max(16, 4 * clients),
        staged=staged, **engine_kw)
    eng.configure(params)
    eng.precompile()
    eng.start()
    return eng


def warmup(eng: InferenceEngine, clients: int, seq: int, d: int,
           serialize: bool = False) -> None:
    """Run the same closed-loop pattern untimed so every batch-size jit
    specialization the load will hit is compiled before the clock starts."""
    for burst in (1, 2, clients):
        futs = [eng.submit(sample(10_000 + i, seq, d), client_id=i)
                for i in range(burst)]
        for f in futs:
            f.result()
    run_load(eng, clients, 4, seq, d, serialize=serialize)
    eng.dispatcher.drain()


def run_load(eng: InferenceEngine, clients: int, samples: int,
             seq: int, d: int, serialize: bool = False
             ) -> tuple[float, list]:
    """Closed-loop: each client thread awaits result i before sending i+1.
    ``serialize`` emulates the synchronous engine (one in flight, ever).
    Returns (wall_s, errors) — an empty error list certifies zero dropped
    or failed requests in the window."""
    lock = threading.Lock() if serialize else None
    barrier = threading.Barrier(clients + 1)
    errors: list = []

    def client(c: int) -> None:
        barrier.wait()
        try:
            for i in range(samples):
                x = sample(1000 * c + i, seq, d)
                if lock is not None:
                    with lock:
                        eng.submit(x, client_id=c).result()
                else:
                    eng.submit(x, client_id=c).result()
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, errors


MODES = (
    # (mode, max_batch multiplier?, serialize clients, staged)
    ("sync", 1, True, False),
    ("async", 8, False, False),
    ("staged", 8, False, True),
)


def run(nodes: int = 4, clients: int = 8, samples: int = 16,
        codec: str = "zfp", repeats: int = 2, depth: int = DEPTH,
        d: int = D, seq: int = SEQ) -> list[dict]:
    g = serving_mlp(depth, d, seq)
    params = g.init(jax.random.PRNGKey(0))
    wire = CODECS[codec]
    # the PR 1 modes run the PR 1 codec implementations; `staged` runs the
    # vectorized hot paths (both sides of the A/B are the code they claim)
    wire_pr1 = dataclasses.replace(wire, vectorized=False)
    rows = []
    for mode, max_batch, serialize, staged in MODES:
        eng = build_engine(g, params, nodes, max_batch, clients,
                           wire if staged else wire_pr1, staged)
        warmup(eng, clients, seq, d, serialize=serialize)
        wall, rep, errs = _measure(eng, clients, samples, seq, d, repeats,
                                   serialize=serialize)
        eng.shutdown()
        assert not errs, errs
        rows.append({
            "mode": mode, "codec": rep.codec, "nodes": nodes,
            "clients": clients, "samples": clients * samples,
            "wall_s": wall,
            "throughput_rps": rep.throughput_cps,
            "p50_ms": rep.p50_latency_s * 1e3,
            "p99_ms": rep.p99_latency_s * 1e3,
            "util_compute": float(np.mean([pn["util_compute"]
                                           for pn in rep.per_node])),
            "util_decode": float(np.mean([pn["util_decode"]
                                          for pn in rep.per_node])),
            "util_encode": float(np.mean([pn["util_encode"]
                                          for pn in rep.per_node])),
            "batch_mean": float(np.mean([pn["batch_mean"]
                                         for pn in rep.per_node])),
            "encodes_per_batch": float(np.mean([pn["encodes_per_batch"]
                                                for pn in rep.per_node])),
        })
    by_mode = {r["mode"]: r for r in rows}
    for r in rows:
        r["speedup_vs_sync"] = (r["throughput_rps"]
                                / by_mode["sync"]["throughput_rps"])
        r["speedup_vs_async"] = (r["throughput_rps"]
                                 / by_mode["async"]["throughput_rps"])
    return rows


# -- PR 3: controller vs static plan on a skewed chain -----------------------

def _measure(eng: InferenceEngine, clients: int, samples: int, seq: int,
             d: int, repeats: int,
             serialize: bool = False) -> tuple[float, "object", list]:
    """Best-of-N measured windows.  Scheduler jitter on an oversubscribed
    box only ever *adds* time, so min-wall is the lowest-noise estimator
    of a mode's real service rate."""
    best = None
    all_errs: list = []
    for _ in range(max(1, repeats)):
        eng.reset_window()
        wall, errs = run_load(eng, clients, samples, seq, d,
                              serialize=serialize)
        all_errs.extend(errs)
        rep = eng.report(samples=clients * samples, wall_s=wall)
        if best is None or wall < best[0]:
            best = (wall, rep)
    return best[0], best[1], all_errs


def _row(mode: str, wall: float, rep, nodes: int, clients: int,
         samples: int) -> dict:
    return {
        "mode": mode, "codec": rep.codec, "nodes": nodes,
        "clients": clients, "samples": clients * samples, "wall_s": wall,
        "throughput_rps": rep.throughput_cps,
        "p50_ms": rep.p50_latency_s * 1e3,
        "p99_ms": rep.p99_latency_s * 1e3,
        "epoch": rep.epoch, "cuts": "/".join(map(str, rep.cuts)),
        "batch_mean": float(np.mean([pn["batch_mean"]
                                     for pn in rep.per_node])),
        "util_compute_raw_max": max(pn["util_compute_raw"]
                                    for pn in rep.per_node),
        "coalesce_ms_mean": float(np.mean([pn["coalesce_s"]
                                           for pn in rep.per_node])) * 1e3,
        "max_batch_mean": float(np.mean([pn["max_batch"]
                                         for pn in rep.per_node])),
    }


def run_rebalance(nodes: int = 4, clients: int = 8, samples: int = 16,
                  codec: str = "zfp_lz4", repeats: int = 2,
                  d: int = D, wide: int = 2 * D, narrow: int = D // 4,
                  seq: int = SEQ, converge_s: float = 90.0,
                  smoke: bool = False) -> dict:
    """Static equal_layers vs controller-enabled serving on the skewed
    chain.  Both start from the SAME (bad) plan; only the controller may
    calibrate, migrate, and retune knobs.  Returns the full result dict
    (also written to BENCH_rebalance.json by main)."""
    g = skewed_chain(d, wide, narrow, seq)
    params = g.init(jax.random.PRNGKey(0))
    wire = CODECS[codec]
    rows = []

    eng = build_engine(g, params, nodes, 8, clients, wire, True,
                       strategy="equal_layers")
    static_cuts = tuple(eng.dispatcher.partition.cuts)
    warmup(eng, clients, seq, narrow)
    wall, rep, errs = _measure(eng, clients, samples, seq, narrow, repeats)
    eng.shutdown()
    assert not errs, errs
    rows.append(_row("static", wall, rep, nodes, clients, samples))

    cfg = ControllerConfig(interval_s=0.25, min_requests=2 * clients,
                           cooldown_s=1.0, hysteresis=0.25,
                           ewma_alpha=0.5)
    eng = build_engine(g, params, nodes, 8, clients, wire, True,
                       strategy="equal_layers", max_batch_cap=32,
                       controller=cfg)
    warmup(eng, clients, seq, narrow)
    # convergence phase: serve until the controller commits a migration
    # (epoch > 0) — the untimed analogue of a warmed-up production chain
    conv_errs: list = []
    t0 = time.perf_counter()
    while (eng.dispatcher.epoch == 0
           and time.perf_counter() - t0 < converge_s):
        _, errs = run_load(eng, clients, 2, seq, narrow)
        conv_errs.extend(errs)
    converged_in = time.perf_counter() - t0
    if smoke and eng.dispatcher.epoch == 0:
        # the tiny raw-codec config may legitimately hold (costs nearly
        # balanced); the smoke gate still must exercise the live-migration
        # plumbing, so force a one-layer fence through the running chain
        eng.dispatcher.reconfigure(
            tuple(c + 1 for c in eng.dispatcher.partition.cuts))
    wall, rep, errs = _measure(eng, clients, samples, seq, narrow, repeats)
    reconfigs = list(eng.dispatcher.reconfig_records)
    eng.shutdown()
    assert not errs and not conv_errs, (errs, conv_errs)
    rows.append(_row("controller", wall, rep, nodes, clients, samples))

    speedup = (rows[1]["throughput_rps"] / rows[0]["throughput_rps"]
               if rows[0]["throughput_rps"] > 0 else 0.0)
    rows[1]["speedup_vs_static"] = speedup
    rows[0]["speedup_vs_static"] = 1.0
    emit("serve_rebalance", rows)
    return {
        "config": {"nodes": nodes, "clients": clients,
                   "samples_per_client": samples, "codec": codec,
                   "model": f"skewed-chain d={d} wide={wide} "
                            f"narrow={narrow} seq={seq} depth=16",
                   "static_cuts": static_cuts,
                   "protocol": "both modes best-of-N measured windows; "
                               "controller measured AFTER convergence "
                               "(epoch > 0 or timeout)"},
        "rows": rows,
        "speedup": speedup,
        "migrations": reconfigs,
        "converge_s": converged_in,
        "zero_dropped": True,        # asserted: no client saw an error
        "smoke": smoke,
        "notes": [
            "Both modes precompile and warm up identically and start from "
            "the same equal_layers plan; only the controller mode runs the "
            "feedback loop (cost calibration -> calibrated DP -> epoch-"
            "fenced migration + adaptive max_batch/coalesce_s).",
            "equal_layers cuts after layers 3/7/11 — all WIDE activations "
            "— so every hop pays maximum codec; the calibrated plan cuts "
            "the narrow pinch points after layers 1/5/9 (wide/narrow x "
            "fewer bytes per hop) and gives the tail node 3x the head "
            "node's layer count.",
            "The static planner cannot find the thin cuts: its LinkModel "
            "prices wire bandwidth, not the measured per-byte codec cost "
            "that dominates the chain — the controller calibrates that "
            "rate online from BatchTrace telemetry.",
            "zero_dropped is asserted, not sampled: every closed-loop "
            "client result is awaited through the migration and any "
            "failed/unresolved future fails the run.",
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples", type=int, default=16)
    ap.add_argument("--codec", choices=sorted(CODECS), default="zfp")
    ap.add_argument("--repeats", type=int, default=2,
                    help="measured windows per mode; fastest is reported")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if async/sync < this (ISSUE 1 bar)")
    ap.add_argument("--min-staged-speedup", type=float, default=0.0,
                    help="exit nonzero if staged/async < this (ISSUE 2 bar)")
    ap.add_argument("--rebalance", action="store_true",
                    help="run the PR 3 skewed-chain controller scenario")
    ap.add_argument("--min-rebalance-speedup", type=float, default=0.0,
                    help="exit nonzero if controller/static < this "
                         "(ISSUE 3 bar)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny raw-codec config (seconds): plumbing gate "
                         "for CI, including one live reconfiguration")
    args = ap.parse_args()

    if args.smoke:
        # small model, 2 nodes, raw codec: exercises admission, staging,
        # batch wire framing, the controller step, and a live repartition
        rows = run(nodes=2, clients=2, samples=3, codec="raw", repeats=1,
                   depth=6, d=64, seq=16)
        emit("serve_load_smoke", rows)
        res = run_rebalance(nodes=2, clients=2, samples=3, codec="raw",
                            repeats=1, d=64, wide=128, narrow=16, seq=16,
                            converge_s=10.0, smoke=True)
        assert res["zero_dropped"]
        # a live repartition MUST have happened (controller-decided or the
        # forced smoke fence) and lost nothing — this is the plumbing the
        # CI gate exists to catch
        assert res["rows"][1]["epoch"] >= 1, res["rows"][1]
        print(f"smoke ok: staged {rows[-1]['throughput_rps']:.1f} req/s, "
              f"rebalance epoch {res['rows'][1]['epoch']}, "
              f"controller {res['rows'][1]['throughput_rps']:.1f} req/s")
        return

    if args.rebalance:
        res = run_rebalance(args.nodes, args.clients, args.samples,
                            args.codec, args.repeats)
        res = {"benchmark": "benchmarks/serve_load.py --rebalance",
               "date": time.strftime("%Y-%m-%d"),
               "host": f"{os.cpu_count()}-core CPU container, "
                       f"jax {jax.__version__} cpu, XLA intra_op=1, "
                       "cpu async dispatch off",
               "acceptance": {
                   "bar": "controller >= 1.3x static equal_layers on the "
                          "skewed chain (ZFP/LZ4, 4 nodes x 8 clients), "
                          "zero in-flight requests dropped by the hot "
                          "repartition",
                   "result": f"{'PASS' if res['speedup'] >= 1.3 else 'FAIL'}"
                             f" at {res['speedup']:.2f}x, zero_dropped="
                             f"{res['zero_dropped']}",
               },
               **res}
        with open("BENCH_rebalance.json", "w") as f:
            json.dump(res, f, indent=2, default=str)
        print(f"controller/static speedup: {res['speedup']:.2f}x "
              f"(epoch {res['rows'][1]['epoch']}, "
              f"cuts {res['rows'][0]['cuts']} -> {res['rows'][1]['cuts']}, "
              f"zero dropped: {res['zero_dropped']})")
        if args.min_rebalance_speedup \
                and res["speedup"] < args.min_rebalance_speedup:
            raise SystemExit(
                f"rebalance speedup {res['speedup']:.2f}x < required "
                f"{args.min_rebalance_speedup}x")
        return

    rows = run(args.nodes, args.clients, args.samples, args.codec,
               args.repeats)
    emit("serve_load", rows)
    by_mode = {r["mode"]: r for r in rows}
    s_async = by_mode["async"]["speedup_vs_sync"]
    s_staged = by_mode["staged"]["speedup_vs_async"]
    print(f"async/sync speedup:   {s_async:.2f}x "
          f"({by_mode['async']['throughput_rps']:.1f} vs "
          f"{by_mode['sync']['throughput_rps']:.1f} req/s)")
    print(f"staged/async speedup: {s_staged:.2f}x "
          f"({by_mode['staged']['throughput_rps']:.1f} vs "
          f"{by_mode['async']['throughput_rps']:.1f} req/s, "
          f"codec {by_mode['staged']['codec']})")
    if args.min_speedup and s_async < args.min_speedup:
        raise SystemExit(
            f"async speedup {s_async:.2f}x < required {args.min_speedup}x")
    if args.min_staged_speedup and s_staged < args.min_staged_speedup:
        raise SystemExit(f"staged speedup {s_staged:.2f}x < "
                         f"required {args.min_staged_speedup}x")


if __name__ == "__main__":
    main()
