"""Shared benchmark utilities: CSV emission + graph cache."""
from __future__ import annotations

import functools
import os
import sys

import jax

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def emit(name: str, rows: list[dict], keys: list[str] | None = None) -> None:
    """Print a CSV block and persist it under artifacts/bench/."""
    if not rows:
        print(f"# {name}: no rows")
        return
    keys = keys or list(rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(_fmt(r.get(k)) for k in keys))
    text = "\n".join(lines)
    print(f"# --- {name} ---")
    print(text)
    os.makedirs(os.path.join(ART, "bench"), exist_ok=True)
    with open(os.path.join(ART, "bench", f"{name}.csv"), "w") as f:
        f.write(text + "\n")


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


@functools.lru_cache(maxsize=8)
def graph_and_params(model: str, batch: int = 1):
    from repro.models import cnn
    g = cnn.BUILDERS[model](batch=batch)
    params = g.init(jax.random.PRNGKey(0))
    return g, params
