"""Fig 3: per-node energy per inference cycle, DEFER vs single device
(ResNet50, 4/6/8 nodes)."""
from __future__ import annotations

from benchmarks.common import emit, graph_and_params
from repro.core.emulator import CodecConfig, emulate


def run(nodes=(4, 6, 8)) -> list[dict]:
    g, _ = graph_and_params("resnet50")
    cfg = CodecConfig(serializer="zfp", compression="none", zfp_rate=16)
    rows = []
    for n in nodes:
        rep = emulate(g, n, cfg)
        rows.append({
            "nodes": n,
            "per_node_energy_j": rep.per_node_energy_j,
            "single_device_energy_j": rep.single_device_energy_j,
            "energy_ratio": rep.energy_ratio,
        })
    return rows


def main() -> None:
    emit("fig3_energy", run())


if __name__ == "__main__":
    main()
