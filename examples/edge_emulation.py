"""Edge emulation study: sweep models x node counts x codecs (the paper's
full evaluation grid) and print Fig-2/Fig-3-style summaries.

    PYTHONPATH=src python examples/edge_emulation.py [--quick]
"""
import argparse

from repro.core.emulator import CodecConfig, emulate
from repro.models.cnn import BUILDERS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    models = ["resnet50"] if args.quick else list(BUILDERS)
    nodes = (4, 8) if args.quick else (4, 6, 8)

    print(f"{'model':10s} {'nodes':>5s} {'cps':>8s} {'speedup':>8s} "
          f"{'E/node (J)':>11s} {'payload MB':>11s}")
    for model in models:
        g = BUILDERS[model](batch=1)
        for n in nodes:
            r = emulate(g, n, CodecConfig("zfp", "none", 16))
            print(f"{model:10s} {n:5d} {r.throughput_cps:8.2f} "
                  f"{r.speedup:8.2f} {r.per_node_energy_j:11.3f} "
                  f"{r.total_payload_mb:11.2f}")
        print(f"{model:10s} {1:5d} {r.single_device_cps:8.2f} "
              f"{1.0:8.2f} {r.single_device_energy_j:11.3f} {0.0:11.2f}")

    print("\ncodec study (ResNet50, 4 nodes):")
    for ser, comp in [("json", "none"), ("json", "lz4"), ("zfp", "none"),
                      ("zfp", "lz4")]:
        r = emulate(g if args.quick else BUILDERS["resnet50"](batch=1), 4,
                    CodecConfig(ser, comp, 16))
        print(f"  {r.codec:18s} cps={r.throughput_cps:6.3f} "
              f"payload={r.total_payload_mb:7.2f} MB "
              f"overhead={r.overhead_s*1e3:7.1f} ms")


if __name__ == "__main__":
    main()
