"""Beyond-paper: autoregressive generation THROUGH the DEFER pipeline.

The sampled token ppermutes from the last stage straight back to stage 0 on
the same ring that relays hidden states — no dispatcher round-trip.  With
M >= S microbatches in flight every stage is busy every tick (the paper's
FIFO law applied to decode).  Token-exact vs single-device greedy decode.

    PYTHONPATH=src python examples/pipeline_decode.py --arch phi3-mini-3.8b
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_smoke
from repro.launch.mesh import make_mesh_compat
from repro.launch.serve import build_pipeline_decoder
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="phi3-mini-3.8b")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=6)
    ap.add_argument("--mb", type=int, default=2)
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = T.init_lm(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh_compat((args.stages,), ("stage",))
    M, mb, steps = args.microbatches, args.mb, args.steps
    start = jax.random.randint(jax.random.PRNGKey(1), (M, mb, 1), 0,
                               cfg.vocab)
    start_pos = jnp.zeros((M, mb), jnp.int32)
    fn, sw, caches0, head = build_pipeline_decoder(
        cfg, params, mesh, args.stages, M, mb, steps + 8, steps)
    with mesh:
        jfn = jax.jit(fn)
        toks, _ = jfn(sw, caches0, start, start_pos, head)
        toks.block_until_ready()
        t0 = time.perf_counter()
        toks, _ = jfn(sw, caches0, start, start_pos, head)
        toks.block_until_ready()
        dt = time.perf_counter() - t0

    # verify against single-device greedy
    mismatches = 0
    for m in range(M):
        caches = T.init_caches(cfg, mb, steps + 8, jnp.float32)
        tok = start[m]
        for p in range(steps):
            lg, caches = T.decode_step(params, cfg, tok,
                                       jnp.full((mb,), p, jnp.int32), caches)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            mismatches += int((toks[m, p] != tok[:, 0]).sum())

    n_tok = M * mb * steps
    ticks = M * steps + args.stages - 1
    print(f"{args.arch}: generated {n_tok} tokens through a "
          f"{args.stages}-stage ring in {ticks} ticks ({dt*1e3:.0f} ms)")
    print(f"token-exact vs single-device greedy: "
          f"{mismatches == 0} ({mismatches} mismatches)")
    print(f"pipeline utilisation: {M * steps / ticks:.1%} "
          f"(bubble only at fill/drain)")


if __name__ == "__main__":
    main()
