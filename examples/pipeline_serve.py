"""TPU-path serving: the DEFER chain as shard_map pipeline parallelism,
with and without the int8 wire codec (the ZFP adaptation).

Runs a smoke-size model over 4 emulated devices in a fresh process:

    PYTHONPATH=src python examples/pipeline_serve.py --arch gemma3-4b
"""
import os

if "--_child" not in os.sys.argv and "XLA_FLAGS" not in os.environ:
    # re-exec with 4 emulated devices before jax initializes
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_smoke
from repro.launch.mesh import make_mesh_compat
from repro.launch.serve import build_pipeline_lm, wire_bytes_per_relay
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="gemma3-4b")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    S = args.stages
    if jax.device_count() < S:
        raise SystemExit("need XLA_FLAGS=--xla_force_host_platform_device_count>=4")
    mesh = make_mesh_compat((S,), ("stage",))
    params = T.init_lm(cfg, jax.random.PRNGKey(0))
    B = args.microbatches * 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, args.seq), 0,
                                cfg.vocab)
    kw = {}
    if cfg.num_prefix_embeds and not cfg.encoder_layers:
        kw["prefix_embeds"] = jnp.zeros((B, cfg.num_prefix_embeds, cfg.d_model))
    if cfg.encoder_layers:
        kw["encoder_embeds"] = jnp.zeros((B, cfg.num_prefix_embeds, cfg.d_model))

    ref, _ = T.forward(params, cfg, tokens, **kw)
    for compress in (False, True):
        lm = build_pipeline_lm(cfg, params, mesh, S, args.microbatches,
                               compress=compress)
        with mesh:
            f = jax.jit(lambda t: lm(t, **kw))
            out = f(tokens)
            out.block_until_ready()
            t0 = time.perf_counter()
            out = f(tokens)
            out.block_until_ready()
            dt = time.perf_counter() - t0
        err = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
        wire = wire_bytes_per_relay(cfg, B // args.microbatches, args.seq,
                                    compress)
        print(f"compress={compress!s:5s} wall={dt*1e3:7.1f} ms "
              f"relay={wire/1e3:8.1f} kB/hop rel_err={err:.4f}")
    print(f"\n{args.arch}: {S}-stage pipeline == single-device forward "
          f"(uncompressed err must be ~0)")


if __name__ == "__main__":
    main()
