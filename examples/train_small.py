"""End-to-end driver (deliverable b): train a ~100M-param dense model for a
few hundred steps on the synthetic pipeline, with checkpoints and resume.

    PYTHONPATH=src python examples/train_small.py [--steps 300] [--dim 768]
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_lm_iter
from repro.train import checkpoint as ckpt
from repro.train.loop import train
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=384,
                    help="768 gives ~100M params (slower on 1 CPU core)")
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = ModelConfig(
        name=f"dense-{args.dim}", family="dense", num_layers=args.layers,
        d_model=args.dim, num_heads=args.dim // 64, kv_heads=args.dim // 128,
        d_ff=4 * args.dim, vocab=args.vocab, gated_mlp=True, remat=False,
        source="example")
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({args.layers}L x d{args.dim})")

    it = make_lm_iter(cfg, args.batch, args.seq, seed=0)
    opt = OptConfig(lr=2e-3, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps)

    def log(m):
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}  "
              f"{m['wall_s']:.0f}s")

    params, _, hist = train(cfg, opt, it, num_steps=args.steps,
                            log_every=20, callback=log)
    if args.ckpt_dir:
        out = ckpt.save(args.ckpt_dir, args.steps, params)
        print(f"checkpoint -> {out}")
    drop = hist[0]["loss"] - hist[-1]["loss"]
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({drop:.2f} nats learned)")
    assert drop > 1.0, "training must visibly learn the synthetic structure"


if __name__ == "__main__":
    main()
