"""Async serving: many clients share one DEFER chain concurrently.

The seed's engine pushed one synchronous stream through the chain; this
example runs the continuous-batching runtime the way a front-end would —
concurrent clients calling ``submit()``/``stream()``, a bounded admission
queue shedding load, and the report showing per-node utilization, batch
occupancy, and p50/p99 latency (the serving view of the paper's
``1/max_i service_i`` throughput law).

    PYTHONPATH=src python examples/async_serve.py
"""
import threading

import jax
import numpy as np

from repro.models import cnn
from repro.runtime import AdmissionFull, InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

NODES, CLIENTS, PER_CLIENT = 4, 6, 4

graph = cnn.resnet50(batch=1, image=64, num_classes=10)
params = graph.init(jax.random.PRNGKey(0))
engine = InferenceEngine(
    graph, NODES,
    DispatcherCodecs(data=WireCodec("zfp", "none", zfp_rate=16),
                     weights=WireCodec("raw", "none")),
    max_batch=4, admission_depth=32)
engine.configure(params)
engine.start()


def client(c: int, out: dict) -> None:
    xs = [np.random.default_rng(100 * c + i)
          .normal(size=(1, 64, 64, 3)).astype(np.float32)
          for i in range(PER_CLIENT)]
    try:
        # stream() admits eagerly and yields THIS client's results FIFO;
        # the admission timeout turns sustained overload into AdmissionFull
        out[c] = [int(np.argmax(y))
                  for y in engine.stream(xs, client_id=c, timeout=60.0)]
    except AdmissionFull:
        out[c] = "shed"       # a real front-end would retry with backoff


results: dict = {}
threads = [threading.Thread(target=client, args=(c, results))
           for c in range(CLIENTS)]
for t in threads:
    t.start()
for t in threads:
    t.join()

report = engine.report()
engine.shutdown()

for c in sorted(results):
    print(f"client {c}: classes {results[c]}")
print(f"\n{report.samples} requests over {NODES} nodes: "
      f"{report.throughput_cps:.1f} req/s, "
      f"p50 {report.p50_latency_s*1e3:.0f} ms, "
      f"p99 {report.p99_latency_s*1e3:.0f} ms")
for pn in report.per_node:
    print(f"  node {pn['node']}: "
          f"util dec/cmp/enc {pn['util_decode']*100:4.1f}/"
          f"{pn['util_compute']*100:4.1f}/{pn['util_encode']*100:4.1f}%  "
          f"mean batch {pn['batch_mean']:.2f}  "
          f"queue depth max {pn['queue_depth_max']}  "
          f"service {pn['service_s']*1e3:.2f} ms")
