"""Topology-first async serving: spec -> engine -> scale.

The serving API is declarative: a :class:`TopologySpec` says what the
chain IS — an ordered list of stages, each binding a contiguous layer
range to a replica count, a routing policy (round-robin /
least-queue-depth), a transport, and optional batching-knob overrides —
and the engine builds exactly that.  Many clients then share the topology
concurrently via ``submit()``/``stream()``, with a bounded admission
queue shedding load and a sequence-numbered merge keeping every client's
responses in its own submission order no matter how replicas reorder
batches in flight.

The walkthrough below:

1. **spec** — plan a 4-stage chain with the partitioner, then give the
   heaviest stage 2 replicas up front;
2. **engine** — configure (weights ship over the wire to every replica)
   and serve a burst of concurrent clients;
3. **scale** — grow the bottleneck stage to 3 replicas and drain it back
   to 1 on the RUNNING engine.  Both ride the epoch fence: spawned
   replicas receive the stage's weights and are fenced into the routing
   set; drained replicas are fenced out, flush their in-flight work, and
   retire.  Zero requests are dropped or reordered.

Controller knobs (the serving-time feedback loop)
-------------------------------------------------
Passing ``controller=ControllerConfig(...)`` turns the static topology
into a self-optimizing one.  Three independently gateable arms:

* ``repartition=True`` — every ``interval_s`` the controller folds the
  stages' measured per-stage timings into an EWMA cost model
  (``ewma_alpha``), re-runs the partition DP on those *calibrated* costs
  priced for the live replica counts, and — only when the predicted
  bottleneck improves by more than ``hysteresis`` — hot-migrates the
  cuts behind the same epoch fence.
* ``adapt_knobs=True`` — per stage, the measured codec/compute
  stage-time ratio retunes ``coalesce_s`` within ``coalesce_bounds`` and
  ``max_batch`` within [1, ``max_batch_cap``], uniformly across replicas.
* ``replica_scaling=True`` — when the calibrated DP says cuts CANNOT fix
  the bottleneck, the controller recommends a replica change for it
  (``scale_recommend`` actions); with ``execute_scaling=True`` it commits
  the change itself via the same ``scale()`` path demonstrated below.

Per-request QoS rides the same admission queue: ``submit(..., priority=p)``
weights the dequeue (band weight ``p + 1``, no starvation), and
``client_quota=n`` caps any one client's in-flight requests.

    PYTHONPATH=src python examples/async_serve.py
"""
import threading

import jax
import numpy as np

from repro.models import cnn
from repro.runtime import (AdmissionFull, ControllerConfig, InferenceEngine,
                           TopologySpec)
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

STAGES, CLIENTS, PER_CLIENT = 4, 6, 4

graph = cnn.resnet50(batch=1, image=64, num_classes=10)
params = graph.init(jax.random.PRNGKey(0))

# 1. spec: the partitioner picks the cuts; the heaviest stage starts with
#    2 replicas (a hand-built spec could instead list explicit StageSpecs
#    with per-stage layer ranges, transports, and knob overrides).
#    transport="tcp" would put every hop on real loopback sockets, and
#    transport="link:10mbit,20ms" on the paper's emulated Ethernet — the
#    serving code below is identical either way
spec = TopologySpec.chain(graph, STAGES, strategy="balanced_latency")
heavy = max(range(STAGES),
            key=lambda i: spec.stages[i].layers[1] - spec.stages[i].layers[0])
spec = spec.with_replicas(heavy, 2)
print("topology:", " | ".join(
    f"stage {i}: layers {s.layers} x{s.replicas}"
    for i, s in enumerate(spec.stages)))

# 2. engine: build the declared topology and serve
engine = InferenceEngine(
    graph, spec,
    DispatcherCodecs(data=WireCodec("zfp", "none", zfp_rate=16),
                     weights=WireCodec("raw", "none")),
    max_batch=4, admission_depth=32,
    client_quota=2 * PER_CLIENT,           # no client monopolizes admission
    # close the measurement->plan loop.  min_requests is set above this
    # short demo's traffic so the run shows calibration + knob adaptation
    # without paying a live resnet migration (minutes of XLA recompiles on
    # a laptop CPU); benchmarks/serve_load.py --rebalance and --elastic
    # demonstrate the hot repartition and live replica scaling end to end
    # on serving-scale chains
    controller=ControllerConfig(
        interval_s=0.5, hysteresis=0.15, cooldown_s=5.0,
        min_requests=2 * CLIENTS * PER_CLIENT,
        replica_scaling=True))             # recommend-only (no execute)
engine.configure(params)
engine.start()


def client(c: int, out: dict) -> None:
    xs = [np.random.default_rng(100 * c + i)
          .normal(size=(1, 64, 64, 3)).astype(np.float32)
          for i in range(PER_CLIENT)]
    try:
        # stream() admits eagerly and yields THIS client's results FIFO —
        # the sequenced merge guarantees it even across the replicated
        # stage; the admission timeout turns overload into AdmissionFull
        out[c] = [int(np.argmax(y))
                  for y in engine.submit_stream(xs, client_id=c, timeout=60.0)]
    except AdmissionFull:
        out[c] = "shed"       # a real front-end would retry with backoff


results: dict = {}
threads = [threading.Thread(target=client, args=(c, results))
           for c in range(CLIENTS)]
for t in threads:
    t.start()
for t in threads:
    t.join()

# 3. scale: membership is live.  Grow the bottleneck stage, serve one more
#    client burst through the wider topology, then drain it back — the
#    epoch fence means no request in flight is dropped either way.
rec_up = engine.scale(heavy, 3)
print(f"scale stage {heavy} -> 3 replicas: spawned {rec_up['spawned']}, "
      f"{rec_up['shipped_bytes'] / 1e6:.1f} MB of weights shipped, "
      f"acked={rec_up['acknowledged']}")
more: dict = {}
threads = [threading.Thread(target=client, args=(c, more))
           for c in range(CLIENTS)]
for t in threads:
    t.start()
for t in threads:
    t.join()
rec_down = engine.scale(heavy, 1)
print(f"drain stage {heavy} -> 1 replica: retired {rec_down['retired']}, "
      f"acked={rec_down['acknowledged']}")

report = engine.report()
controller_log = list(engine.controller.actions)
engine.shutdown()

for c in sorted(results):
    print(f"client {c}: classes {results[c]} then {more.get(c)}")
print(f"\n{report.samples} requests over {report.num_nodes} replicas "
      f"({'x'.join(map(str, report.replicas))} per stage): "
      f"{report.throughput_cps:.1f} req/s, "
      f"p50 {report.p50_latency_s*1e3:.0f} ms, "
      f"p99 {report.p99_latency_s*1e3:.0f} ms")
for pn in report.per_node:
    print(f"  stage {pn['stage']} replica {pn['replica']}: "
          f"util dec/cmp/enc {pn['util_decode']*100:4.1f}/"
          f"{pn['util_compute']*100:4.1f}/{pn['util_encode']*100:4.1f}%  "
          f"mean batch {pn['batch_mean']:.2f}  "
          f"service {pn['service_s']*1e3:.2f} ms  "
          f"knobs mb={pn['max_batch']} co={pn['coalesce_s']*1e3:.1f}ms")
print(f"partition epoch {report.epoch}, cuts {report.cuts}; "
      f"controller decided: "
      f"{[a.kind for a in controller_log] or '(no full period elapsed)'}")
