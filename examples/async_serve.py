"""Async serving: many clients share one DEFER chain concurrently.

The seed's engine pushed one synchronous stream through the chain; this
example runs the continuous-batching runtime the way a front-end would —
concurrent clients calling ``submit()``/``stream()``, a bounded admission
queue shedding load, and the report showing per-node utilization, batch
occupancy, and p50/p99 latency (the serving view of the paper's
``1/max_i service_i`` throughput law).

Controller knobs (the serving-time feedback loop)
-------------------------------------------------
Passing ``controller=ControllerConfig(...)`` turns the static chain into a
self-optimizing one.  The loop has two arms, each independently gateable:

* ``repartition=True`` — every ``interval_s`` the controller folds the
  nodes' measured per-stage timings into an EWMA cost model
  (``ewma_alpha``), re-runs the partition DP on those *calibrated* costs,
  and — only when the predicted bottleneck improves by more than
  ``hysteresis`` (the anti-thrash deadband) — hot-migrates the cuts: the
  shifted layers' weights ship to the affected neighbors and an epoch
  marker fences the swap on the wire, so zero in-flight requests are
  dropped.  ``min_requests`` gates decisions on window size,
  ``cooldown_s`` spaces migrations, and ``window`` (layers) caps how far
  one migration may move a cut (bounding the weight bytes shipped).
* ``adapt_knobs=True`` — per node, the measured codec/compute stage-time
  ratio retunes ``coalesce_s`` within ``coalesce_bounds`` (codec-bound
  nodes grow the ingress coalescing window to amortize codec passes;
  compute-bound nodes shrink it for latency) and ``max_batch`` within
  [1, ``max_batch_cap``] (precompiled pow2 shapes stay authoritative).

Per-request QoS rides the same admission queue: ``submit(..., priority=p)``
weights the dequeue (band weight ``p + 1``, no starvation), and
``client_quota=n`` caps any one client's in-flight requests.

    PYTHONPATH=src python examples/async_serve.py
"""
import threading

import jax
import numpy as np

from repro.models import cnn
from repro.runtime import (AdmissionFull, ControllerConfig, InferenceEngine)
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

NODES, CLIENTS, PER_CLIENT = 4, 6, 4

graph = cnn.resnet50(batch=1, image=64, num_classes=10)
params = graph.init(jax.random.PRNGKey(0))
engine = InferenceEngine(
    graph, NODES,
    DispatcherCodecs(data=WireCodec("zfp", "none", zfp_rate=16),
                     weights=WireCodec("raw", "none")),
    max_batch=4, admission_depth=32,
    client_quota=2 * PER_CLIENT,           # no client monopolizes admission
    # close the measurement->plan loop.  min_requests is set above this
    # short demo's traffic so the run shows calibration + knob adaptation
    # without paying a live resnet migration (minutes of XLA recompiles on
    # a laptop CPU); benchmarks/serve_load.py --rebalance demonstrates the
    # hot repartition end to end on a serving-scale chain
    controller=ControllerConfig(
        interval_s=0.5, hysteresis=0.15, cooldown_s=5.0,
        min_requests=2 * CLIENTS * PER_CLIENT))
engine.configure(params)
engine.start()


def client(c: int, out: dict) -> None:
    xs = [np.random.default_rng(100 * c + i)
          .normal(size=(1, 64, 64, 3)).astype(np.float32)
          for i in range(PER_CLIENT)]
    try:
        # stream() admits eagerly and yields THIS client's results FIFO;
        # the admission timeout turns sustained overload into AdmissionFull
        out[c] = [int(np.argmax(y))
                  for y in engine.stream(xs, client_id=c, timeout=60.0)]
    except AdmissionFull:
        out[c] = "shed"       # a real front-end would retry with backoff


results: dict = {}
threads = [threading.Thread(target=client, args=(c, results))
           for c in range(CLIENTS)]
for t in threads:
    t.start()
for t in threads:
    t.join()

report = engine.report()
controller_log = list(engine.controller.actions)
engine.shutdown()

for c in sorted(results):
    print(f"client {c}: classes {results[c]}")
print(f"\n{report.samples} requests over {NODES} nodes: "
      f"{report.throughput_cps:.1f} req/s, "
      f"p50 {report.p50_latency_s*1e3:.0f} ms, "
      f"p99 {report.p99_latency_s*1e3:.0f} ms")
for pn in report.per_node:
    print(f"  node {pn['node']}: "
          f"util dec/cmp/enc {pn['util_decode']*100:4.1f}/"
          f"{pn['util_compute']*100:4.1f}/{pn['util_encode']*100:4.1f}%  "
          f"mean batch {pn['batch_mean']:.2f}  "
          f"queue depth max {pn['queue_depth_max']}  "
          f"service {pn['service_s']*1e3:.2f} ms  "
          f"knobs mb={pn['max_batch']} co={pn['coalesce_s']*1e3:.1f}ms")
print(f"partition epoch {report.epoch}, cuts {report.cuts}; "
      f"controller decided: "
      f"{[a.kind for a in controller_log] or '(no full period elapsed)'}")
