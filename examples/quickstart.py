"""Quickstart: partition a model, run the emulated DEFER chain, and compare
against single-device inference — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emulator import CodecConfig, emulate
from repro.core.partitioner import partition
from repro.models.cnn import resnet50
from repro.runtime import InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

# 1. the model as a layer graph (what the Keras DAG is to the paper)
graph = resnet50(batch=1)
print(f"{graph.name}: {len(graph)} layers, "
      f"{graph.total_param_bytes/1e6:.0f} MB params, "
      f"{graph.total_flops/1e9:.1f} GFLOPs")

# 2. plan a 4-node partition (the dispatcher's job)
plan = partition(graph, 4, strategy="balanced_latency")
for i, st in enumerate(plan.stages):
    print(f"  node {i}: layers [{st.start}:{st.stop})  "
          f"{st.flops/1e9:.2f} GFLOPs  ->{st.out_bytes/1e6:.2f} MB")

# 3. run REAL distributed inference over the in-process chain
params = graph.init(jax.random.PRNGKey(0))
engine = InferenceEngine(graph, 4, DispatcherCodecs(
    data=WireCodec("zfp", "none", zfp_rate=16)))
engine.configure(params)
xs = [np.random.default_rng(i).normal(size=(1, 224, 224, 3))
      .astype(np.float32) for i in range(4)]
outs, report = engine.run(xs)
engine.shutdown()

single = np.asarray(graph.apply(params, jnp.asarray(xs[0])))
agree = np.argmax(outs[0]) == np.argmax(single)
print(f"\nchain output agrees with single device: {agree}")
print(f"measured throughput  {report.throughput_cps:.2f} cycles/s "
      f"(modeled steady-state {report.modeled_throughput_cps:.2f})")
print(f"payload/cycle {report.payload_mb:.2f} MB, "
      f"codec overhead {report.overhead_s*1e3:.1f} ms")

# 4. the analytic emulator (the CORE-network study): 1 vs 8 nodes
base = emulate(graph, 8, CodecConfig("zfp", "none", 16))
print(f"\n8-node emulated: {base.throughput_cps:.2f} cps vs single "
      f"{base.single_device_cps:.2f} cps -> speedup {base.speedup:.2f}x; "
      f"per-node energy ratio {base.energy_ratio:.2f}")
