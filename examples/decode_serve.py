"""Autoregressive decode serving: sessions, resident KV, token streaming.

One-shot inference ships a whole input through the chain per request.
Autoregressive decode is different: a *session* prefills its prompt ONCE
(``kind=open`` frame, the full ``[1, S]`` token sequence), every
attention layer's KV cache stays RESIDENT on the replica that computed
it, and from then on each step ships only the NEWEST token per hop
(``kind=step``, ``[1, 1]`` plus a sequence position) — the per-hop
payload is O(d_model), no matter how long the sequence grows.  Tokens
stream back from the tail as they are produced:

    for tok in engine.generate(prompt, max_new_tokens=32):
        ...

Residency makes replicas stateful, and the runtime pays for that
honestly:

* **stickiness** — stage routers pin a session to the replica holding
  its cache; opens pick a replica by the stage's routing policy, steps
  follow the pin, closes evict it.
* **elasticity** — ``scale()`` and ``reconfigure()`` still work DURING
  active generation.  A drained/repartitioned replica's sessions are
  flagged displaced at the epoch fence; the generate loop (which retains
  the full token history client-side) transparently re-opens them — one
  re-prefill — on whatever replicas the routers pick next.  Greedy
  decode is deterministic, so the recovered session's tokens are
  bit-identical to an undisturbed run.
* **loss** — if a replica dies (or LRU capacity evicts a cache) and
  ``restart`` forbids recovery, the iterator raises ``SessionLost``
  (``retryable=False``); with ``restart='always'`` (or ``'auto'`` plus a
  ``RetryPolicy``) it re-prefills instead.

The walkthrough: build a small decode-capable transformer, serve it as a
2-stage chain, stream several concurrent sessions (each at a DIFFERENT
sequence position — the stages batch their steps anyway), scale a stage
mid-generation, and check every token against the single-device
reference.

    PYTHONPATH=src python examples/decode_serve.py
"""
import threading

import jax
import numpy as np

from repro.models.lm_graph import decode_lm_graph, pipeline_decode_reference
from repro.runtime import InferenceEngine, TopologySpec
from repro.runtime.dispatcher import DispatcherCodecs, RetryPolicy
from repro.runtime.wire import WireCodec

# -- 1. a decode-capable graph ------------------------------------------------
# Each attention layer declares a LayerDecode (prefill_fn + step_fn) next
# to its full-sequence fn; `decode_cache_len` bounds prompt + new tokens.
g = decode_lm_graph(vocab=64, d_model=32, n_layers=2, num_heads=2,
                    kv_heads=2, head_dim=16, d_ff=64, cache_len=64)
params = g.init(jax.random.PRNGKey(0))

# -- 2. a 2-stage chain, lossless data path -----------------------------------
# raw+lz4 keeps greedy decode bit-identical across hops; small_bypass
# ships the few-hundred-byte token frames as raw .npy, skipping LZ4
# setup cost (see benchmarks/codec_microbench.py for the win).
codecs = DispatcherCodecs(data=WireCodec("raw", "lz4", small_bypass=4096),
                          weights=WireCodec("raw", "none"))
topo = TopologySpec.chain(g, 2).with_replicas(0, 2)
eng = InferenceEngine(g, topo, codecs, max_batch=4,
                      retry_policy=RetryPolicy(max_attempts=4,
                                               retry_budget=64.0))
eng.configure(params)
eng.start()

prompts = [[1, 5, 9, 2], [3, 3, 7], [2, 8, 4, 6, 1], [11, 0, 5, 5]]
m = 16

# -- 3. concurrent sessions, tokens streamed from the tail --------------------
outs = [[] for _ in prompts]


def session(i: int, prompt: list[int]) -> None:
    # restart='auto' + the engine's RetryPolicy => lost residency is
    # recovered by re-prefilling the retained history
    for tok in eng.generate(prompt, m):
        outs[i].append(tok)


threads = [threading.Thread(target=session, args=(i, p))
           for i, p in enumerate(prompts)]
for t in threads:
    t.start()

# -- 4. elasticity mid-generation ---------------------------------------------
# Drain one stage-0 replica while all four sessions are live: its pinned
# sessions are displaced at the fence and re-prefill onto the survivor.
while not all(len(o) >= 2 for o in outs):
    pass
eng.scale(0, 1)
for t in threads:
    t.join()

# -- 5. bit-identity against the single-device reference ----------------------
for p, out in zip(prompts, outs):
    ref = pipeline_decode_reference(g, params, p, m)
    assert out == ref, (out, ref)
print("four sessions decoded through a live scale(), all bit-identical:")
for p, out in zip(prompts, outs):
    print(f"  prompt {p} -> {out}")

x = np.asarray([prompts[0]], np.int32)
np.testing.assert_allclose(eng.submit(x).result(timeout=60),
                           np.asarray(g.apply(params, x)), atol=1e-4)
print("single-shot traffic still serves on the same chain")
eng.shutdown()
