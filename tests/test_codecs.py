"""Wire codec tests: JSON/LZ4 byte-exact, ZFP fixed-rate error bound.

LZ4 round-trip is property-tested with hypothesis over arbitrary byte
strings (the invariant DEFER's weights socket depends on).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # network-less CI image: degrade to fixed examples
    from _hypothesis_compat import given, settings, st

from repro.core import codecs

RNG = np.random.default_rng(0)


# -- JSON -------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_json_roundtrip_exact(dtype):
    arr = (RNG.normal(size=(9, 13)) * 100).astype(dtype)
    c = codecs.JsonCodec()
    np.testing.assert_array_equal(c.decode(c.encode(arr)), arr)


# -- LZ4 -------------------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(st.binary(min_size=0, max_size=4096))
def test_lz4_roundtrip_arbitrary_bytes(data):
    lz = codecs.Lz4Codec()
    assert lz.decompress(lz.compress(data)) == data


@settings(max_examples=30, deadline=None)
@given(st.binary(min_size=1, max_size=64), st.integers(2, 200))
def test_lz4_compresses_repetition(chunk, reps):
    lz = codecs.Lz4Codec()
    data = chunk * reps
    out = lz.compress(data)
    assert lz.decompress(out) == data
    if len(data) > 256:
        assert len(out) < len(data)            # repetitive data must shrink


def test_lz4_overlapping_match():
    # RLE-style overlap (offset < match length) exercises byte-wise copy
    data = b"a" * 1000 + b"bc" + b"a" * 7
    lz = codecs.Lz4Codec()
    assert lz.decompress(lz.compress(data)) == data


@settings(max_examples=100, deadline=None)
@given(st.binary(min_size=0, max_size=4096))
def test_lz4_vectorized_byte_exact_property(data):
    """The vectorized compressor must emit byte-identical streams to the
    pure-Python greedy reference for arbitrary inputs."""
    vec, ref = codecs.Lz4Codec(), codecs.Lz4Codec(vectorized=False)
    assert vec.compress(data) == ref.compress(data)


def test_lz4_vectorized_byte_exact_payloads():
    """Byte-exactness on random + structured payloads shaped like the wire
    actually carries (raw bytes, zfp streams, tiled, text, zeros)."""
    payloads = [
        b"",
        b"abc",
        bytes(RNG.integers(0, 256, 65536).astype(np.uint8)),      # random
        b"the quick brown fox jumps over the lazy dog " * 500,    # text
        np.zeros(5000, np.uint8).tobytes(),                       # zeros
        bytes(range(256)) * 40,                                   # tiled
        codecs.ZfpCodec(rate=16).encode(                          # zfp wire
            RNG.normal(size=(64, 128)).astype(np.float32)),
        codecs.ZfpCodec(rate=8).encode(
            RNG.normal(size=(64, 128)).astype(np.float32)),
    ]
    vec, ref = codecs.Lz4Codec(), codecs.Lz4Codec(vectorized=False)
    for data in payloads:
        out = vec.compress(data)
        assert out == ref.compress(data)
        assert vec.decompress(out) == data
        assert ref.decompress(out) == data


# -- ZFP ------------------------------------------------------------------------

@pytest.mark.parametrize("rate", [8, 12, 16, 24])
@pytest.mark.parametrize("transform", [True, False])
def test_zfp_error_bound(rate, transform):
    z = codecs.ZfpCodec(rate=rate, transform=transform)
    arr = RNG.normal(size=(33, 57)).astype(np.float32) * 50
    back = z.decode(z.encode(arr))
    bound = z.error_bound(float(np.abs(arr).max()))
    assert np.abs(back - arr).max() <= bound


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 500), st.floats(1e-3, 1e3))
def test_zfp_roundtrip_any_length_and_scale(n, scale):
    z = codecs.ZfpCodec(rate=16)
    arr = (RNG.normal(size=n) * scale).astype(np.float32)
    back = z.decode(z.encode(arr))
    assert back.shape == arr.shape
    assert np.abs(back - arr).max() <= z.error_bound(float(np.abs(arr).max()))


def test_zfp_rate_controls_payload():
    arr = RNG.normal(size=(256, 256)).astype(np.float32)
    sizes = [len(codecs.ZfpCodec(rate=r).encode(arr)) for r in (8, 16)]
    assert sizes[0] < sizes[1] < arr.nbytes


def test_zfp_preserves_dtype_and_shape():
    arr = RNG.normal(size=(4, 5, 6)).astype(np.float32)
    z = codecs.ZfpCodec(rate=16)
    back = z.decode(z.encode(arr))
    assert back.shape == arr.shape and back.dtype == arr.dtype


def test_zfp_vectorized_byte_exact():
    """The batched (4,4,B)-layout lift must reproduce the per-axis
    reference bit-for-bit, encode and decode."""
    arr = RNG.normal(size=(37, 53)).astype(np.float32) * 3
    for rate in (8, 14, 24):
        vec = codecs.ZfpCodec(rate=rate)
        ref = codecs.ZfpCodec(rate=rate, vectorized=False)
        blob = vec.encode(arr)
        assert blob == ref.encode(arr)
        np.testing.assert_array_equal(vec.decode(blob), ref.decode(blob))


def test_zfp_lift_near_invertible():
    """zfp's integer lift drops a few low bits by design (they sit below the
    coded precision); round-trip error must stay within the handful of LSBs
    that ``error_bound`` budgets for."""
    from repro.core.codecs import _fwd_lift, _inv_lift
    q = RNG.integers(-2**28, 2**28, size=(10, 4, 4)).astype(np.int64)
    out = _inv_lift(_inv_lift(_fwd_lift(_fwd_lift(q, 1), 2), 2), 1)
    assert np.abs(out - q).max() <= 16


# -- composition (what the emulator charges) ------------------------------------

@pytest.mark.parametrize("ser,comp", [("json", "none"), ("json", "lz4"),
                                      ("zfp", "none"), ("zfp", "lz4")])
def test_roundtrip_all_configurations(ser, comp):
    arr = np.maximum(RNG.normal(size=4096).astype(np.float32), 0)
    back, stats = codecs.roundtrip(arr, ser, comp, zfp_rate=16)
    assert stats.wire_bytes > 0 and stats.encode_s >= 0
    if ser == "json":
        np.testing.assert_array_equal(back, arr)
    else:
        bound = codecs.ZfpCodec(rate=16).error_bound(float(np.abs(arr).max()))
        assert np.abs(back - arr).max() <= bound
