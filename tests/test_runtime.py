"""DEFER edge runtime: chain == single device, FIFO order, config step,
codec configurations (integration tests over the real threaded chain)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import cnn
from repro.runtime import InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec


@pytest.fixture(scope="module")
def small_graph_and_params():
    g = cnn.resnet50(batch=1, image=64, num_classes=10)
    params = g.init(jax.random.PRNGKey(0))
    return g, params


def _inputs(n, image=64):
    rng = np.random.default_rng(0)
    return [rng.normal(size=(1, image, image, 3)).astype(np.float32)
            for _ in range(n)]


def test_chain_matches_single_device_exact(small_graph_and_params):
    g, params = small_graph_and_params
    xs = _inputs(3)
    ref = [np.asarray(g.apply(params, jnp.asarray(x))) for x in xs]
    eng = InferenceEngine(g, 4, DispatcherCodecs(
        data=WireCodec("raw", "none"), weights=WireCodec("raw", "none")))
    eng.configure(params)
    outs, rep = eng.run(xs)
    eng.shutdown()
    for o, r in zip(outs, ref):
        np.testing.assert_allclose(o, r, atol=1e-5)
    assert rep.samples == 3 and rep.num_nodes == 4
    assert rep.throughput_cps > 0 and rep.payload_mb > 0


def test_chain_zfp_error_bounded(small_graph_and_params):
    g, params = small_graph_and_params
    xs = _inputs(2)
    ref = [np.asarray(g.apply(params, jnp.asarray(x))) for x in xs]
    eng = InferenceEngine(g, 3, DispatcherCodecs(
        data=WireCodec("zfp", "lz4", zfp_rate=16),
        weights=WireCodec("raw", "none")))
    eng.configure(params)
    outs, rep = eng.run(xs)
    eng.shutdown()
    for o, r in zip(outs, ref):
        rel = np.abs(o - r).max() / max(1e-9, np.abs(r).max())
        assert rel < 0.15, rel
    assert rep.codec == "ZFP/LZ4"


def test_weights_over_wire_with_lossy_codec(small_graph_and_params):
    """Weights shipped ZFP-24 (near-lossless): outputs stay close."""
    g, params = small_graph_and_params
    xs = _inputs(2)
    ref = [np.asarray(g.apply(params, jnp.asarray(x))) for x in xs]
    eng = InferenceEngine(g, 2, DispatcherCodecs(
        weights=WireCodec("zfp", "none", zfp_rate=24),
        data=WireCodec("raw", "none")))
    eng.configure(params)
    outs, _ = eng.run(xs)
    eng.shutdown()
    for o, r in zip(outs, ref):
        rel = np.abs(o - r).max() / max(1e-9, np.abs(r).max())
        assert rel < 0.1, rel


def test_fifo_order_under_load(small_graph_and_params):
    """The chain must return results in submission order (paper's FIFO)."""
    g, params = small_graph_and_params
    xs = _inputs(8)
    eng = InferenceEngine(g, 4, DispatcherCodecs(
        data=WireCodec("raw", "none"), weights=WireCodec("raw", "none")))
    eng.configure(params)
    outs, _ = eng.run(xs)          # dispatcher asserts FIFO internally
    eng.shutdown()
    # outputs must match per-input single-device results (order-correct)
    for o, x in zip(outs, xs):
        np.testing.assert_allclose(
            o, np.asarray(g.apply(params, jnp.asarray(x))), atol=1e-5)


def test_config_step_records(small_graph_and_params):
    g, params = small_graph_and_params
    eng = InferenceEngine(g, 3, DispatcherCodecs(
        weights=WireCodec("zfp", "lz4", zfp_rate=16),
        data=WireCodec("raw", "none")))
    eng.configure(params)
    recs = eng.dispatcher.config_records
    kinds = {r.kind for r in recs}
    assert kinds == {"architecture", "weights"}
    w = [r for r in recs if r.kind == "weights"]
    assert len(w) == 3
    total_raw = sum(r.raw_bytes for r in w)
    total_wire = sum(r.wire_bytes for r in w)
    assert total_wire < total_raw          # zfp16+lz4 must compress weights
    eng.shutdown()


def test_wire_tree_roundtrip():
    from repro.runtime.wire import WireCodec, tree_unflatten_paths
    codec = WireCodec("raw", "none")
    tree = {"a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "c": np.ones(4, np.int32)}
    blob, rec = codec.encode_tree(tree, "weights")
    flat, _ = codec.decode_tree(blob)
    nested = tree_unflatten_paths(flat)
    np.testing.assert_array_equal(nested["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(nested["c"], tree["c"])
    assert rec.raw_bytes == 6 * 4 + 4 * 4
