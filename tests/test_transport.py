"""Transport conformance + wire-path hardening.

The conformance suite runs the SAME contract over every registered
backend — in-proc queues, real TCP loopback sockets, and the emulated
CORE-style link — because the runtime's ordering and flow-control
arguments (epoch fences, staged-relay backpressure, `_STOP` accounting)
assume nothing about a channel beyond FIFO delivery, bounded in-flight
items, and token identity.  The hardening tests prove the failure story:
a truncated blob or a killed socket fails exactly the affected batch as a
NodeError while the chain keeps serving and shuts down cleanly.
"""
import dataclasses
import queue
import threading
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.runtime.wire as wire_mod
from repro.core.graph import LayerGraph
from repro.core.metrics import EDGE
from repro.runtime import InferenceEngine, StageSpec, TopologySpec
from repro.runtime.dispatcher import DispatcherCodecs, NodeError
from repro.runtime.transport import (ChannelClosed, InprocTransport,
                                     LinkTransport, TcpChannel,
                                     _TRANSPORTS, get_transport,
                                     register_transport)
from repro.runtime.wire import (BatchEnvelope, NodePlan, ReconfigMarker,
                                RowExtent, WireCodec, WireFormatError,
                                _RETIRE, _STOP, frame, slice_parts, unframe)

D = 16

RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))

# every registered backend plus a parameterized link (jitter on, to prove
# the monotonic-ready clamp keeps FIFO); new register_transport backends
# are picked up automatically
BACKENDS = sorted(_TRANSPORTS) + ["link:40mbit,1ms,0.5ms"]


def envelope(i: int, cid=0, rows: int = 1, blob: bytes = b"x" * 32,
             epoch: int = 0) -> BatchEnvelope:
    return BatchEnvelope([RowExtent(i, cid, i, rows, t_submit=0.25)],
                         blob, epoch=epoch)


def mlp_graph(depth: int = 6, d: int = D) -> LayerGraph:
    g = LayerGraph("toy-mlp", jax.ShapeDtypeStruct((1, d), np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, d), np.float32),
                flops=2.0 * d * d)
        prev = f"fc{i}"
    return g


def sample(i: int) -> np.ndarray:
    return np.random.default_rng(i).normal(size=(1, D)).astype(np.float32)


def make_engine(topology, graph=None, **kw):
    g = graph if graph is not None else mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, topology, RAW, **kw)
    eng.configure(params)
    return g, params, eng


def shutdown_or_fail(eng, timeout=60.0):
    """Shutdown on a watchdog: a hang here is the bug being tested for."""
    t = threading.Thread(target=eng.shutdown, daemon=True)
    t.start()
    t.join(timeout)
    assert not t.is_alive(), "engine shutdown hung"


# -- frame()/unframe(): the byte wire under every transport -------------------

def test_frame_roundtrip_envelope():
    env = BatchEnvelope(
        [RowExtent(7, ("bg", 3), 2, 4, t_submit=1.25, pad_trim=(3, 5),
                   attempt=2),
         RowExtent(8, "client-x", 0, 1),
         RowExtent(9, 0, 1, 2)],
        b"\x00\x01payload\xff", epoch=3)
    r = unframe(frame(env))
    assert r.epoch == 3 and r.blob == env.blob and r.error is None
    assert r.extents[0].client_id == ("bg", 3)
    assert isinstance(r.extents[0].client_id, tuple)    # hashable again
    assert r.extents[0].pad_trim == (3, 5)
    assert r.extents[0].t_submit == 1.25                # exact (f64)
    assert r.extents[0].attempt == 2                    # replay tag rides
    assert r.extents[1].client_id == "client-x"
    assert r.extents[1].pad_trim is None
    assert r.extents[1].attempt == 0
    assert r.retryable is False
    err = unframe(frame(BatchEnvelope([RowExtent(1, 0, 0, 1)], b"",
                                      error="trace\nback ü", epoch=1,
                                      retryable=True)))
    assert err.error == "trace\nback ü" and err.blob == b""
    assert err.retryable is True                # classification rides too


def test_frame_roundtrip_tokens_and_marker():
    assert unframe(frame(_STOP)) is _STOP       # the very same singleton
    assert unframe(frame(_RETIRE)) is _RETIRE
    codec = WireCodec("zfp", "lz4", zfp_rate=12, vectorized=False)
    m = ReconfigMarker(4, {
        1: NodePlan(2, 5, b'{"layers": []}', b"WWWW",
                    WireCodec("raw", "none"), wire_bytes=18),
        0: NodePlan(0, 2, b"a", b"", codec, wire_bytes=1)})
    r = unframe(frame(m))
    assert r.epoch == 4 and sorted(r.plans) == [0, 1]
    assert r.plans[1].weights_blob == b"WWWW" and r.plans[1].lo == 2
    assert r.plans[0].weights_codec == codec
    # empty marker (scale fences carry no plans)
    assert unframe(frame(ReconfigMarker(9, {}))).plans == {}


def test_frame_rejects_non_channel_items():
    with pytest.raises(WireFormatError):
        frame(object())
    with pytest.raises(WireFormatError):        # unencodable client id
        frame(envelope(0, cid=object()))


def test_unframe_truncation_is_always_wireformaterror():
    blob = frame(envelope(3, cid=("a", 1), blob=b"b" * 100))
    for k in range(len(blob)):
        with pytest.raises(WireFormatError):
            unframe(blob[:k])
    with pytest.raises(WireFormatError):        # trailing garbage
        unframe(blob + b"!")


def test_unframe_corruption_fuzz():
    """Flipped bytes either parse (flip landed in the payload) or raise
    WireFormatError — never a bare struct.error/ValueError/KeyError.  The
    seed carries the v3 reliability fields so flips land in the attempt
    header and the flags byte too."""
    blob = frame(BatchEnvelope(
        [RowExtent(3, ("a", 1), 3, 1, t_submit=0.25, attempt=1)],
        b"b" * 64, error="boom", retryable=True))
    rng = np.random.default_rng(0)
    for _ in range(300):
        b = bytearray(blob)
        for _ in range(int(rng.integers(1, 4))):
            b[int(rng.integers(len(b)))] = int(rng.integers(256))
        try:
            unframe(bytes(b))
        except WireFormatError:
            pass


def test_old_frame_version_rejected_by_name_compat_path_decodes():
    """FRAME_VERSION bumped to 4 (decode-session fields): old frames are
    refused by the strict decoder with an error NAMING the versions, the
    explicit compat path still decodes v2/v3 (missing fields at their
    defaults), and newer-only field values refuse to frame as an older
    version rather than silently dropping the tag."""
    from repro.runtime.wire import FRAME_VERSION, unframe_compat
    assert FRAME_VERSION == 4
    env = BatchEnvelope([RowExtent(7, "c", 2, 4, t_submit=1.25)],
                        b"payload", epoch=2)
    for old_v in (2, 3):
        old = frame(env, version=old_v)
        with pytest.raises(WireFormatError,
                           match=rf"version {old_v}.*speaking 4"):
            unframe(old)
        r = unframe_compat(old)
        assert r.blob == b"payload" and r.extents[0].request_id == 7
        assert r.extents[0].attempt == 0 and r.retryable is False
        assert r.extents[0].session is None
        assert r.extents[0].kind == 0 and r.extents[0].pos == 0
    # current frames flow through the compat path too
    r4 = unframe_compat(frame(env))
    assert r4.extents[0].t_submit == 1.25
    # v3-only values are not representable in v2
    with pytest.raises(WireFormatError, match="attempt"):
        frame(BatchEnvelope([RowExtent(1, 0, 0, 1, attempt=1)], b""),
              version=2)
    with pytest.raises(WireFormatError, match="retryable"):
        frame(BatchEnvelope([RowExtent(1, 0, 0, 1)], b"", error="e",
                            retryable=True), version=2)
    # v4-only values (decode sessions) are not representable in v3
    with pytest.raises(WireFormatError, match="session"):
        frame(BatchEnvelope([RowExtent(1, 0, 0, 1, session="s", kind=2,
                                       pos=5)], b""), version=3)


# -- decode_tree / decode_array: untrusted blobs ------------------------------

def test_decode_tree_truncated_blob():
    wc = WireCodec("raw", "none")
    blob, _ = wc.encode_tree(
        {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
         "b": np.ones((2, 2), np.float32)}, "data")
    out, _ = wc.decode_tree(blob)
    assert set(out) == {"a", "b"}
    for cut in (0, 2, 4, 9, len(blob) // 2, len(blob) - 1):
        with pytest.raises(WireFormatError):
            wc.decode_tree(blob[:cut])
    with pytest.raises(WireFormatError):
        wc.decode_tree(blob + b"xx")
    # corrupt leaf count cannot allocate-loop its way to a struct.error
    with pytest.raises(WireFormatError):
        wc.decode_tree(b"\xff\xff\xff\x7f" + blob[4:])
    # regression: truncation landing BETWEEN leaves (the 2-leaf count
    # header still passes the up-front guard, leaf 0 parses whole, and
    # leaf 1's 4-byte name-length header is short) must be
    # WireFormatError, not a bare struct.error from the header unpack
    solo, _ = wc.encode_tree(
        {"a": np.arange(12, dtype=np.float32).reshape(3, 4)}, "data")
    leaf0_end = len(solo)                       # leaf 0 bytes == solo[4:]
    two_leaves_cut = blob[:leaf0_end + 2]       # 2 stray header bytes
    with pytest.raises(WireFormatError):
        wc.decode_tree(two_leaves_cut)


@pytest.mark.parametrize("codec", [WireCodec("raw", "none"),
                                   WireCodec("zfp", "lz4", zfp_rate=16),
                                   WireCodec("q8", "none"),
                                   WireCodec("json", "none")])
def test_decode_array_corrupt_blob(codec):
    blob = codec.encode_array(np.ones((4, 8), np.float32))
    codec.decode_array(blob)                    # intact: fine
    for cut in (0, 1, len(blob) // 3, len(blob) - 1):
        try:
            codec.decode_array(blob[:cut])
        except WireFormatError:
            pass        # the contract: WireFormatError or a clean parse


def test_truncated_blob_fails_only_the_affected_batch():
    """Regression (ISSUE 5): a corrupt wire payload mid-chain — now
    reachable via a dropped socket — must fail exactly the affected batch
    with NodeError and leave the chain serving."""
    class TruncatingCodec:
        def __init__(self, inner):
            self._inner = inner
            self.arm = 0

        def encode_tree(self, *a, **kw):
            blob, rec = self._inner.encode_tree(*a, **kw)
            if self.arm:
                self.arm -= 1
                blob = blob[: len(blob) // 2]
            return blob, rec

        def __getattr__(self, name):
            return getattr(self._inner, name)

    g, params, eng = make_engine(TopologySpec.chain(mlp_graph(), 2),
                                 max_batch=1)
    eng.start()
    node0 = eng.dispatcher.stages[0].replicas[0]
    node0.data_codec = TruncatingCodec(node0.data_codec)
    assert eng.submit(sample(0)).result(timeout=60) is not None

    node0.data_codec.arm = 1                    # corrupt the next payload
    with pytest.raises(NodeError, match="WireFormatError"):
        eng.submit(sample(1)).result(timeout=60)

    ref = np.asarray(g.apply(params, jnp.asarray(sample(2))))
    np.testing.assert_allclose(eng.submit(sample(2)).result(timeout=60),
                               ref, atol=1e-5)  # chain kept serving
    shutdown_or_fail(eng)


# -- conformance: same contract over every backend ----------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_fifo(backend):
    ch = get_transport(backend).channel(0)
    try:
        for i in range(40):
            ch.send(envelope(i))
        got = [ch.recv(timeout=10).extents[0].request_id for _ in range(40)]
        assert got == list(range(40))
        with pytest.raises(queue.Empty):
            ch.recv(timeout=0.02)
        with pytest.raises(queue.Empty):
            ch.recv_nowait()
    finally:
        ch.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_tokens(backend):
    """Stop / retire / fence markers round-trip with identity preserved —
    the routers' `is _STOP` checks and epoch accounting must work on the
    far side of any backend."""
    ch = get_transport(backend).channel(0)
    try:
        plan = NodePlan(1, 3, b'{"layers": ["fc1", "fc2"]}', b"wts",
                        WireCodec("raw", "none"), wire_bytes=30)
        ch.send(envelope(0, epoch=2))
        ch.send(ReconfigMarker(3, {0: plan}))
        ch.send(_STOP)
        ch.send(_RETIRE)
        env = ch.recv(timeout=10)
        assert env.epoch == 2 and env.extents[0].request_id == 0
        m = ch.recv(timeout=10)
        assert m.epoch == 3 and m.plans[0].arch_blob == plan.arch_blob
        assert ch.recv(timeout=10) is _STOP
        assert ch.recv(timeout=10) is _RETIRE
    finally:
        ch.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_backpressure_and_qsize(backend):
    """A capacity-k channel admits at most k unconsumed sends (the
    staged-relay flow-control contract: kernel socket buffers must not
    widen the window), and qsize reports the outstanding depth lqd
    routing keys on."""
    cap = 4
    ch = get_transport(backend).channel(cap)
    try:
        sent = []

        def sender():
            for i in range(cap * 2):
                ch.send(envelope(100 + i))
                sent.append(i)

        t = threading.Thread(target=sender, daemon=True)
        t.start()
        time.sleep(0.6)
        assert len(sent) <= cap, f"backpressure leak: {len(sent)} > {cap}"
        assert ch.qsize() >= cap - 1            # the depth signal is live
        for _ in range(cap * 2):
            ch.recv(timeout=10)
        t.join(10)
        assert not t.is_alive()
        deadline = time.monotonic() + 5
        while ch.qsize() != 0 and time.monotonic() < deadline:
            time.sleep(0.01)                    # credits return async
        assert ch.qsize() == 0
    finally:
        ch.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_conformance_engine_end_to_end(backend):
    """A replicated chain serves correct, per-client-FIFO results over
    the backend, survives a live scale-down, and shuts down cleanly."""
    spec = TopologySpec.chain(mlp_graph(), 2,
                              transport=backend).with_replicas(0, 2)
    g, params, eng = make_engine(spec, max_batch=2)
    eng.start()
    futs = [eng.submit(sample(i), client_id=("c", i % 3)) for i in range(10)]
    for i, f in enumerate(futs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    rec = eng.scale(0, 1)                       # drain a replica live
    assert rec["changed"] and rec["acknowledged"]
    for i in range(10, 14):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(
            eng.submit(sample(i)).result(timeout=60), ref, atol=1e-5)
    shutdown_or_fail(eng)


def test_mixed_transports_per_stage():
    """Each stage binds its own backend (the per-stage transport config
    from the ISSUE): tcp into stage 0, an emulated link into stage 1,
    in-proc at the tail."""
    g = mlp_graph()
    spec = TopologySpec((
        StageSpec((0, 2), transport="tcp"),
        StageSpec((2, 4), transport="link:80mbit,1ms"),
        StageSpec((4, 6), transport="inproc"),
    ))
    g, params, eng = make_engine(spec, graph=g, max_batch=2)
    eng.start()
    for i in range(6):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(
            eng.submit(sample(i)).result(timeout=60), ref, atol=1e-5)
    shutdown_or_fail(eng)


def test_link_shaping_delays_delivery():
    """The emulated link is actually shaped: a 10 KB frame over 1 mbit
    takes >= 80 ms to become receivable."""
    ch = get_transport("link:1mbit,0ms").channel(0)
    try:
        t0 = time.monotonic()
        ch.send(envelope(0, blob=b"z" * 10_000))
        ch.recv(timeout=10)
        assert time.monotonic() - t0 >= 0.07
    finally:
        ch.close()


def test_link_spec_parsing():
    tr = LinkTransport.from_spec("10mbit,20ms,5ms")
    assert tr.bandwidth_bytes_s == pytest.approx(1.25e6)
    assert tr.latency_s == pytest.approx(0.020)
    assert tr.jitter_s == pytest.approx(0.005)
    assert LinkTransport.from_spec("1gbit,2ms").jitter_s == 0.0
    with pytest.raises(ValueError):
        LinkTransport.from_spec("10parsecs,20ms")
    with pytest.raises(ValueError):
        LinkTransport.from_spec("10mbit,20ms,1ms,oops")
    with pytest.raises(ValueError, match="unknown transport"):
        get_transport("warp:9")


# -- kill the socket: the chain survives a dead replica link ------------------

def test_tcp_kill_fails_batch_chain_keeps_serving():
    """Sever one replica's TCP inbox mid-serve: any batch already routed
    onto the dead link fails with NodeError (never a hang, never a wrong
    answer), the router heals onto the sibling — since ISSUE 7 it also
    PROBES channel liveness, so a link severed while no send is in
    flight is healed before another batch is risked on it — later
    requests succeed, and shutdown still joins every thread (the router
    proxies the dead replica's fence/stop tokens downstream)."""
    spec = TopologySpec.chain(mlp_graph(), 1,
                              transport="tcp").with_replicas(0, 2)
    g, params, eng = make_engine(spec, max_batch=1)
    eng.start()
    for i in range(4):                          # both replicas warm
        eng.submit(sample(i)).result(timeout=60)

    victim = eng.dispatcher.stages[0].replicas[1]
    assert isinstance(victim.inbox, TcpChannel)
    victim.inbox.kill()

    outcomes = []
    for i in range(8):
        try:
            res = eng.submit(sample(10 + i)).result(timeout=60)
            ref = np.asarray(g.apply(params, jnp.asarray(sample(10 + i))))
            np.testing.assert_allclose(res, ref, atol=1e-5)
            outcomes.append("ok")
        except NodeError:
            outcomes.append("failed")
    # only batches the router had already risked on the dead link may
    # fail (at most the one in flight — liveness probing heals the
    # member otherwise); traffic recovered and kept succeeding
    assert outcomes.count("failed") <= 1, outcomes
    assert outcomes[-1] == "ok" and outcomes.count("ok") >= 4, outcomes
    # the dead replica self-retired off the live set
    deadline = time.monotonic() + 20
    while (len(eng.dispatcher.stages[0].live_replicas()) > 1
           and time.monotonic() < deadline):
        time.sleep(0.05)
    assert len(eng.dispatcher.stages[0].live_replicas()) == 1
    shutdown_or_fail(eng)


def test_unencodable_client_id_rejected_at_submit():
    """A client id the byte framing cannot carry is a clear submit-time
    error on ANY topology — not a silent mid-chain relay failure on
    whichever stage binds a socket transport."""
    _, _, eng = make_engine(TopologySpec.chain(mlp_graph(), 2),
                            max_batch=1)
    eng.start()
    with pytest.raises(WireFormatError, match="not wire-encodable"):
        eng.submit(sample(0), client_id=frozenset({1}))
    # tuple/str/int ids stay fine, and the rejection left no debris
    eng.submit(sample(1), client_id=("ok", 1)).result(timeout=60)
    shutdown_or_fail(eng)


def test_tcp_kill_under_load_every_future_resolves():
    """Kill a replica's inbox with batches genuinely in flight: whatever
    was stranded in the dead link's buffers fails via the router's
    in-flight ledger — every future resolves (result or NodeError),
    none hangs."""
    spec = TopologySpec.chain(mlp_graph(), 1,
                              transport="tcp").with_replicas(0, 2)
    g, params, eng = make_engine(spec, max_batch=1, queue_depth=4)
    eng.start()
    for i in range(4):
        eng.submit(sample(i)).result(timeout=60)

    futs = [eng.submit(sample(20 + i), client_id=i % 3) for i in range(16)]
    eng.dispatcher.stages[0].replicas[1].inbox.kill()
    outcomes = {"ok": 0, "failed": 0}
    for f in futs:
        try:
            f.result(timeout=60)
            outcomes["ok"] += 1
        except NodeError:
            outcomes["failed"] += 1
    assert outcomes["ok"] >= 1, outcomes      # the chain kept serving
    # and the healed chain still serves fresh traffic
    eng.submit(sample(99)).result(timeout=60)
    shutdown_or_fail(eng)


def test_tcp_dead_tail_fails_pending_not_hangs():
    """Sever the collector's result channel: in-flight futures fail with
    NodeError instead of hanging, new submits are refused with a clear
    error, and shutdown completes."""
    spec = TopologySpec.chain(mlp_graph(), 1, transport="tcp")
    g, params, eng = make_engine(spec, max_batch=1)
    eng.start()
    eng.submit(sample(0)).result(timeout=60)

    futs = [eng.submit(sample(1 + i)) for i in range(4)]
    eng.dispatcher.result_channel.kill()
    for f in futs:                  # resolve — completed or failed — fast
        try:
            f.result(timeout=30)
        except NodeError:
            pass
    deadline = time.monotonic() + 20
    while not eng.dispatcher._tail_dead and time.monotonic() < deadline:
        time.sleep(0.02)
    assert eng.dispatcher._tail_dead
    with pytest.raises(RuntimeError, match="no longer deliver"):
        eng.submit(sample(50))
    shutdown_or_fail(eng)


def test_tcp_dead_midchain_link_fails_pending_not_hangs():
    """Sever a MID-chain stage-input link: the dead stage's router stops
    the chain downstream, the collector recognizes the stop cascade it
    did not initiate and fails everything unresolved, new submits are
    refused, and shutdown completes — the generalization of the dead-tail
    case one hop earlier."""
    spec = TopologySpec.chain(mlp_graph(), 3, transport="tcp")
    g, params, eng = make_engine(spec, max_batch=1)
    eng.start()
    eng.submit(sample(0)).result(timeout=60)

    futs = [eng.submit(sample(1 + i)) for i in range(6)]
    eng.dispatcher._stage_inputs[1].kill()      # stage 1's inbound link
    for f in futs:                  # resolve — completed or failed — fast
        try:
            f.result(timeout=30)
        except NodeError:
            pass
    deadline = time.monotonic() + 20
    while not eng.dispatcher._tail_dead and time.monotonic() < deadline:
        time.sleep(0.02)
    assert eng.dispatcher._tail_dead
    with pytest.raises(RuntimeError, match="no longer deliver"):
        eng.submit(sample(50))
    shutdown_or_fail(eng)


def _generous_policy():
    from repro.runtime.dispatcher import RetryPolicy
    return RetryPolicy(max_attempts=5, backoff_s=0.02,
                       retry_budget=64.0, refill_per_s=32.0)


def test_tcp_kill_with_retry_policy_zero_failures():
    """The same dead-link drill as above, with a retry policy: stranded
    batches are re-admitted through the healed routing set instead of
    failing — EVERY future resolves with the correct result, no client
    ever sees a NodeError."""
    spec = TopologySpec.chain(mlp_graph(), 1,
                              transport="tcp").with_replicas(0, 2)
    g, params, eng = make_engine(spec, max_batch=1, queue_depth=4,
                                 retry_policy=_generous_policy())
    eng.start()
    for i in range(4):                          # both replicas warm
        eng.submit(sample(i)).result(timeout=60)

    futs = [(20 + i, eng.submit(sample(20 + i), client_id=i % 3))
            for i in range(16)]
    eng.dispatcher.stages[0].replicas[1].inbox.kill()
    for i, f in futs:       # no try/except: a NodeError IS the failure
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    # the healed chain serves fresh traffic on the survivor
    eng.submit(sample(99)).result(timeout=60)
    shutdown_or_fail(eng)


def test_tcp_dead_tail_revives_and_replays_with_retry_policy():
    """The un-bricking path: severing the result channel used to poison
    the dispatcher forever (_tail_dead, 'restart the engine').  With a
    retry policy the collector rebuilds the tail channel in place,
    replays what was in flight, and keeps accepting submits — zero
    client-visible failures."""
    spec = TopologySpec.chain(mlp_graph(), 1, transport="tcp")
    g, params, eng = make_engine(spec, max_batch=1,
                                 retry_policy=_generous_policy())
    eng.start()
    eng.submit(sample(0)).result(timeout=60)

    futs = [(1 + i, eng.submit(sample(1 + i))) for i in range(4)]
    eng.dispatcher.result_channel.kill()
    for i, f in futs:
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    assert eng.dispatcher.replay_stats.tail_revives >= 1
    assert not eng.dispatcher._tail_dead
    # new submits are NOT refused — the engine needed no restart
    eng.submit(sample(50)).result(timeout=60)
    shutdown_or_fail(eng)


def test_tcp_dead_tail_still_fails_fast_without_policy():
    """Replay OFF must preserve the PR 7 contract byte-for-byte: this is
    test_tcp_dead_tail_fails_pending_not_hangs re-asserted next to its
    replay twin so the two semantics are diffable side by side."""
    spec = TopologySpec.chain(mlp_graph(), 1, transport="tcp")
    g, params, eng = make_engine(spec, max_batch=1)
    eng.start()
    eng.submit(sample(0)).result(timeout=60)
    fut = eng.submit(sample(1))
    eng.dispatcher.result_channel.kill()
    try:
        fut.result(timeout=30)
    except NodeError:
        pass
    deadline = time.monotonic() + 20
    while not eng.dispatcher._tail_dead and time.monotonic() < deadline:
        time.sleep(0.02)
    assert eng.dispatcher._tail_dead
    assert eng.dispatcher.replay_stats.tail_revives == 0
    with pytest.raises(RuntimeError, match="no longer deliver"):
        eng.submit(sample(50))
    shutdown_or_fail(eng)


# -- registry: re-registration vs live channels -------------------------------

def test_register_transport_refuses_while_channels_live():
    register_transport("reg-probe", InprocTransport)
    tr = get_transport("reg-probe")
    ch = tr.channel(1)
    with pytest.raises(ValueError, match="live"):
        register_transport("reg-probe", InprocTransport)
    assert get_transport("reg-probe") is tr     # instance NOT stranded
    ch.close()
    register_transport("reg-probe", InprocTransport)    # idle now: fine
    assert get_transport("reg-probe") is not tr

    ch2 = get_transport("reg-probe").channel(1)
    register_transport("reg-probe", InprocTransport, force=True)
    ch2.close()
    del _TRANSPORTS["reg-probe"]


def test_register_transport_scheme_strand_protection():
    from repro.runtime.transport import (_INSTANCES, _SCHEMES,
                                         register_transport_scheme)
    register_transport_scheme("probe-sch", lambda args: InprocTransport())
    tr = get_transport("probe-sch:x")
    ch = tr.channel(1)
    with pytest.raises(ValueError, match="live"):
        register_transport_scheme("probe-sch",
                                  lambda args: InprocTransport())
    assert get_transport("probe-sch:x") is tr   # not stranded
    ch.close()
    register_transport_scheme("probe-sch", lambda args: InprocTransport())
    # stale cached instances dropped: the new factory actually serves
    assert get_transport("probe-sch:x") is not tr
    del _SCHEMES["probe-sch"]
    _INSTANCES.pop("probe-sch:x", None)


def test_engine_shutdown_releases_channels():
    register_transport("reg-engine", InprocTransport)
    spec = TopologySpec.chain(mlp_graph(), 2, transport="reg-engine")
    _, _, eng = make_engine(spec, max_batch=2)
    eng.start()
    eng.submit(sample(0)).result(timeout=60)
    tr = get_transport("reg-engine")
    assert tr.live_channels > 0
    with pytest.raises(ValueError, match="live"):
        register_transport("reg-engine", InprocTransport)
    shutdown_or_fail(eng)
    assert tr.live_channels == 0                # shutdown closed them all
    register_transport("reg-engine", InprocTransport)
    del _TRANSPORTS["reg-engine"]


# -- slice_parts pad_trim rank mismatch: one-shot warning ---------------------

def test_slice_parts_rank_mismatch_warns_once():
    wire_mod._RANK_MISMATCH_WARNED = False
    flat = {"out": np.ones((4, 7), np.float32)}         # rank 2
    ext = [RowExtent(0, 0, 0, 4, pad_trim=(5,))]        # expects rank 3
    with pytest.warns(RuntimeWarning, match="pad_safe=False"):
        parts = slice_parts(flat, ext)
    assert parts[0]["out"].shape == (4, 7)              # passed through
    with warnings.catch_warnings():
        warnings.simplefilter("error")                  # would raise if
        slice_parts(flat, ext)                          # warned again
    # matching ranks stay silent and still trim
    wire_mod._RANK_MISMATCH_WARNED = False
    flat3 = {"out": np.ones((4, 8, 3), np.float32)}
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        parts = slice_parts(flat3, [RowExtent(0, 0, 0, 4, pad_trim=(5,))])
    assert parts[0]["out"].shape == (4, 5, 3)


# -- replica-aware energy: idle replicas burn the baseline --------------------

def test_engine_idle_energy_accounting():
    # default profile (idle_w = 0): figures unchanged, idle term zero
    spec = TopologySpec.chain(mlp_graph(), 1).with_replicas(0, 2)
    _, _, eng = make_engine(spec, max_batch=2)
    eng.start()
    for i in range(6):
        eng.submit(sample(i)).result(timeout=60)
    rep = eng.report()
    assert all(pn["idle_energy_j"] == 0.0 for pn in rep.per_node)
    # per-cycle total: each replica's per-request energy weighted by the
    # share of the window's cycles it actually served
    active = sum(pn["energy_j"] * pn["requests"] for pn in rep.per_node) \
        / rep.samples
    assert rep.per_node_energy_j == pytest.approx(active / rep.num_nodes)
    # one stage: its replicas' request counts tile the window exactly
    assert sum(pn["requests"] for pn in rep.per_node) == rep.samples
    shutdown_or_fail(eng)

    # idle_w > 0: a mostly-idle replicated stage books baseline burn
    hw = dataclasses.replace(EDGE, idle_w=5.0)
    _, _, eng = make_engine(spec, max_batch=2, hw=hw)
    eng.start()
    for i in range(6):
        eng.submit(sample(i)).result(timeout=60)
    time.sleep(0.3)                             # guaranteed idle window
    rep = eng.report()
    assert all(pn["idle_energy_j"] > 0.0 for pn in rep.per_node)
    active = sum(pn["energy_j"] * pn["requests"] for pn in rep.per_node) \
        / rep.samples
    idle = sum(pn["idle_energy_j"] for pn in rep.per_node)
    assert rep.per_node_energy_j == pytest.approx(
        (active + idle) / rep.num_nodes)
    shutdown_or_fail(eng)


def test_emulator_replicas_energy():
    """emulate(replicas=...): 1-replica formulas reduce to the pre-replica
    report; replicating the bottleneck raises modeled throughput; idle
    replicas burn idle_w."""
    from repro.core.emulator import emulate
    g = mlp_graph(8)
    base = emulate(g, 4, seed=0)
    assert base.replicas == () and base.num_nodes == 4

    ones = emulate(g, 4, seed=0, replicas=[1, 1, 1, 1])
    assert ones.replicas == (1, 1, 1, 1) and ones.num_nodes == 4
    # the 1-replica case is unchanged: no idle term (idle_w=0), the same
    # per-node mean over 4 nodes, the same bottleneck law
    assert all(s.idle_energy_j == 0.0 for s in ones.stages)
    assert ones.per_node_energy_j == pytest.approx(
        sum(s.energy_j for s in ones.stages) / 4)
    assert ones.throughput_cps == pytest.approx(
        1.0 / max(s.service_s for s in ones.stages))

    svc = [s.service_s for s in base.stages]
    reps = [1] * 4
    reps[int(np.argmax(svc))] = 2               # replicate the bottleneck
    r2 = emulate(g, 4, seed=0, replicas=reps)
    assert r2.num_nodes == 5 and sum(r2.replicas) == 5
    # structural (codec timings are measured, so cross-run comparisons are
    # noisy): the bottleneck prices the amortized rate, which can only be
    # at or below the unamortized service time of the same run
    amort = max(s.rate_service_s for s in r2.stages)
    assert r2.throughput_cps == pytest.approx(1.0 / amort)
    assert amort <= max(s.service_s for s in r2.stages)
    rep_stage = r2.stages[int(np.argmax(reps))]
    assert rep_stage.rate_service_s == pytest.approx(
        rep_stage.service_s / 2)

    hw = dataclasses.replace(EDGE, idle_w=3.0)
    r_idle = emulate(g, 4, seed=0, hw=hw, replicas=reps)
    assert sum(s.idle_energy_j for s in r_idle.stages) > 0
    assert r_idle.per_node_energy_j == pytest.approx(
        sum(s.energy_j + s.idle_energy_j for s in r_idle.stages) / 5)
    with pytest.raises(ValueError):
        emulate(g, 4, replicas=[1, 1])          # wrong length
