"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def rand(shape, dtype=jnp.float32, scale=1.0):
    x = RNG.normal(size=shape).astype(np.float32) * scale
    return jnp.asarray(x, dtype=dtype)


# -- block quantization ----------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (64, 512), (3, 7),
                                   (1, 1), (2, 4, 384), (1000,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_quant_roundtrip_bound(shape, dtype):
    x = rand(shape, dtype, scale=10.0)
    q, s, meta = ops.quantize_blocks(x)
    back = ops.dequantize_blocks(q, s, meta, dtype=jnp.float32)
    err = jnp.abs(back - x.astype(jnp.float32)).max()
    bound = jnp.abs(x.astype(jnp.float32)).max() / 127.0 + 1e-6
    assert err <= bound, (shape, dtype, float(err), float(bound))


@pytest.mark.parametrize("shape", [(8, 128), (32, 256), (64, 1024)])
def test_block_quant_matches_ref(shape):
    x = rand(shape)
    q, s, _ = ops.quantize_blocks(x)
    qr, sr = ref.quantize_blocks_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_block_quant_zero_tile():
    x = jnp.zeros((8, 128))
    q, s, meta = ops.quantize_blocks(x)
    back = ops.dequantize_blocks(q, s, meta)
    assert jnp.all(back == 0)


# -- decode attention ---------------------------------------------------------------

@pytest.mark.parametrize("B,H,kv,hd,C", [
    (1, 4, 4, 64, 256),       # MHA
    (2, 8, 2, 64, 512),       # GQA
    (2, 8, 1, 128, 1024),     # MQA
    (1, 16, 4, 80, 640),      # odd head_dim (zamba-like), pad path
])
@pytest.mark.parametrize("window", [None, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, kv, hd, C, window, dtype):
    q = rand((B, 1, H, hd), dtype)
    k = rand((B, C, kv, hd), dtype)
    v = rand((B, C, kv, hd), dtype)
    kpos = jnp.broadcast_to(jnp.arange(C)[None], (B, C)).astype(jnp.int32)
    kpos = jnp.where(kpos > C - 50, -1, kpos)          # empty ring slots
    pos = jnp.full((B,), C - 50, jnp.int32)
    scale = 1.0 / np.sqrt(hd)
    out = ops.decode_attention(q, k, v, kpos, pos, window, scale)
    expect = ref.decode_attention_ref(q, k, v, kpos, pos, window, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_decode_attention_masks_everything_empty():
    """All-empty cache: softmax denominator guard must not NaN."""
    B, H, kv, hd, C = 1, 2, 2, 64, 128
    q = rand((B, 1, H, hd))
    k = jnp.zeros((B, C, kv, hd))
    v = jnp.zeros((B, C, kv, hd))
    kpos = jnp.full((B, C), -1, jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    out = ops.decode_attention(q, k, v, kpos, pos, None, 0.125)
    assert bool(jnp.isfinite(out).all())


# -- SSD scan ------------------------------------------------------------------------

@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (1, 2, 16, 2, 16, 8),
    (2, 4, 32, 3, 32, 16),
    (1, 8, 64, 2, 64, 64),     # mamba2-like tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, nc, Q, H, P, N, dtype):
    xc = rand((B, nc, Q, H, P), dtype)
    dtc = jnp.asarray(RNG.uniform(0.001, 0.1, (B, nc, Q, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bc = rand((B, nc, Q, N), dtype)
    Cc = rand((B, nc, Q, N), dtype)
    st = rand((B, H, P, N))
    y, fin = ops.ssd_scan(xc, dtc, A, Bc, Cc, st)
    yr, fr = ref.ssd_scan_ref(xc, dtc, A, Bc, Cc, st)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr.reshape(y.shape), np.float32),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fr),
                               atol=tol, rtol=1e-3)


def test_ssd_scan_state_chaining():
    """Scanning 4 chunks at once == two 2-chunk calls chained via state."""
    B, nc, Q, H, P, N = 1, 4, 16, 2, 16, 8
    xc = rand((B, nc, Q, H, P))
    dtc = jnp.asarray(RNG.uniform(0.01, 0.1, (B, nc, Q, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    Bc = rand((B, nc, Q, N))
    Cc = rand((B, nc, Q, N))
    st0 = jnp.zeros((B, H, P, N))
    y_all, f_all = ops.ssd_scan(xc, dtc, A, Bc, Cc, st0)
    y1, f1 = ops.ssd_scan(xc[:, :2], dtc[:, :2], A, Bc[:, :2], Cc[:, :2], st0)
    y2, f2 = ops.ssd_scan(xc[:, 2:], dtc[:, 2:], A, Bc[:, 2:], Cc[:, 2:], f1)
    np.testing.assert_allclose(np.asarray(y_all),
                               np.concatenate([y1, y2], axis=1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f_all), np.asarray(f2), atol=1e-4)


# -- kernels wired into the model ---------------------------------------------------

def test_model_use_kernel_paths_match():
    import importlib
    from repro.models import transformer as T
    key = jax.random.PRNGKey(0)
    cfg = importlib.import_module("repro.configs.mamba2_2_7b").smoke_config()
    params = T.init_lm(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    l0, _ = T.forward(params, cfg, tokens, use_kernel=False)
    l1, _ = T.forward(params, cfg, tokens, use_kernel=True)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-4)
