"""Graph factories for process-backed workers in tests.

Worker processes rebuild the layer graph locally from a factory named in
:class:`~repro.runtime.supervisor.SupervisorConfig` — tests point at this
file with the path form (``"/abs/path/_worker_graphs.py:mlp_graph"``,
resolved by :func:`repro.runtime.worker.load_graph_factory`) because the
``tests`` directory is not an installed package.  Everything here must be
importable with only ``src`` on ``sys.path``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import LayerGraph

D = 16


POISON = 777.0


def poison_graph(depth: int = 4, d: int = D) -> LayerGraph:
    """mlp_graph plus a tripwire: an input whose first element is
    :data:`POISON` makes the first layer raise — a deterministic
    APPLICATION error (user ``apply`` code), which the reliability layer
    must surface after exactly one attempt, never replay."""
    shape = (1, d)
    g = LayerGraph("poison-mlp", jax.ShapeDtypeStruct(shape, np.float32))

    def check(x_host):
        # host-side tripwire (the stage apply is jitted, so the
        # data-dependent raise must escape the trace via a callback);
        # raises identically on every attempt — nothing a retry can heal
        if np.any(np.asarray(x_host) == POISON):
            raise ValueError("poison pill: application error from apply()")
        return np.asarray(x_host)

    def trip(p, x):
        x = jax.pure_callback(
            check, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return jnp.tanh(x @ p["w"])

    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                trip if i == 0
                else (lambda p, x: jnp.tanh(x @ p["w"])),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct(shape, np.float32),
                flops=2.0 * d * d)
        prev = f"fc{i}"
    return g


def lm_graph(**kw) -> LayerGraph:
    """The small decode-capable transformer the decode-serving tests
    standardize on — deterministic builder, so the supervisor-side and
    worker-side copies agree layer for layer (and the KV cache capacity,
    a graph-level constant, matches across processes)."""
    from repro.models.lm_graph import decode_lm_graph
    defaults = dict(vocab=32, d_model=16, n_layers=2, num_heads=2,
                    kv_heads=2, head_dim=8, d_ff=32, cache_len=48)
    defaults.update(kw)
    return decode_lm_graph(**defaults)


def mlp_graph(depth: int = 6, d: int = D) -> LayerGraph:
    """The toy tanh MLP the runtime tests standardize on — deterministic,
    so the supervisor-side and worker-side copies agree layer for layer."""
    shape = (1, d)
    g = LayerGraph("toy-mlp", jax.ShapeDtypeStruct(shape, np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct(shape, np.float32),
                flops=2.0 * d * d)
        prev = f"fc{i}"
    return g
