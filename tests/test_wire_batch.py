"""Batch-level wire format + q8 serializer: row-extent framing round trips
(ragged and single-request), q8 error bound through encode_tree, and the
full dispatcher -> chain -> collector path on CPU interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codecs
from repro.runtime import InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import BatchEnvelope, RowExtent, WireCodec, slice_parts

RNG = np.random.default_rng(7)


def _extents(rows):
    return [RowExtent(request_id=i, client_id=i % 2, seq=i, rows=r)
            for i, r in enumerate(rows)]


@pytest.mark.parametrize("rows", [[1], [3], [1, 1, 1], [2, 5, 1, 4]])
@pytest.mark.parametrize("serializer", ["raw", "zfp", "q8"])
def test_batch_framing_roundtrip(rows, serializer):
    """Stack ragged per-request trees, encode ONCE, decode, slice by the
    envelope's row extents: every request gets back exactly its rows."""
    codec = WireCodec(serializer, "none", zfp_rate=20)
    parts = [{"a": RNG.normal(size=(r, 6, 4)).astype(np.float32),
              "b": RNG.normal(size=(r, 3)).astype(np.float32)}
             for r in rows]
    stacked = {k: np.concatenate([p[k] for p in parts], axis=0)
               for k in parts[0]}
    blob, rec = codec.encode_tree(stacked, "data")
    env = BatchEnvelope(_extents(rows), blob)
    assert env.n == len(rows) and env.rows == sum(rows)
    flat, _ = codec.decode_tree(env.blob)
    back = slice_parts({k: np.asarray(v) for k, v in flat.items()},
                       env.extents)
    assert len(back) == len(parts)
    bound = codec.error_bound(
        float(max(np.abs(stacked[k]).max() for k in stacked)))
    for orig, got in zip(parts, back):
        for k in orig:
            assert got[k].shape == orig[k].shape
            if serializer == "raw":
                np.testing.assert_array_equal(got[k], orig[k])
            else:
                assert np.abs(got[k] - orig[k]).max() <= bound


def test_batch_framing_is_one_encode_pass():
    """Encoding the stacked batch must cost ONE codec pass whose payload is
    smaller than the sum of per-request passes (amortized framing)."""
    codec = WireCodec("zfp", "lz4", zfp_rate=16)
    parts = [{"x": RNG.normal(size=(1, 64, 32)).astype(np.float32)}
             for _ in range(8)]
    stacked = {"x": np.concatenate([p["x"] for p in parts], axis=0)}
    one, rec_one = codec.encode_tree(stacked, "data")
    per = [codec.encode_tree(p, "data")[0] for p in parts]
    assert len(one) <= sum(len(b) for b in per)


@pytest.mark.parametrize("shape", [(5,), (1, 64, 256), (33, 100), (8, 128)])
def test_q8_codec_roundtrip_error_bound(shape):
    q8 = codecs.Q8Codec()
    arr = (RNG.normal(size=shape) * 10).astype(np.float32)
    back = q8.decode(q8.encode(arr))
    assert back.shape == arr.shape and back.dtype == arr.dtype
    assert np.abs(back - arr).max() <= q8.error_bound(
        float(np.abs(arr).max()))


def test_q8_wire_codec_tree_roundtrip():
    codec = WireCodec("q8", "lz4")
    tree = {"h": RNG.normal(size=(4, 32, 16)).astype(np.float32)}
    blob, rec = codec.encode_tree(tree, "data")
    assert rec.wire_bytes < tree["h"].nbytes        # ~4x + scales + lz4
    flat, _ = codec.decode_tree(blob)
    bound = codec.error_bound(float(np.abs(tree["h"]).max()))
    assert np.abs(np.asarray(flat["h"]) - tree["h"]).max() <= bound


def _mlp(depth=4, d=16):
    from repro.core.graph import LayerGraph
    g = LayerGraph("q8-mlp", jax.ShapeDtypeStruct((1, d), np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, d), np.float32),
                flops=2.0 * d * d)
        prev = f"fc{i}"
    return g


def test_q8_through_full_chain():
    """q8 inter-node activations ride the dispatcher -> chain -> collector
    path end to end (CPU interpret mode) within the accumulated per-hop
    error bound."""
    g = _mlp()
    params = g.init(jax.random.PRNGKey(0))
    num_nodes = 2
    eng = InferenceEngine(g, num_nodes, DispatcherCodecs(
        data=WireCodec("q8", "none"),
        weights=WireCodec("raw", "none")), max_batch=4)
    eng.configure(params)
    xs = [RNG.normal(size=(1, 16)).astype(np.float32) for _ in range(6)]
    outs, rep = eng.run(xs)
    eng.shutdown()
    assert rep.codec == "Q8/Uncompressed"
    # worst case: every hop (dispatcher feed + inter-node + tail) quantizes
    # a tanh-bounded activation, and errors compound through |W| matmuls;
    # with |acts| <= ~4 and small depth a loose stacked bound suffices
    bound = (num_nodes + 1) * codecs.Q8Codec().error_bound(4.0) * 10
    for x, out in zip(xs, outs):
        ref = np.asarray(g.apply(params, jnp.asarray(x)))
        assert np.abs(out - ref).max() <= bound, np.abs(out - ref).max()
