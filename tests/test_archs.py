"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates the REDUCED same-family variant (2 layers, d_model<=512,
<=4 experts) and runs one forward + one train step on CPU, asserting output
shapes and no NaNs; decode consistency is covered for one arch per family.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, get_smoke
from repro.models import transformer as T
from repro.train.optimizer import OptConfig, init_opt_state, apply_updates

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.num_prefix_embeds and not cfg.encoder_layers:
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_prefix_embeds, cfg.d_model)) * 0.05
    if cfg.encoder_layers:
        batch["encoder_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_prefix_embeds, cfg.d_model)) * 0.05
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = T.init_lm(cfg, KEY)
    batch = _batch(cfg)
    B, S = batch["tokens"].shape

    logits, aux = T.forward(params, cfg, batch["tokens"],
                            batch.get("prefix_embeds"),
                            batch.get("encoder_embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    # one train step
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_opt_state(params)
    (loss, _), grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    new_params, state, stats = apply_updates(params, grads, state, opt)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_analytic_matches_built(arch):
    cfg = get_smoke(arch)
    params = T.init_lm(cfg, KEY)
    assert T.param_count(params) == cfg.param_count()


@pytest.mark.parametrize("arch,expect_b", [
    ("phi3-mini-3.8b", 3.8), ("starcoder2-3b", 3.0), ("gemma3-4b", 4.3),
    ("granite-34b", 34), ("dbrx-132b", 132), ("mamba2-2.7b", 2.7),
    ("zamba2-2.7b", 2.7), ("pixtral-12b", 12),
])
def test_full_config_param_count_plausible(arch, expect_b):
    n = get_config(arch).param_count() / 1e9
    assert 0.6 * expect_b <= n <= 1.45 * expect_b, f"{arch}: {n:.2f}B"


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "gemma3-4b", "dbrx-132b",
                                  "zamba2-2.7b", "mamba2-2.7b",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch):
    """prefill + one decode_step == forward on the extended sequence."""
    cfg = get_smoke(arch)
    params = T.init_lm(cfg, KEY)
    batch = _batch(cfg, B=2, S=12)
    kw = {k: batch[k] for k in ("prefix_embeds", "encoder_embeds")
          if k in batch}
    lp, caches = T.prefill(params, cfg, batch["tokens"], max_len=20, **kw)
    nt = jnp.argmax(lp, -1).astype(jnp.int32)
    lg, _ = T.decode_step(params, cfg, nt, jnp.full((2,), 12, jnp.int32),
                          caches)
    ext = jnp.concatenate([batch["tokens"], nt], axis=1)
    lf, _ = T.forward(params, cfg, ext, **kw)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lf[:, -1:]),
                               atol=2e-4)


def test_unroll_matches_scan():
    cfg = get_smoke("gemma3-4b")
    params = T.init_lm(cfg, KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    a, _ = T.forward(params, cfg, tokens, unroll=False)
    b, _ = T.forward(params, cfg, tokens, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_dispatch_variants_agree():
    """Global-view scatter dispatch == reference dense mixture when no drop."""
    from repro.models import moe as M
    from repro.configs.base import MoEConfig
    spec = M.MoESpec(64, 128, True, MoEConfig(4, 2, capacity_factor=4.0))
    p = M.init_moe(KEY, spec, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 64)) * 0.3
    out, aux = M.moe_block(p, spec, x)
    # dense reference: route every token through its top-k experts directly
    h = x.reshape(16, 64)
    from repro.models.layers import rmsnorm
    hn = rmsnorm(p["ln"], h.reshape(2, 8, 64)).reshape(16, 64)
    idx, gates, _ = M._route(p, spec, hn)
    ys = []
    for t in range(16):
        acc = 0
        for j in range(2):
            e = int(idx[t, j])
            up = hn[t] @ p["up"][e]
            up = jax.nn.silu(hn[t] @ p["gate"][e]) * up
            acc += gates[t, j] * (up @ p["down"][e])
        ys.append(acc)
    expect = h + jnp.stack(ys)
    np.testing.assert_allclose(np.asarray(out.reshape(16, 64)),
                               np.asarray(expect), atol=2e-5)


def test_sliding_window_attention_banded_equals_dense():
    """Banded sliding-window path == dense masked attention."""
    from repro.models import attention as A
    s = A.AttnSpec(64, 4, 2, 16, window=32, q_chunk=16)
    p = A.init_attn(KEY, s, jnp.float32)
    x = jax.random.normal(KEY, (2, 64, 64)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    out = A.attention(p, s, x, pos)
    # dense reference with explicit window mask
    s_full = A.AttnSpec(64, 4, 2, 16, window=None, q_chunk=64)
    from repro.models.layers import rmsnorm, linear
    h = rmsnorm(p["ln"], x)
    q, k, v = A._project_qkv(p, s, h, pos)
    delta = pos[:, :, None] - pos[:, None, :]
    mask = (delta >= 0) & (delta < 32)
    o = A._sdpa(q, k, v, mask, 1 / np.sqrt(16)).reshape(2, 64, -1)
    expect = x + linear(p["wo"], o)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)
