"""TPU pipeline path: schedule correctness, stage stacking, wire quant,
multi-device equivalence (subprocess with 4 fake devices)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import (PipelineConfig, make_pipeline,
                                 make_stage_unit_fn, pipeline_apply,
                                 stack_stages)
from repro.launch.mesh import make_mesh_compat


def test_stack_stages_padding_and_mask():
    w = jnp.arange(7 * 3).reshape(7, 3).astype(jnp.float32)
    stacked, valid = stack_stages(w, 7, 4)
    assert stacked.shape == (4, 2, 3)
    assert valid.tolist() == [[True, True], [True, True], [True, True],
                              [True, False]]
    np.testing.assert_array_equal(np.asarray(stacked[3, 1]), np.zeros(3))


def test_stack_stages_exact_division():
    w = jnp.ones((8, 2))
    stacked, valid = stack_stages(w, 8, 4)
    assert stacked.shape == (4, 2, 2) and bool(valid.all())


def test_single_stage_pipeline_equals_sequential():
    """S=1 runs on one real device; schedule reduces to a plain loop."""
    d = 16
    w = jax.random.normal(jax.random.PRNGKey(0), (3, d, d)) * 0.1

    def apply_unit(up, x):
        return x + jnp.tanh(x @ up)

    mesh = make_mesh_compat((1,), ("stage",))
    stacked, valid = stack_stages(w, 3, 1)
    fn = make_pipeline(mesh, PipelineConfig(1, 4),
                       make_stage_unit_fn(apply_unit))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8, d))
    with mesh:
        y = jax.jit(fn)((stacked, valid), x)
    ref = x
    for i in range(3):
        ref = apply_unit(w[i], ref)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)


def test_wire_quant_roundtrip_in_pipeline_codec():
    from repro.core.pipeline import _wire_decode, _wire_encode
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 24, 96))
    q, sc = _wire_encode(x, "jnp")
    assert q.dtype == jnp.int8
    back = _wire_decode(q, sc, x.shape, x.dtype, "jnp")
    assert back.shape == x.shape
    err = jnp.abs(back - x).max()
    assert err <= jnp.abs(x).max() / 127.0 + 1e-6


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, importlib
from repro.launch.mesh import make_mesh_compat
from repro.launch.serve import build_pipeline_lm
from repro.models import transformer as T

failures = []
for a in ["phi3_mini_3_8b", "zamba2_2_7b", "seamless_m4t_large_v2"]:
    cfg = importlib.import_module(f"repro.configs.{a}").smoke_config()
    params = T.init_lm(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh_compat((4,), ("stage",))
    B, S, M = 8, 16, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.encoder_layers:
        kw["encoder_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_prefix_embeds, cfg.d_model)) * 0.1
    ref, _ = T.forward(params, cfg, tokens, **kw)
    lm = build_pipeline_lm(cfg, params, mesh, 4, M, compress=False)
    with mesh:
        out = jax.jit(lambda t: lm(t, **kw))(tokens)
    err = float(jnp.abs(out - ref).max())
    if err > 1e-4:
        failures.append((a, err))
    lmc = build_pipeline_lm(cfg, params, mesh, 4, M, compress=True)
    with mesh:
        outc = jax.jit(lambda t: lmc(t, **kw))(tokens)
    rel = float(jnp.abs(outc - ref).max() / jnp.abs(ref).max())
    if rel > 0.15:
        failures.append((a + "+compress", rel))
assert not failures, failures
print("OK")
"""


@pytest.mark.slow
def test_pipeline_lm_multidevice_subprocess():
    """4-stage pipeline == single-device forward, for 3 families, on 4
    fake devices (own process so the 1-device test env is untouched)."""
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
