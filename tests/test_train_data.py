"""Optimizer, schedule, checkpointing, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # network-less CI image: degrade to fixed examples
    from _hypothesis_compat import given, settings, st

from repro.train import checkpoint as ckpt
from repro.train.optimizer import (OptConfig, apply_updates, global_norm,
                                   init_opt_state, schedule)


def test_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-9            # peak at end of warmup
    assert lrs[99] < lrs[50] < lrs[11]           # cosine decays
    assert lrs[99] >= 0.1 * 1e-3 - 1e-12         # floor


def test_grad_clip_caps_update():
    params = {"w": jnp.ones((4, 4))}
    huge = {"w": jnp.full((4, 4), 1e6)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=1, total_steps=10,
                    weight_decay=0.0)
    new, state, stats = apply_updates(params, huge, state, cfg)
    assert float(stats["grad_norm"]) > 1e5
    # post-clip Adam step magnitude is bounded by lr
    assert float(jnp.abs(new["w"] - params["w"]).max()) <= 1.0 + 1e-5


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=1, max_size=8))
def test_global_norm_matches_numpy(vals):
    tree = {"a": jnp.asarray(vals, jnp.float32)}
    np.testing.assert_allclose(float(global_norm(tree)),
                               np.linalg.norm(np.asarray(vals, np.float32)),
                               rtol=1e-5, atol=1e-5)


def test_checkpoint_roundtrip_and_sharding():
    tree = {"a": {"b": np.arange(1000, dtype=np.float32).reshape(10, 100)},
            "c": [np.ones(3, np.int32), np.zeros((2, 2), np.float64)]}
    with tempfile.TemporaryDirectory() as d:
        out = ckpt.save(d, 5, tree, shard_bytes=1024)   # force multi-shard
        assert len([f for f in os.listdir(out) if f.startswith("shard")]) > 1
        like = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        back = ckpt.restore(d, 5, like)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ckpt.latest_step(d) == 5


def test_data_pipeline_determinism_and_sharding():
    from repro.data.pipeline import TokenStream
    a = list(next(TokenStream(100, 8, 16, seed=3)) for _ in range(1))[0]
    b = next(TokenStream(100, 8, 16, seed=3))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards partition the batch deterministically but differ from each other
    s0 = next(TokenStream(100, 8, 16, seed=3, shard=0, num_shards=2))
    s1 = next(TokenStream(100, 8, 16, seed=3, shard=1, num_shards=2))
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next-token shifted
    assert a["labels"].shape == a["tokens"].shape


def test_data_has_learnable_structure():
    """A bigram table must beat uniform on the synthetic stream."""
    from repro.data.pipeline import TokenStream
    it = TokenStream(50, 16, 64, seed=0)
    hits = tot = 0
    for _ in range(5):
        b = next(it)
        # the dominant structure: next = cur + stride (mod V); check top-1
        # predictability via empirical delta histogram
        delta = (b["labels"] - b["tokens"]) % 50
        vals, counts = np.unique(delta, return_counts=True)
        hits += counts.max()
        tot += delta.size
    assert hits / tot > 0.10        # >> 1/50 uniform chance


def test_prefetcher_preserves_order():
    from repro.data.pipeline import Prefetcher
    out = list(Prefetcher(iter(range(20)), depth=4))
    assert out == list(range(20))


def test_end_to_end_training_loss_drops():
    from repro.configs.registry import get_smoke
    from repro.data.pipeline import make_lm_iter
    from repro.train.loop import train
    cfg = get_smoke("starcoder2-3b")
    it = make_lm_iter(cfg, batch=8, seq_len=32, seed=0)
    opt = OptConfig(lr=2e-3, warmup_steps=3, total_steps=25)
    _, _, hist = train(cfg, opt, it, num_steps=25, log_every=24)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3
