"""deferlint self-tests: one minimal violating snippet per rule plus a
passing twin, asserting rule id and line number, plus the repo-is-clean
gate and unit tests for the runtime lockdep registry."""

import os
import textwrap
import threading

import pytest

from tools.deferlint import lint_paths, main
from tools.deferlint.lockdep import Registry, running_nondaemon_threads


def _lint_snippet(tmp_path, source, reldir="runtime"):
    """Write `source` as a module under a fake package tree (pkg/<reldir>/)
    and lint it, returning the violations."""
    d = tmp_path / "pkg" / reldir
    d.mkdir(parents=True, exist_ok=True)
    mod = d / "mod.py"
    mod.write_text(textwrap.dedent(source))
    return lint_paths([str(tmp_path / "pkg")])


def _rules_at(violations):
    return [(v.rule, v.line) for v in violations]


# -- DL101: unchecked struct.unpack -------------------------------------------

def test_dl101_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import struct

        def parse(blob):
            (n,) = struct.unpack_from("<I", blob, 0)
            return n
        """)
    assert ("DL101", 4) in _rules_at(vs)


def test_dl101_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import struct

        def _checked(blob, off, n, what):
            return off + n

        def parse(blob):
            _checked(blob, 0, 4, "count")
            (n,) = struct.unpack_from("<I", blob, 0)
            return n
        """)
    assert not [v for v in vs if v.rule == "DL101"]


# -- DL102: pickle/eval banned in runtime/ ------------------------------------

def test_dl102_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import pickle

        def load(blob):
            return pickle.loads(blob)
        """)
    assert ("DL102", 1) in _rules_at(vs)


def test_dl102_eval_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def run(expr):
            return eval(expr)
        """)
    assert ("DL102", 2) in _rules_at(vs)


def test_dl102_passing_outside_runtime(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import pickle
        """, reldir="offline")
    assert not [v for v in vs if v.rule == "DL102"]


# -- DL103: wall clock banned in runtime/ -------------------------------------

def test_dl103_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def expired(deadline):
            return time.time() >= deadline
        """)
    assert ("DL103", 4) in _rules_at(vs)


def test_dl103_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def expired(deadline):
            return time.monotonic() >= deadline

        def fine_timing():
            return time.perf_counter()
        """)
    assert not [v for v in vs if v.rule == "DL103"]


def test_dl103_passing_outside_runtime(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def stamp():
            return time.time()
        """, reldir="offline")
    assert not [v for v in vs if v.rule == "DL103"]


# -- DL201: lock-order cycle --------------------------------------------------

def test_dl201_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Node:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def forward(self):
                with self._a:
                    with self._b:
                        pass

            def backward(self):
                with self._b:
                    with self._a:
                        pass
        """)
    dl201 = [v for v in vs if v.rule == "DL201"]
    # anchored at whichever inner `with` completed the cycle edge
    assert dl201 and dl201[0].line in (10, 15)


def test_dl201_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Node:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def forward(self):
                with self._a:
                    with self._b:
                        pass

            def backward(self):
                with self._a:
                    with self._b:
                        pass
        """)
    assert not [v for v in vs if v.rule == "DL201"]


def test_dl201_cross_method_cycle(tmp_path):
    # a cycle only visible through a held call into another method
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Node:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def report_stats(self):
                with self._b:
                    pass

            def forward(self):
                with self._a:
                    self.report_stats()

            def backward(self):
                with self._b:
                    with self._a:
                        pass
        """)
    assert [v for v in vs if v.rule == "DL201"]


# -- DL301: non-daemon unjoined thread ----------------------------------------

def test_dl301_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Worker:
            def start(self):
                self._t = threading.Thread(target=self._run)
                self._t.start()
        """)
    assert ("DL301", 5) in _rules_at(vs)


def test_dl301_passing_daemon(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Worker:
            def start(self):
                self._t = threading.Thread(target=self._run, daemon=True)
                self._t.start()
        """)
    assert not [v for v in vs if v.rule == "DL301"]


def test_dl301_passing_joined(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Worker:
            def start(self):
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def stop(self):
                self._t.join(5.0)
        """)
    assert not [v for v in vs if v.rule == "DL301"]


# -- DL302: unkillable blocking loop / unbounded join -------------------------

def test_dl302_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def pump(q):
            while True:
                item = q.get()
                handle(item)
        """)
    assert ("DL302", 3) in _rules_at(vs)


def test_dl302_passing_stop_token(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        _STOP = object()

        def pump(q):
            while True:
                item = q.get()
                if item is _STOP:
                    return
                handle(item)
        """)
    assert not [v for v in vs if v.rule == "DL302"]


def test_dl302_unbounded_join_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def wait_for(t):
            t.join()
        """)
    assert ("DL302", 2) in _rules_at(vs)


def test_dl302_join_in_shutdown_passes(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        class Worker:
            def stop(self):
                self._t.join()
        """)
    assert not [v for v in vs if v.rule == "DL302"]


# -- DL303: time.sleep outside the shaper -------------------------------------

def test_dl303_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def poll(x):
            time.sleep(0.1)
        """)
    assert ("DL303", 4) in _rules_at(vs)


def test_dl303_passing_in_shaper(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        class LinkChannel:
            def _xmit_loop(self):
                time.sleep(0.001)
        """)
    assert not [v for v in vs if v.rule == "DL303"]


# -- DL304: unreaped child processes ------------------------------------------

def test_dl304_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import subprocess

        class Spawner:
            def launch(self, cmd):
                self.proc = subprocess.Popen(cmd)
        """)
    assert ("DL304", 5) in _rules_at(vs)


def test_dl304_passing_twin_reaped_elsewhere(tmp_path):
    # spawn in one method, reap in another — the check is global, like
    # DL301's join accounting
    vs = _lint_snippet(tmp_path, """\
        import subprocess

        class Spawner:
            def launch(self, cmd):
                self.proc = subprocess.Popen(cmd)

            def close(self):
                self.proc.terminate()
                self.proc.wait()
        """)
    assert not [v for v in vs if v.rule == "DL304"]


def test_dl304_multiprocessing_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import multiprocessing

        def fork(fn):
            worker = multiprocessing.Process(target=fn)
            worker.start()
            return worker
        """)
    assert ("DL304", 4) in _rules_at(vs)


def test_dl304_passing_outside_runtime(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import subprocess

        def launch(cmd):
            proc = subprocess.Popen(cmd)
            return proc
        """, reldir="offline")
    assert not [v for v in vs if v.rule == "DL304"]


# -- DL401: unaudited broad except --------------------------------------------

def test_dl401_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def relay(ch, item):
            try:
                ch.send(item)
            except Exception:
                pass
        """)
    assert ("DL401", 4) in _rules_at(vs)


def test_dl401_passing_swallow_tag(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def relay(ch, item):
            try:
                ch.send(item)
            except Exception:  # deferlint: swallow(best-effort notify)
                pass
        """)
    assert not [v for v in vs if v.rule == "DL401"]


def test_dl401_passing_reraise(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def relay(ch, item):
            try:
                ch.send(item)
            except Exception as e:
                raise RuntimeError("send failed") from e
        """)
    assert not [v for v in vs if v.rule == "DL401"]


# -- DL501: token compared by equality ----------------------------------------

def test_dl501_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        _STOP = object()

        def pump(q):
            while True:
                item = q.get()
                if item == _STOP:
                    return
        """)
    assert ("DL501", 6) in _rules_at(vs)


def test_dl501_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        _STOP = object()

        def pump(q):
            while True:
                item = q.get()
                if item is _STOP:
                    return
        """)
    assert not [v for v in vs if v.rule == "DL501"]


def test_dl501_int_tag_untouched(tmp_path):
    # integer wire tags like _F_STOP legitimately use ==
    vs = _lint_snippet(tmp_path, """\
        _F_STOP = 2

        def classify(ftype):
            return ftype == _F_STOP
        """)
    assert not [v for v in vs if v.rule == "DL501"]


# -- the repo itself is clean, and the CLI exit codes are right ---------------

def test_repo_is_clean():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    violations = lint_paths([src])
    assert not violations, "\n".join(v.format() for v in violations)


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "runtime"
    bad.mkdir()
    (bad / "m.py").write_text("import struct\n(n,) = struct.unpack('<I', b)\n")
    assert main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "DL101" in out
    good = tmp_path / "clean"
    good.mkdir()
    (good / "m.py").write_text("x = 1\n")
    assert main([str(good)]) == 0


# -- runtime lockdep unit tests -----------------------------------------------

def test_lockdep_detects_inversion():
    reg = Registry()

    def t1():
        reg.note_acquire("A", "t1")
        reg.note_acquire("B", "t1")
        reg.note_release("B")
        reg.note_release("A")

    def t2():
        reg.note_acquire("B", "t2")
        reg.note_acquire("A", "t2")
        reg.note_release("A")
        reg.note_release("B")

    # run in real threads so the per-thread held stacks are distinct
    for fn in (t1, t2):
        t = threading.Thread(target=fn)
        t.start()
        t.join()
    inv = reg.inversions()
    assert inv and "A" in inv[0] and "B" in inv[0]


def test_lockdep_consistent_order_is_clean():
    reg = Registry()
    for _ in range(2):
        reg.note_acquire("A", "x")
        reg.note_acquire("B", "x")
        reg.note_release("B")
        reg.note_release("A")
    assert reg.inversions() == []


def test_thread_leak_helper():
    evt = threading.Event()
    before = set(threading.enumerate())
    t = threading.Thread(target=evt.wait)
    t.start()
    try:
        assert t in running_nondaemon_threads(before)
    finally:
        evt.set()
        t.join()
    assert t not in running_nondaemon_threads(before)
