"""deferlint self-tests: one minimal violating snippet per rule plus a
passing twin, asserting rule id and line number, plus the repo-is-clean
gate and unit tests for the runtime lockdep registry."""

import json
import os
import re
import textwrap
import threading

import pytest

from tools.deferlint import RULE_CATALOG, lint_paths, main
from tools.deferlint.lockdep import Registry, running_nondaemon_threads


def _lint_snippet(tmp_path, source, reldir="runtime"):
    """Write `source` as a module under a fake package tree (pkg/<reldir>/)
    and lint it, returning the violations."""
    d = tmp_path / "pkg" / reldir
    d.mkdir(parents=True, exist_ok=True)
    mod = d / "mod.py"
    mod.write_text(textwrap.dedent(source))
    return lint_paths([str(tmp_path / "pkg")])


def _lint_files(tmp_path, files):
    """Write several modules (relpath -> source) under pkg/ and lint the
    tree — for rules that correlate across modules (DL603/DL604)."""
    for rel, src in files.items():
        p = tmp_path / "pkg" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return lint_paths([str(tmp_path / "pkg")])


def _rules_at(violations):
    return [(v.rule, v.line) for v in violations]


# -- DL101: unchecked struct.unpack -------------------------------------------

def test_dl101_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import struct

        def parse(blob):
            (n,) = struct.unpack_from("<I", blob, 0)
            return n
        """)
    assert ("DL101", 4) in _rules_at(vs)


def test_dl101_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import struct

        def _checked(blob, off, n, what):
            return off + n

        def parse(blob):
            _checked(blob, 0, 4, "count")
            (n,) = struct.unpack_from("<I", blob, 0)
            return n
        """)
    assert not [v for v in vs if v.rule == "DL101"]


# -- DL102: pickle/eval banned in runtime/ ------------------------------------

def test_dl102_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import pickle

        def load(blob):
            return pickle.loads(blob)
        """)
    assert ("DL102", 1) in _rules_at(vs)


def test_dl102_eval_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def run(expr):
            return eval(expr)
        """)
    assert ("DL102", 2) in _rules_at(vs)


def test_dl102_passing_outside_runtime(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import pickle
        """, reldir="offline")
    assert not [v for v in vs if v.rule == "DL102"]


# -- DL103: wall clock banned in runtime/ -------------------------------------

def test_dl103_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def expired(deadline):
            return time.time() >= deadline
        """)
    assert ("DL103", 4) in _rules_at(vs)


def test_dl103_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def expired(deadline):
            return time.monotonic() >= deadline

        def fine_timing():
            return time.perf_counter()
        """)
    assert not [v for v in vs if v.rule == "DL103"]


def test_dl103_passing_outside_runtime(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def stamp():
            return time.time()
        """, reldir="offline")
    assert not [v for v in vs if v.rule == "DL103"]


# -- DL201: lock-order cycle --------------------------------------------------

def test_dl201_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Node:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def forward(self):
                with self._a:
                    with self._b:
                        pass

            def backward(self):
                with self._b:
                    with self._a:
                        pass
        """)
    dl201 = [v for v in vs if v.rule == "DL201"]
    # anchored at whichever inner `with` completed the cycle edge
    assert dl201 and dl201[0].line in (10, 15)


def test_dl201_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Node:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def forward(self):
                with self._a:
                    with self._b:
                        pass

            def backward(self):
                with self._a:
                    with self._b:
                        pass
        """)
    assert not [v for v in vs if v.rule == "DL201"]


def test_dl201_cross_method_cycle(tmp_path):
    # a cycle only visible through a held call into another method
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Node:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def report_stats(self):
                with self._b:
                    pass

            def forward(self):
                with self._a:
                    self.report_stats()

            def backward(self):
                with self._b:
                    with self._a:
                        pass
        """)
    assert [v for v in vs if v.rule == "DL201"]


# -- DL301: non-daemon unjoined thread ----------------------------------------

def test_dl301_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Worker:
            def start(self):
                self._t = threading.Thread(target=self._run)
                self._t.start()
        """)
    assert ("DL301", 5) in _rules_at(vs)


def test_dl301_passing_daemon(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Worker:
            def start(self):
                self._t = threading.Thread(target=self._run, daemon=True)
                self._t.start()
        """)
    assert not [v for v in vs if v.rule == "DL301"]


def test_dl301_passing_joined(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import threading

        class Worker:
            def start(self):
                self._t = threading.Thread(target=self._run)
                self._t.start()

            def stop(self):
                self._t.join(5.0)
        """)
    assert not [v for v in vs if v.rule == "DL301"]


# -- DL302: unkillable blocking loop / unbounded join -------------------------

def test_dl302_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def pump(q):
            while True:
                item = q.get()
                handle(item)
        """)
    assert ("DL302", 3) in _rules_at(vs)


def test_dl302_passing_stop_token(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        _STOP = object()

        def pump(q):
            while True:
                item = q.get()
                if item is _STOP:
                    return
                handle(item)
        """)
    assert not [v for v in vs if v.rule == "DL302"]


def test_dl302_unbounded_join_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def wait_for(t):
            t.join()
        """)
    assert ("DL302", 2) in _rules_at(vs)


def test_dl302_join_in_shutdown_passes(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        class Worker:
            def stop(self):
                self._t.join()
        """)
    assert not [v for v in vs if v.rule == "DL302"]


# -- DL303: time.sleep outside the shaper -------------------------------------

def test_dl303_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        def poll(x):
            time.sleep(0.1)
        """)
    assert ("DL303", 4) in _rules_at(vs)


def test_dl303_passing_in_shaper(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import time

        class LinkChannel:
            def _xmit_loop(self):
                time.sleep(0.001)
        """)
    assert not [v for v in vs if v.rule == "DL303"]


# -- DL304: unreaped child processes ------------------------------------------

def test_dl304_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import subprocess

        class Spawner:
            def launch(self, cmd):
                self.proc = subprocess.Popen(cmd)
        """)
    assert ("DL304", 5) in _rules_at(vs)


def test_dl304_passing_twin_reaped_elsewhere(tmp_path):
    # spawn in one method, reap in another — the check is global, like
    # DL301's join accounting
    vs = _lint_snippet(tmp_path, """\
        import subprocess

        class Spawner:
            def launch(self, cmd):
                self.proc = subprocess.Popen(cmd)

            def close(self):
                self.proc.terminate()
                self.proc.wait()
        """)
    assert not [v for v in vs if v.rule == "DL304"]


def test_dl304_multiprocessing_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import multiprocessing

        def fork(fn):
            worker = multiprocessing.Process(target=fn)
            worker.start()
            return worker
        """)
    assert ("DL304", 4) in _rules_at(vs)


def test_dl304_passing_outside_runtime(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        import subprocess

        def launch(cmd):
            proc = subprocess.Popen(cmd)
            return proc
        """, reldir="offline")
    assert not [v for v in vs if v.rule == "DL304"]


# -- DL401: unaudited broad except --------------------------------------------

def test_dl401_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def relay(ch, item):
            try:
                ch.send(item)
            except Exception:
                pass
        """)
    assert ("DL401", 4) in _rules_at(vs)


def test_dl401_passing_swallow_tag(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def relay(ch, item):
            try:
                ch.send(item)
            except Exception:  # deferlint: swallow(best-effort notify)
                pass
        """)
    assert not [v for v in vs if v.rule == "DL401"]


def test_dl401_passing_reraise(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def relay(ch, item):
            try:
                ch.send(item)
            except Exception as e:
                raise RuntimeError("send failed") from e
        """)
    assert not [v for v in vs if v.rule == "DL401"]


# -- DL501: token compared by equality ----------------------------------------

def test_dl501_violation(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        _STOP = object()

        def pump(q):
            while True:
                item = q.get()
                if item == _STOP:
                    return
        """)
    assert ("DL501", 6) in _rules_at(vs)


def test_dl501_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        _STOP = object()

        def pump(q):
            while True:
                item = q.get()
                if item is _STOP:
                    return
        """)
    assert not [v for v in vs if v.rule == "DL501"]


def test_dl501_int_tag_untouched(tmp_path):
    # integer wire tags like _F_STOP legitimately use ==
    vs = _lint_snippet(tmp_path, """\
        _F_STOP = 2

        def classify(ftype):
            return ftype == _F_STOP
        """)
    assert not [v for v in vs if v.rule == "DL501"]


# -- DL601: future-resolution completeness (flow-sensitive) -------------------

def test_dl601_violation(tmp_path):
    # the except arm swallows and falls through: the dequeued future is
    # never resolved on that path — exactly PR 4/5/7's hang class
    vs = _lint_snippet(tmp_path, """\
        def flush(pending_futures, batch):
            fut = pending_futures.pop(batch, None)
            if fut is None:
                return
            try:
                value = compute(batch)
            except Exception:
                log("compute failed")
                return
            fut.set_result(value)
        """)
    assert ("DL601", 2) in _rules_at(vs)


def test_dl601_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def flush(pending_futures, batch):
            fut = pending_futures.pop(batch, None)
            if fut is None:
                return
            try:
                value = compute(batch)
            except Exception as e:
                fut.set_exception(e)
                return
            fut.set_result(value)
        """)
    assert not [v for v in vs if v.rule == "DL601"]


def test_dl601_sink_handoff_passes(tmp_path):
    # storing the new future into a tracked pending map discharges it,
    # and a raise before the store leaves the caller owning the request
    vs = _lint_snippet(tmp_path, """\
        from concurrent.futures import Future

        class Dispatcher:
            def submit(self, rid, item):
                fut = Future()
                if self._closed:
                    raise RuntimeError("closed")
                self._futures[rid] = fut
                return fut
        """)
    assert not [v for v in vs if v.rule == "DL601"]


# -- DL602: channel/resource lifecycle (flow-sensitive) -----------------------

def test_dl602_violation(tmp_path):
    # if the second channel() raises, the first leaks: no close on the
    # exception path and no hand-off before it
    vs = _lint_snippet(tmp_path, """\
        def open_pair(transport, capacity):
            a = transport.channel(capacity)
            b = transport.channel(capacity)
            return a, b
        """)
    assert ("DL602", 2) in _rules_at(vs)
    assert ("DL602", 3) not in _rules_at(vs)


def test_dl602_passing_twin(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def open_pair(transport, capacity):
            a = transport.channel(capacity)
            try:
                b = transport.channel(capacity)
            except BaseException:
                a.close()
                raise
            return a, b
        """)
    assert not [v for v in vs if v.rule == "DL602"]


def test_dl602_none_guard_cleanup_passes(tmp_path):
    # the transport.channel() idiom: close under `if sock is not None`
    # in the handler — requires the None-aware branch pruning
    vs = _lint_snippet(tmp_path, """\
        import socket

        def connect(addr):
            sock = None
            try:
                sock = socket.create_connection(addr)
                verify_peer(addr)
            except Exception as e:
                if sock is not None:
                    sock.close()
                raise ChannelClosed("dial failed") from e
            return sock
        """)
    assert not [v for v in vs if v.rule == "DL602"]


def test_dl602_suppression_tag(tmp_path):
    vs = _lint_snippet(tmp_path, """\
        def adopt(transport):
            ch = transport.channel(4)  # deferlint: resolved-by(registry weakref)
            register(id(ch))
        """)
    assert not [v for v in vs if v.rule == "DL602"]


# -- DL603: wire-tag exhaustiveness -------------------------------------------

_WIRE_FIXTURE = """\
    K_PLAIN = 0
    K_OPEN = 1
    K_STEP = 2
    K_CLOSE = 3
"""


def test_dl603_violation(tmp_path):
    vs = _lint_files(tmp_path, {
        "runtime/wire.py": _WIRE_FIXTURE,
        "runtime/mod.py": """\
            from pkg.runtime.wire import K_CLOSE, K_OPEN, K_PLAIN, K_STEP

            def route(e):
                if e.kind == K_PLAIN:
                    return handle_plain(e)
                elif e.kind == K_OPEN:
                    return handle_open(e)
                elif e.kind == K_STEP:
                    return handle_step(e)
            """,
    })
    dl603 = [(v.rule, v.line) for v in vs if v.path.endswith("mod.py")]
    assert ("DL603", 4) in dl603


def test_dl603_passing_catchall_twin(tmp_path):
    vs = _lint_files(tmp_path, {
        "runtime/wire.py": _WIRE_FIXTURE,
        "runtime/mod.py": """\
            from pkg.runtime.wire import K_OPEN, K_STEP

            def route(e):
                if e.kind == K_OPEN:
                    return handle_open(e)
                elif e.kind == K_STEP:
                    return handle_step(e)
                else:
                    raise WireFormatError(f"unknown kind {e.kind}")
            """,
    })
    assert not [v for v in vs if v.rule == "DL603"]


def test_dl603_single_test_is_not_a_chain(tmp_path):
    # routing code that peels one kind off and forwards the rest is not
    # a dispatch chain — router.route's standalone membership tests
    vs = _lint_files(tmp_path, {
        "runtime/wire.py": _WIRE_FIXTURE,
        "runtime/mod.py": """\
            from pkg.runtime.wire import K_CLOSE, K_OPEN, K_STEP

            def route(e, ledger):
                if e.kind == K_CLOSE:
                    ledger.evict(e)
                forward(e)
                if e.kind in (K_OPEN, K_STEP):
                    ledger.track(e)
            """,
    })
    assert not [v for v in vs if v.rule == "DL603"]


_DISPATCH_FIXTURE = """\
    from pkg.runtime.wire import K_CLOSE, K_OPEN, K_PLAIN, K_STEP

    def route(e):
        if e.kind == K_PLAIN:
            return 0
        elif e.kind == K_OPEN:
            return 1
        elif e.kind == K_STEP:
            return 2
        elif e.kind == K_CLOSE:
            return 3
    """


def test_dl603_mutation_gate_fires(tmp_path):
    # the exhaustiveness self-test: the full dispatch is clean because it
    # enumerates every K_* member; deleting the K_STEP arm must trip DL603
    full = textwrap.dedent(_DISPATCH_FIXTURE)
    vs = _lint_files(tmp_path, {"runtime/wire.py": _WIRE_FIXTURE,
                                "runtime/mod.py": full})
    assert not [v for v in vs if v.rule == "DL603"]

    mutated = full.replace(
        "    elif e.kind == K_STEP:\n        return 2\n", "")
    assert mutated != full
    vs = _lint_files(tmp_path, {"runtime/wire.py": _WIRE_FIXTURE,
                                "runtime/mod.py": mutated})
    dl603 = [v for v in vs if v.rule == "DL603"]
    assert dl603 and "K_STEP" in dl603[0].message


# -- DL604: supervisor <-> worker control-verb drift --------------------------

def test_dl604_violation_both_directions(tmp_path):
    vs = _lint_files(tmp_path, {
        "runtime/supervisor.py": """\
            def push(handle):
                handle.send(ControlFrame("config", {}))
                handle.send(ControlFrame("flush", {}))
            """,
        "runtime/worker.py": """\
            def run(item):
                if item.kind == "config":
                    return 1
                if item.kind == "zap":
                    return 2
            """,
    })
    dl604 = [(v.path.rsplit("/", 1)[-1], v.line)
             for v in vs if v.rule == "DL604"]
    assert ("supervisor.py", 3) in dl604   # sends "flush", never handled
    assert ("worker.py", 4) in dl604       # handles "zap", never sent


def test_dl604_passing_twin(tmp_path):
    vs = _lint_files(tmp_path, {
        "runtime/supervisor.py": """\
            def push(handle):
                handle.send(ControlFrame("config", {}))

            def on_frame(frame):
                if frame.kind == "ready":
                    return True
            """,
        "runtime/worker.py": """\
            def run(sock, item):
                if item.kind == "config":
                    send(sock, ControlFrame("ready", {}))
            """,
    })
    assert not [v for v in vs if v.rule == "DL604"]


def test_dl604_suppression_tag(tmp_path):
    vs = _lint_files(tmp_path, {
        "runtime/supervisor.py": """\
            def push(handle):
                handle.send(ControlFrame("config", {}))
            """,
        "runtime/worker.py": """\
            def run(item):
                if item.kind == "config":
                    return 1
                if item.kind == "chaos":  # deferlint: control-verb(test harness only)
                    return 2
            """,
    })
    assert not [v for v in vs if v.rule == "DL604"]


# -- the rule catalog is derived from the registry ----------------------------

def test_rule_catalog_matches_registry():
    from tools.deferlint.core import _CHECKERS
    declared = {}
    for _name, _fn, rules in _CHECKERS:
        assert rules, f"checker {_name!r} declares no rules"
        declared.update(rules)
    assert declared == RULE_CATALOG
    assert all(re.fullmatch(r"DL\d{3}", rid) for rid in RULE_CATALOG)
    for rid in ("DL101", "DL102", "DL103", "DL201", "DL301", "DL302",
                "DL303", "DL304", "DL401", "DL501", "DL601", "DL602",
                "DL603", "DL604"):
        assert RULE_CATALOG.get(rid), f"missing catalog row for {rid}"


# -- the repo itself is clean, and the CLI exit codes are right ---------------

def test_repo_is_clean():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    violations = lint_paths([src])
    assert not violations, "\n".join(v.format() for v in violations)


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "runtime"
    bad.mkdir()
    (bad / "m.py").write_text("import struct\n(n,) = struct.unpack('<I', b)\n")
    assert main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "DL101" in out
    good = tmp_path / "clean"
    good.mkdir()
    (good / "m.py").write_text("x = 1\n")
    assert main([str(good)]) == 0


def test_cli_json_select_ignore_github(tmp_path, capsys):
    bad = tmp_path / "runtime"
    bad.mkdir()
    (bad / "m.py").write_text("import struct\n(n,) = struct.unpack('<I', b)\n")

    assert main(["--json", str(tmp_path)]) == 1
    data = json.loads(capsys.readouterr().out)
    assert [d["rule"] for d in data] == ["DL101"]
    assert data[0]["line"] == 2
    assert data[0]["path"].endswith("runtime/m.py")

    assert main(["--select", "DL101", str(tmp_path)]) == 1
    capsys.readouterr()
    assert main(["--select=DL999", str(tmp_path)]) == 0
    capsys.readouterr()
    assert main(["--ignore", "DL101", str(tmp_path)]) == 0
    capsys.readouterr()

    assert main(["--github", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "::error file=" in out
    assert "title=deferlint DL101" in out

    assert main(["--bogus"]) == 2


# -- runtime lockdep unit tests -----------------------------------------------

def test_lockdep_detects_inversion():
    reg = Registry()

    def t1():
        reg.note_acquire("A", "t1")
        reg.note_acquire("B", "t1")
        reg.note_release("B")
        reg.note_release("A")

    def t2():
        reg.note_acquire("B", "t2")
        reg.note_acquire("A", "t2")
        reg.note_release("A")
        reg.note_release("B")

    # run in real threads so the per-thread held stacks are distinct
    for fn in (t1, t2):
        t = threading.Thread(target=fn)
        t.start()
        t.join()
    inv = reg.inversions()
    assert inv and "A" in inv[0] and "B" in inv[0]


def test_lockdep_consistent_order_is_clean():
    reg = Registry()
    for _ in range(2):
        reg.note_acquire("A", "x")
        reg.note_acquire("B", "x")
        reg.note_release("B")
        reg.note_release("A")
    assert reg.inversions() == []


def test_thread_leak_helper():
    evt = threading.Event()
    before = set(threading.enumerate())
    t = threading.Thread(target=evt.wait)
    t.start()
    try:
        assert t in running_nondaemon_threads(before)
    finally:
        evt.set()
        t.join()
    assert t not in running_nondaemon_threads(before)
