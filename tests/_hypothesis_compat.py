"""Fallback for `hypothesis` in network-less images.

The property tests in this repo only use a small strategy vocabulary
(`binary`, `integers`, `floats`, `lists`, `tuples`).  When the real
library is unavailable, this shim degrades each ``@given`` property test
into an example test over a deterministic set of draws: the boundary
values (all-min, all-max) plus a handful of seeded random examples.  Far
weaker than hypothesis (no shrinking, no coverage-guided search), but the
invariants still get exercised instead of the module erroring at import.

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import random
import types

N_EXAMPLES = 10
_SEED = 0x5EED


class _Strategy:
    """A deterministic value source: draw(rng, edge) -> value.

    ``edge`` is 0 for the all-minimum example, 1 for the all-maximum one,
    and None for seeded random draws.
    """

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random, edge=None):
        return self._draw(rng, edge)


def _size(rng, edge, lo, hi):
    if edge == 0:
        return lo
    if edge == 1:
        return hi
    return rng.randint(lo, hi)


def binary(min_size: int = 0, max_size: int = 64) -> _Strategy:
    def draw(rng, edge):
        n = _size(rng, edge, min_size, max_size)
        if edge == 0:
            return b"\x00" * n
        if edge == 1:
            return bytes(rng.getrandbits(8) for _ in range(n))
        # mix compressible runs with noise so LZ4 sees both regimes
        if rng.random() < 0.5:
            unit = bytes(rng.getrandbits(8) for _ in range(max(1, n // 16) or 1))
            return (unit * (n // max(1, len(unit)) + 1))[:n]
        return bytes(rng.getrandbits(8) for _ in range(n))
    return _Strategy(draw)


def integers(min_value: int, max_value: int) -> _Strategy:
    def draw(rng, edge):
        if edge == 0:
            return min_value
        if edge == 1:
            return max_value
        return rng.randint(min_value, max_value)
    return _Strategy(draw)


def floats(min_value: float, max_value: float) -> _Strategy:
    def draw(rng, edge):
        if edge == 0:
            return min_value
        if edge == 1:
            return max_value
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng, edge):
        n = _size(rng, edge, min_size, max_size)
        return [elements.draw(rng, None) for _ in range(n)]
    return _Strategy(draw)


def tuples(*elements: _Strategy) -> _Strategy:
    def draw(rng, edge):
        return tuple(e.draw(rng, edge) for e in elements)
    return _Strategy(draw)


st = types.SimpleNamespace(binary=binary, integers=integers, floats=floats,
                           lists=lists, tuples=tuples)


def settings(**_kw):
    """Accepted and ignored (example count here is fixed and small)."""
    def deco(fn):
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        def runner(*fixture_args, **fixture_kw):
            for i in range(N_EXAMPLES):
                edge = i if i < 2 else None
                rng = random.Random(_SEED + i)
                args = [s.draw(rng, edge) for s in strategies]
                fn(*fixture_args, *args, **fixture_kw)
        # NOTE: no functools.wraps — pytest follows __wrapped__ when
        # introspecting the signature and would mistake the property
        # arguments for fixtures.
        runner.__name__ = fn.__name__
        runner.__module__ = fn.__module__
        runner.__doc__ = fn.__doc__
        runner.hypothesis_fallback = True
        return runner
    return deco
