"""§Perf variants: numerics of the optimized paths == the baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, ModelConfig
from repro.models import moe as M
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def test_vocab_padding_preserves_semantics():
    """Padded-vocab logits == unpadded logits on the real ids; pad ids are
    -inf (HC1)."""
    base = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                       num_heads=4, kv_heads=2, d_ff=256, vocab=100,
                       remat=False)
    padded = dataclasses.replace(base, vocab_pad_multiple=64)
    assert padded.padded_vocab == 128
    p0 = T.init_lm(base, KEY)
    p1 = T.init_lm(padded, KEY)
    # same init stream: embedding rows 0..99 must agree
    np.testing.assert_array_equal(
        np.asarray(p0["embed"]["table"][:100]),
        np.asarray(p1["embed"]["table"][:100]))
    tokens = jax.random.randint(KEY, (2, 8), 0, 100)
    l0, _ = T.forward(p0, base, tokens)
    l1, _ = T.forward(p1, padded, tokens)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1[..., :100]),
                               atol=1e-5)
    assert bool((l1[..., 100:] < -1e29).all())
    # loss identical (softmax unaffected by -inf pads)
    batch = {"tokens": tokens, "labels": tokens}
    loss0, _ = T.loss_fn(p0, base, batch)
    loss1, _ = T.loss_fn(p1, padded, batch)
    np.testing.assert_allclose(float(loss0), float(loss1), rtol=1e-6)


def test_moe_sharded_dispatch_matches_global():
    """HC2 iter-1 path: per-shard capacity dispatch == global dispatch when
    capacity is generous."""
    spec_g = M.MoESpec(64, 128, True, MoEConfig(4, 2, capacity_factor=8.0))
    spec_s = M.MoESpec(64, 128, True,
                       MoEConfig(4, 2, capacity_factor=8.0, token_shards=4))
    p = M.init_moe(KEY, spec_g, jnp.float32)
    x = jax.random.normal(KEY, (4, 16, 64)) * 0.3
    og, aux_g = M.moe_block(p, spec_g, x)
    os_, aux_s = M.moe_block(p, spec_s, x)
    np.testing.assert_allclose(np.asarray(og), np.asarray(os_), atol=1e-6)
    np.testing.assert_allclose(float(aux_g), float(aux_s), rtol=1e-5)


def test_moe_sharded_dispatch_drops_locally():
    """With tight capacity, sharded dispatch drops per-shard (never crashes,
    stays finite)."""
    spec = M.MoESpec(32, 64, True,
                     MoEConfig(4, 2, capacity_factor=0.5, token_shards=2))
    p = M.init_moe(KEY, spec, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, 32))
    y, aux = M.moe_block(p, spec, x)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ["phi3_mini_3_8b", "gemma3_4b",
                                  "zamba2_2_7b"])
def test_int8_kv_cache_bounded_error(arch):
    """HC5: prefill+decode with int8 KV caches stays within quantization
    tolerance of the bf16-cache path (per-row fixed-rate, like ZFP)."""
    import importlib
    cfg = importlib.import_module(f"repro.configs.{arch}").smoke_config()
    cfgq = dataclasses.replace(cfg, kv_cache_quant=True)
    params = T.init_lm(cfg, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    lp0, c0 = T.prefill(params, cfg, tokens, max_len=24)
    lpq, cq = T.prefill(params, cfgq, tokens, max_len=24)
    pos0 = cq["units"]["pos0"]
    attn_cache = pos0 if "k" in pos0 else cq["units"]["shared"]
    assert attn_cache["k"].dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(lp0), np.asarray(lpq),
                               atol=0.05 * float(jnp.abs(lp0).max()))
    nt = jnp.argmax(lp0, -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    lg0, _ = T.decode_step(params, cfg, nt, pos, c0)
    lgq, _ = T.decode_step(params, cfgq, nt, pos, cq)
    rel = float(jnp.abs(lg0 - lgq).max() / jnp.abs(lg0).max())
    assert rel < 0.05, rel


@pytest.mark.slow
def test_ep_pipeline_subprocess():
    """PP x EP == single-device forward (dbrx smoke, 2 stage x 2 expert)."""
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, importlib, dataclasses
from repro.core.pipeline import stack_stages
from repro.core.pipeline_ep import build_ep_pipeline
from repro.launch.mesh import make_mesh_compat
from repro.models import transformer as T
from repro.models import layers as L

cfg = importlib.import_module("repro.configs.dbrx_132b").smoke_config()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                       capacity_factor=8.0))
params = T.init_lm(cfg, jax.random.PRNGKey(0))
B, S, M = 4, 16, 2
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
ref, _ = T.forward(params, cfg, tokens)
mesh = make_mesh_compat((1, 2, 2), ("data", "expert", "stage"))
n_units = cfg.num_layers // cfg.unit_layers
factory = build_ep_pipeline(cfg, mesh, num_stages=2, num_microbatches=M)
def step(params, tokens):
    x = L.embed(params["embed"], tokens)
    stacked, valid = stack_stages(params["units"], n_units, 2)
    fn = factory(stacked, valid)
    y = fn((stacked, valid), x.reshape(M, B//M, S, -1)).reshape(B, S, -1)
    y = L.rmsnorm(params["final_ln"], y, cfg.norm_eps)
    return T._mask_pad_vocab(cfg, L.linear(params["unembed"], y))
with mesh:
    out = jax.jit(step)(params, tokens)
rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
assert rel < 1e-4, rel
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
