"""Partitioner: DP optimality (vs brute force, hypothesis), strategies,
cut costs, and graph slicing/reassembly."""
import itertools

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # network-less CI image: degrade to fixed examples
    from _hypothesis_compat import given, settings, st

from repro.core.graph import LayerGraph
from repro.core.partitioner import (CalibratedCosts, ComputeModel, LinkModel,
                                    _linear_partition_dp, bounds_bottleneck,
                                    calibrated_partition, partition)


def chain_graph(flops, out_elems=None):
    g = LayerGraph("toy", jax.ShapeDtypeStruct((4,), np.float32))
    prev = ""
    out_elems = out_elems or [4] * len(flops)
    for i, (f, oe) in enumerate(zip(flops, out_elems)):
        g.layer(f"l{i}", lambda p, x: x, {}, (prev,),
                jax.ShapeDtypeStruct((oe,), np.float32), flops=f)
        prev = f"l{i}"
    return g


def brute_force_bottleneck(w, edge, k):
    n = len(w)
    best = float("inf")
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = [0, *cuts, n]
        cost = max(sum(w[lo:hi]) + edge[hi - 1]
                   for lo, hi in zip(bounds, bounds[1:]))
        best = min(best, cost)
    return best


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.1, 100.0), min_size=3, max_size=9),
       st.integers(2, 4))
def test_dp_optimal_vs_brute_force(w, k):
    if k > len(w):
        k = len(w)
    edge = [0.0] * len(w)
    bounds = _linear_partition_dp(np.array(w), np.array(edge), k)
    got = max(sum(w[lo:hi]) for lo, hi in zip(bounds, bounds[1:]))
    assert got <= brute_force_bottleneck(w, edge, k) + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(0.1, 50.0), st.floats(0.0, 10.0)),
                min_size=3, max_size=8),
       st.integers(2, 3))
def test_dp_optimal_with_edge_costs(pairs, k):
    w = [p[0] for p in pairs]
    edge = [p[1] for p in pairs]
    edge[-1] = 0.0
    if k > len(w):
        k = len(w)
    bounds = _linear_partition_dp(np.array(w), np.array(edge), k)
    got = max(sum(w[lo:hi]) + edge[hi - 1]
              for lo, hi in zip(bounds, bounds[1:]))
    assert got <= brute_force_bottleneck(w, edge, k) + 1e-9


def test_partition_properties():
    g = chain_graph([1e6 * (i + 1) for i in range(10)])
    for strat in ("equal_layers", "balanced_flops", "balanced_latency"):
        p = partition(g, 4, strategy=strat)
        ranges = p.ranges()
        assert ranges[0][0] == 0 and ranges[-1][1] == 10
        assert all(hi > lo for lo, hi in ranges)            # non-empty
        assert all(a[1] == b[0] for a, b in zip(ranges, ranges[1:]))
        assert p.num_stages == 4


def test_balanced_flops_beats_equal_layers_on_skew():
    # one huge layer at the end: equal_layers puts it with others
    g = chain_graph([1e6] * 9 + [1e9])
    eq = partition(g, 4, strategy="equal_layers")
    bal = partition(g, 4, strategy="balanced_flops")
    assert max(s.flops for s in bal.stages) <= max(s.flops for s in eq.stages)


def test_balanced_latency_avoids_fat_cuts():
    # cutting after l1 would transfer a huge activation
    g = chain_graph([1e6] * 6, out_elems=[4, 1_000_000, 4, 4, 4, 4])
    p = partition(g, 2, strategy="balanced_latency",
                  link=LinkModel(bandwidth_bytes_per_s=1e6),
                  compute=ComputeModel(flops_per_s=1e9))
    assert 2 not in p.cuts      # cut index 2 = after node 1 (fat edge)


def test_heterogeneous_nodes_get_proportional_work():
    """Paper's future work: faster nodes receive more layers."""
    g = chain_graph([1e9] * 12)
    fast_last = [ComputeModel(10e9), ComputeModel(10e9), ComputeModel(40e9)]
    het = partition(g, 3, strategy="balanced_flops", compute=fast_last)
    sizes = [hi - lo for lo, hi in het.ranges()]
    assert sizes[2] > sizes[0]
    # the heterogeneous plan is never worse than the paper's equal split
    eq = partition(g, 3, strategy="equal_layers", compute=fast_last)
    assert het.bottleneck_s <= eq.bottleneck_s + 1e-12


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.5, 50.0), min_size=3, max_size=7),
       st.lists(st.floats(1.0, 8.0), min_size=2, max_size=3))
def test_heterogeneous_dp_optimal_vs_brute_force(w, rates):
    k = len(rates)
    if k > len(w):
        return
    bounds = _linear_partition_dp(np.array(w), np.zeros(len(w)), k,
                                  np.array(rates))
    got = max(sum(w[lo:hi]) / rates[j]
              for j, (lo, hi) in enumerate(zip(bounds, bounds[1:])))
    best = float("inf")
    for cuts in itertools.combinations(range(1, len(w)), k - 1):
        bs = [0, *cuts, len(w)]
        best = min(best, max(sum(w[lo:hi]) / rates[j]
                             for j, (lo, hi) in enumerate(zip(bs, bs[1:]))))
    assert got <= best + 1e-9


def test_cut_cost_counts_pass_through():
    """An activation consumed two stages later crosses BOTH cuts."""
    g = LayerGraph("skip", jax.ShapeDtypeStruct((8,), np.float32))
    g.layer("a", lambda p, x: x, {}, ("",),
            jax.ShapeDtypeStruct((8,), np.float32), flops=1.0)
    g.layer("b", lambda p, x: x, {}, ("a",),
            jax.ShapeDtypeStruct((8,), np.float32), flops=1.0)
    g.layer("c", lambda p, x, y: x, {}, ("b", "a"),
            jax.ShapeDtypeStruct((8,), np.float32), flops=1.0)
    assert "a" in g.crossing_names(0)
    assert set(g.crossing_names(1)) == {"a", "b"}   # a passes through stage 2
    assert g.cut_cost(1) == 2 * 8 * 4


def test_explicit_cuts_override_strategy():
    g = chain_graph([1e6] * 8)
    p = partition(g, 3, cuts=(5, 7))
    assert p.ranges() == [(0, 5), (5, 7), (7, 8)]
    for bad in ((5,), (0, 4), (4, 8), (4, 4)):
        with pytest.raises(ValueError):
            partition(g, 3, cuts=bad)


def _costs(layer_s, bytes_=4.0, enc=0.0, dec=0.0):
    n = len(layer_s)
    return CalibratedCosts(
        layer_s=np.asarray(layer_s, np.float64),
        cut_bytes=np.full(n, bytes_), encode_s_per_byte=enc,
        decode_s_per_byte=dec, head_in_bytes=bytes_, tail_out_bytes=bytes_)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0.01, 10.0), min_size=3, max_size=9),
       st.integers(2, 4))
def test_calibrated_dp_optimal_vs_brute_force(w, k):
    """The staged (max-of-stage-times) DP matches brute force."""
    if k > len(w):
        k = len(w)
    costs = _costs(w, enc=0.05, dec=0.03)
    bounds, got = calibrated_partition(costs, k)
    assert got == pytest.approx(bounds_bottleneck(costs, bounds))
    best = float("inf")
    for cuts in itertools.combinations(range(1, len(w)), k - 1):
        best = min(best, bounds_bottleneck(costs, [0, *cuts, len(w)]))
    assert got <= best + 1e-12


def test_warm_start_window_bounds_migration_size():
    """The warm-started DP shifts no boundary further than ``window``
    layers from the current cuts — the cap on weights a live migration
    ships — and still improves the bottleneck monotonically."""
    layer_s = [8.0] + [1.0] * 11               # heavy head layer
    costs = _costs(layer_s)
    cur = [0, 6, 9, 12]                        # skewed start
    full, full_b = calibrated_partition(costs, 3)
    windowed, win_b = calibrated_partition(costs, 3, prev_bounds=cur,
                                           window=2)
    for j in (1, 2):
        assert abs(windowed[j] - cur[j]) <= 2
    assert win_b <= bounds_bottleneck(costs, cur) + 1e-12
    assert full_b <= win_b + 1e-12             # full search at least as good
    # iterating windowed steps converges to the full optimum
    b = cur
    for _ in range(6):
        b, _ = calibrated_partition(costs, 3, prev_bounds=b, window=2)
    assert bounds_bottleneck(costs, b) == pytest.approx(full_b)


def test_warm_start_infeasible_window_falls_back():
    """A window too tight to form k non-empty stages falls back to the
    full search instead of failing.  Degenerate prev bounds (an empty
    stage, e.g. handed down from a different stage count) with window=0
    make every windowed plan infeasible, so this genuinely drives the
    dp[k][n] == INF fallback branch."""
    costs = _costs([1.0] * 6)
    bounds, got = calibrated_partition(costs, 3, prev_bounds=[0, 1, 1, 6],
                                       window=0)
    full, full_b = calibrated_partition(costs, 3)
    assert bounds == full and got == pytest.approx(full_b)
    assert all(b2 > b1 for b1, b2 in zip(bounds, bounds[1:]))
    # a valid window solves in-window without falling back
    wb, _ = calibrated_partition(costs, 3, prev_bounds=[0, 1, 2, 6],
                                 window=0)
    assert wb == [0, 1, 2, 6]


def test_calibrated_staged_prefers_overlap_aware_cuts():
    """Staged pricing is max(dec, cmp, enc), not the sum: a plan that
    equalizes stage compute at ~codec cost is optimal even though the
    sequential model would say codec makes it worse."""
    costs = _costs([1.0] * 8, bytes_=4.0, enc=0.25, dec=0.25)
    bounds, got = calibrated_partition(costs, 4, staged=True)
    assert got == pytest.approx(2.0)           # 2 layers/stage, codec hidden
    seq = bounds_bottleneck(costs, bounds, staged=False)
    assert seq > got                           # overlap is what buys it


def test_replica_pricing_amortizes_rate_not_latency():
    """Replicas divide a stage's throughput contribution (compute+codec),
    never a request's own service time."""
    g = chain_graph([1e6] * 8)
    p = partition(g, 2, cuts=(4,), replicas=(1, 2))
    assert p.replicas == (1, 2)
    s0, s1 = p.stages
    assert s1.throughput_service_s == pytest.approx(s1.service_time_s / 2)
    assert s0.throughput_service_s == pytest.approx(s0.service_time_s)
    # per-request bottleneck is replica-blind; the throughput one amortizes
    assert p.bottleneck_s == max(s0.service_time_s, s1.service_time_s)
    assert p.throughput_bottleneck_s <= p.bottleneck_s
    with pytest.raises(ValueError):
        partition(g, 2, cuts=(4,), replicas=(1, 2, 3))


def test_calibrated_replica_pricing():
    costs = _costs([1.0] * 8, enc=0.1, dec=0.1)
    one = costs.stage_service_s(0, 4)
    assert costs.stage_service_s(0, 4, replicas=2) == pytest.approx(one / 2)
    # bounds_bottleneck prices the replicated topology
    b = [0, 4, 8]
    assert bounds_bottleneck(costs, b, replicas=[2, 2]) == pytest.approx(
        bounds_bottleneck(costs, b) / 2)


def test_calibrated_dp_leans_layers_into_replicated_stage():
    """With stage 1 at 2 replicas, the replica-aware DP hands it ~2x the
    layers of stage 0 — a replica-blind plan would split evenly."""
    costs = _costs([1.0] * 9)
    blind, _ = calibrated_partition(costs, 2)
    aware, aware_b = calibrated_partition(costs, 2, replicas=[1, 2])
    assert blind[1] in (4, 5)
    assert aware[1] == 3                       # 3 layers vs 6/2 = 3 each
    assert aware_b == pytest.approx(3.0)
    # and the replica-aware plan is optimal under the replica ruler
    best = min(bounds_bottleneck(costs, [0, c, 9], replicas=[1, 2])
               for c in range(1, 9))
    assert aware_b <= best + 1e-12


def test_resnet_partition_reassembly_exact():
    from repro.models.cnn import resnet50
    g = resnet50(batch=1)
    params = g.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 224, 224, 3))
    full = g.apply(params, x)
    p = partition(g, 6, strategy="balanced_latency")
    acts = {"": x}
    out = None
    for lo, hi in p.ranges():
        nodes = g.slice_nodes(lo, hi)
        sub = {n: acts[n] for n in
               (g.crossing_names(lo - 1) if lo else [""])}
        for node in nodes:
            args = [sub[i] for i in node.inputs]
            sub[node.name] = node.fn(params[node.name], *args)
        exported = (g.crossing_names(hi - 1) if hi < len(g.nodes)
                    else [g.nodes[-1].name])
        acts.update({n: sub[n] for n in exported})
        out = sub[g.nodes[hi - 1].name]
    np.testing.assert_allclose(np.asarray(out), np.asarray(full), atol=1e-5)
