"""Chaos drills: kill/hang/sever worker processes under closed-loop load
and prove the serving contract — a fault fails exactly the batches it
stranded (NodeError, never a hang), the chain keeps answering on the
survivors, the supervisor respawns within its backoff window, an
exhausted respawn budget degrades instead of wedging or storming, and a
full kill/respawn/kill cycle resolves every single future.

All tests here spawn real worker processes (SupervisorConfig with
``allow_chaos=True``) and are marked slow; the fast smoke lives in
test_supervisor.py."""
import os
import signal
import threading
import time

import jax
import numpy as np
import pytest

from repro.runtime import NodeError, TopologySpec
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.supervisor import (SupervisorConfig, WorkerHandle,
                                      supervised_engine)
from repro.runtime.wire import WireCodec
from tests._worker_graphs import mlp_graph
from tools.chaos import Chaos

pytestmark = pytest.mark.slow

GRAPHS = os.path.join(os.path.dirname(__file__), "_worker_graphs.py")
RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))
D = 16


def _cfg(**kw):
    kw.setdefault("graph_factory", GRAPHS + ":mlp_graph")
    kw.setdefault("heartbeat_s", 0.1)
    kw.setdefault("heartbeat_timeout_s", 5.0)
    kw.setdefault("backoff_initial_s", 0.1)
    kw.setdefault("backoff_max_s", 0.5)
    kw.setdefault("shutdown_grace_s", 5.0)
    kw.setdefault("allow_chaos", True)
    return SupervisorConfig(**kw)


def _build(cfg, replicas=2, **engine_kw):
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    topo = TopologySpec.chain(g, 2).with_replicas(0, replicas)
    engine_kw.setdefault("codecs", RAW)
    engine_kw.setdefault("max_batch", 4)
    eng, sup = supervised_engine(g, params, topo, cfg, **engine_kw)
    return g, params, eng, sup


class _Load:
    """Closed-loop clients: each keeps exactly one request in flight.
    Every future must resolve — with a value or a NodeError; anything
    else (timeout, foreign exception) is a hang/contract violation."""

    def __init__(self, eng, clients=4, timeout=60.0):
        self.eng = eng
        self.timeout = timeout
        self.ok = 0
        self.failed = 0
        self.violations: list[str] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads = [threading.Thread(target=self._run, args=(c,),
                                          daemon=True)
                         for c in range(clients)]

    def __enter__(self):
        for t in self._threads:
            t.start()
        return self

    def __exit__(self, *exc):
        self.stop()

    def _run(self, cid):
        i = 0
        while not self._stop.is_set():
            x = np.random.default_rng(i).normal(size=(1, D)) \
                .astype(np.float32)
            f = self.eng.submit(x, client_id=f"c{cid}")
            try:
                f.result(timeout=self.timeout)
                with self._lock:
                    self.ok += 1
            except NodeError:
                with self._lock:
                    self.failed += 1
            except Exception as e:     # noqa: BLE001 - the assertion itself
                with self._lock:
                    self.violations.append(f"{type(e).__name__}: {e}")
                return
            i += 1

    def stop(self):
        self._stop.set()
        for t in self._threads:
            t.join(self.timeout + 30)
        assert not any(t.is_alive() for t in self._threads), \
            "load client hung: some future never resolved"
        assert not self.violations, \
            f"futures must resolve with a value or NodeError: " \
            f"{self.violations}"


def test_kill_mid_batch_fails_only_stranded_chain_keeps_serving():
    """SIGKILL one of two stage-0 worker processes mid-batch under
    closed-loop load: the stranded batches fail with NodeError, nothing
    hangs, the chain keeps answering on the survivor, and the supervisor
    respawns the replica within the backoff window."""
    g, params, eng, sup = _build(_cfg())
    chaos = Chaos(sup)
    try:
        eng.start()
        # dwell in compute so the kill lands mid-batch, not between them
        for h in chaos.workers(stage=0):
            chaos.slow_compute(h, 0.05)
        with _Load(eng) as load:
            deadline = time.monotonic() + 20
            while load.ok < 20 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert load.ok >= 20, "load never ramped"
            chaos.kill(chaos.pick(stage=0))
            death = chaos.wait_death(stage=0, timeout=30)[0]
            assert "exited" in death["why"]
            # respawn within the backoff window: budget 3 x max 0.5s
            # backoff, plus spawn+configure time — call it 30s, not minutes
            chaos.wait_respawn(stage=0, timeout=30)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=30) == 2
        # closed-loop accounting: everything resolved, the kill cost at
        # most the batches inside the dead worker's pipeline
        assert load.failed <= 4 * eng.dispatcher._defaults["queue_depth"]
        # and the healed chain still answers with reference numerics
        x = np.random.default_rng(7).normal(size=(1, D)).astype(np.float32)
        np.testing.assert_allclose(
            eng.submit(x).result(timeout=60),
            np.asarray(g.apply(params, x)), atol=1e-5)
    finally:
        eng.shutdown()
        sup.close()


def test_kill_respawn_kill_cycle_zero_hangs():
    """Two kills with a respawn between them, all under load: every
    future resolves (ok or NodeError), both deaths heal, and shutdown is
    clean — the full cycle the self-healing loop must survive."""
    g, params, eng, sup = _build(_cfg())
    chaos = Chaos(sup)
    try:
        eng.start()
        with _Load(eng) as load:
            for round_no in (1, 2):
                deadline = time.monotonic() + 20
                base = load.ok
                while load.ok < base + 10 and time.monotonic() < deadline:
                    time.sleep(0.02)
                chaos.kill(chaos.pick(stage=0))
                chaos.wait_death(stage=0, count=round_no, timeout=30)
                chaos.wait_respawn(stage=0, count=round_no, timeout=30)
                assert chaos.wait_stage_full(eng.dispatcher, 0,
                                             timeout=30) == 2
        assert load.ok > 0
    finally:
        eng.shutdown()
        sup.close()
    kinds = [e["kind"] for e in sup.events]
    assert kinds.count("death") >= 2 and kinds.count("respawn") >= 2
    assert "degraded" not in kinds


def test_respawn_budget_exhaustion_degrades_not_wedges():
    """With a budget of 1, the second kill exhausts it: the supervisor
    records a degrade, stops respawning (no storm), and the stage keeps
    serving on its survivor — no wedge, no hang."""
    g, params, eng, sup = _build(_cfg(respawn_budget=1, stable_s=3600.0))
    chaos = Chaos(sup)
    try:
        eng.start()
        x = np.random.default_rng(0).normal(size=(1, D)).astype(np.float32)
        ref = np.asarray(g.apply(params, x))
        chaos.kill(chaos.pick(stage=0))
        chaos.wait_respawn(stage=0, timeout=30)
        chaos.wait_stage_full(eng.dispatcher, 0, timeout=30)
        chaos.kill(chaos.pick(stage=0))
        chaos.wait_event("degraded", stage=0, timeout=30)
        # degraded, not dead: the survivor answers
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
        live = [r for r in eng.dispatcher.stages[0].live_replicas()
                if not r.retiring]
        assert len(live) == 1
        # no respawn storm after the degrade
        time.sleep(2.0)
        assert len(chaos.events("respawn", stage=0)) == 1
    finally:
        eng.shutdown()
        sup.close()


def test_sigkill_during_spawn_fence(monkeypatch):
    """The nastiest window: the replacement worker is killed right after
    start(), while the membership fence that admits it is in flight.
    The heal path must proxy the dead newcomer's fence share (scale()
    un-wedges), and the NEXT respawn attempt restores the stage."""
    g, params, eng, sup = _build(_cfg(spawn_timeout_s=15.0))
    chaos = Chaos(sup)
    kill_next = threading.Event()
    orig_start = WorkerHandle.start

    def start_then_die(self):
        orig_start(self)
        if kill_next.is_set():
            kill_next.clear()
            os.kill(self.proc.pid, signal.SIGKILL)

    monkeypatch.setattr(WorkerHandle, "start", start_then_die)
    try:
        eng.start()
        kill_next.set()     # arms for the NEXT spawn: the respawned worker
        chaos.kill(chaos.pick(stage=0))
        # two deaths: the original kill, then the fence-window kill
        chaos.wait_death(stage=0, count=2, timeout=60)
        assert chaos.wait_stage_full(eng.dispatcher, 0, timeout=60) == 2
        x = np.random.default_rng(0).normal(size=(1, D)).astype(np.float32)
        np.testing.assert_allclose(
            eng.submit(x).result(timeout=60),
            np.asarray(g.apply(params, x)), atol=1e-5)
    finally:
        eng.shutdown()
        sup.close()


def test_slow_but_alive_worker_is_not_falsely_killed():
    """A worker whose compute is dilated way past the heartbeat timeout
    is SLOW, not DEAD: its heartbeat thread stays healthy, so failure
    detection must not page — zero deaths, all futures resolve."""
    g, params, eng, sup = _build(
        _cfg(heartbeat_timeout_s=0.5, stall_timeout_s=None))
    chaos = Chaos(sup)
    try:
        eng.start()
        for h in chaos.workers(stage=0):
            chaos.slow_compute(h, 0.8)      # >> heartbeat_timeout_s
        xs = [np.random.default_rng(i).normal(size=(1, D))
              .astype(np.float32) for i in range(6)]
        outs = [eng.submit(x) for x in xs]
        for x, f in zip(xs, outs):
            np.testing.assert_allclose(
                f.result(timeout=60),
                np.asarray(g.apply(params, x)), atol=1e-5)
        assert not chaos.events("death"), \
            "slow-but-alive worker was falsely declared dead"
    finally:
        eng.shutdown()
        sup.close()


def test_hung_compute_caught_by_stall_detection():
    """The inverse failure mode: a wedged compute thread with a healthy
    heartbeat.  Heartbeat-age detection can never fire; stall detection
    (snapshot frozen + inbox backlog) must kill and heal it, failing the
    wedged batches with NodeError and respawning the replica."""
    g, params, eng, sup = _build(_cfg(stall_timeout_s=1.0))
    chaos = Chaos(sup)
    try:
        eng.start()
        victim = chaos.pick(stage=0)
        chaos.hang_compute(victim)
        with _Load(eng, clients=4):
            death = chaos.wait_death(stage=0, timeout=60)[0]
            assert "stalled" in death["why"]
            chaos.wait_respawn(stage=0, timeout=60)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=60) == 2
    finally:
        eng.shutdown()
        sup.close()


def test_severed_sockets_heal_like_a_crash():
    """Cut a worker's data sockets mid-batch (flaky link, process still
    alive): the routers heal exactly as for a crash, the monitor retires
    the unreachable orphan and respawns it, and the chain keeps serving
    throughout — no hang."""
    g, params, eng, sup = _build(_cfg())
    chaos = Chaos(sup)
    try:
        eng.start()
        for h in chaos.workers(stage=0):
            chaos.slow_compute(h, 0.05)
        with _Load(eng) as load:
            deadline = time.monotonic() + 20
            while load.ok < 10 and time.monotonic() < deadline:
                time.sleep(0.02)
            chaos.sever(chaos.pick(stage=0))
            death = chaos.wait_death(stage=0, timeout=30)[0]
            assert "severed" in death["why"]
            chaos.wait_respawn(stage=0, timeout=30)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=30) == 2
            # the chain kept serving across the whole failover
            base = load.ok
            deadline = time.monotonic() + 30
            while load.ok < base + 10 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert load.ok >= base + 10, \
                "chain stopped serving after a severed link"
    finally:
        eng.shutdown()
        sup.close()
