"""Chaos drills: kill/hang/sever worker processes under closed-loop load
and prove the serving contract — a fault fails exactly the batches it
stranded (NodeError, never a hang), the chain keeps answering on the
survivors, the supervisor respawns within its backoff window, an
exhausted respawn budget degrades instead of wedging or storming, and a
full kill/respawn/kill cycle resolves every single future.

All tests here spawn real worker processes (SupervisorConfig with
``allow_chaos=True``) and are marked slow; the fast smoke lives in
test_supervisor.py."""
import os
import signal
import threading
import time

import jax
import numpy as np
import pytest

from repro.runtime import NodeError, TopologySpec
from repro.runtime.dispatcher import (DeadlineExceeded, DispatcherCodecs,
                                      RetryPolicy)
from repro.runtime.supervisor import (SupervisorConfig, WorkerHandle,
                                      supervised_engine)
from repro.runtime.wire import WireCodec
from repro.models.lm_graph import pipeline_decode_reference
from tests._worker_graphs import POISON, lm_graph, mlp_graph, poison_graph
from tools.chaos import Chaos

pytestmark = pytest.mark.slow

GRAPHS = os.path.join(os.path.dirname(__file__), "_worker_graphs.py")
RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))
D = 16


def _cfg(**kw):
    kw.setdefault("graph_factory", GRAPHS + ":mlp_graph")
    kw.setdefault("heartbeat_s", 0.1)
    kw.setdefault("heartbeat_timeout_s", 5.0)
    kw.setdefault("backoff_initial_s", 0.1)
    kw.setdefault("backoff_max_s", 0.5)
    kw.setdefault("shutdown_grace_s", 5.0)
    kw.setdefault("allow_chaos", True)
    return SupervisorConfig(**kw)


def _build(cfg, replicas=2, graph=mlp_graph, **engine_kw):
    g = graph()
    params = g.init(jax.random.PRNGKey(0))
    topo = TopologySpec.chain(g, 2).with_replicas(0, replicas)
    engine_kw.setdefault("codecs", RAW)
    engine_kw.setdefault("max_batch", 4)
    eng, sup = supervised_engine(g, params, topo, cfg, **engine_kw)
    return g, params, eng, sup


class _Load:
    """Closed-loop clients: each keeps exactly one request in flight.
    Every future must resolve — with a value or a NodeError; anything
    else (timeout, foreign exception) is a hang/contract violation."""

    def __init__(self, eng, clients=4, timeout=60.0, ref=None):
        self.eng = eng
        self.timeout = timeout
        self.ref = ref          # optional x -> expected output (numerics)
        self.ok = 0
        self.failed = 0
        self.violations: list[str] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads = [threading.Thread(target=self._run, args=(c,),
                                          daemon=True)
                         for c in range(clients)]

    def __enter__(self):
        for t in self._threads:
            t.start()
        return self

    def __exit__(self, *exc):
        self.stop()

    def _run(self, cid):
        i = 0
        while not self._stop.is_set():
            x = np.random.default_rng(i).normal(size=(1, D)) \
                .astype(np.float32)
            f = self.eng.submit(x, client_id=f"c{cid}")
            try:
                out = f.result(timeout=self.timeout)
                if self.ref is not None and not np.allclose(
                        out, self.ref(x), atol=1e-5):
                    with self._lock:
                        self.violations.append(
                            f"numerically wrong output for client {cid} "
                            f"request {i}")
                    return
                with self._lock:
                    self.ok += 1
            except NodeError:
                with self._lock:
                    self.failed += 1
            except Exception as e:     # noqa: BLE001 - the assertion itself
                with self._lock:
                    self.violations.append(f"{type(e).__name__}: {e}")
                return
            i += 1

    def stop(self):
        self._stop.set()
        for t in self._threads:
            t.join(self.timeout + 30)
        assert not any(t.is_alive() for t in self._threads), \
            "load client hung: some future never resolved"
        assert not self.violations, \
            f"futures must resolve with a value or NodeError: " \
            f"{self.violations}"


def test_kill_mid_batch_fails_only_stranded_chain_keeps_serving():
    """SIGKILL one of two stage-0 worker processes mid-batch under
    closed-loop load: the stranded batches fail with NodeError, nothing
    hangs, the chain keeps answering on the survivor, and the supervisor
    respawns the replica within the backoff window."""
    g, params, eng, sup = _build(_cfg())
    chaos = Chaos(sup)
    try:
        eng.start()
        # dwell in compute so the kill lands mid-batch, not between them
        for h in chaos.workers(stage=0):
            chaos.slow_compute(h, 0.05)
        with _Load(eng) as load:
            deadline = time.monotonic() + 20
            while load.ok < 20 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert load.ok >= 20, "load never ramped"
            chaos.kill(chaos.pick(stage=0))
            death = chaos.wait_death(stage=0, timeout=30)[0]
            assert "exited" in death["why"]
            # respawn within the backoff window: budget 3 x max 0.5s
            # backoff, plus spawn+configure time — call it 30s, not minutes
            chaos.wait_respawn(stage=0, timeout=30)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=30) == 2
        # closed-loop accounting: everything resolved, the kill cost at
        # most the batches inside the dead worker's pipeline
        assert load.failed <= 4 * eng.dispatcher._defaults["queue_depth"]
        # and the healed chain still answers with reference numerics
        x = np.random.default_rng(7).normal(size=(1, D)).astype(np.float32)
        np.testing.assert_allclose(
            eng.submit(x).result(timeout=60),
            np.asarray(g.apply(params, x)), atol=1e-5)
    finally:
        eng.shutdown()
        sup.close()


def test_kill_respawn_kill_cycle_zero_hangs():
    """Two kills with a respawn between them, all under load: every
    future resolves (ok or NodeError), both deaths heal, and shutdown is
    clean — the full cycle the self-healing loop must survive."""
    g, params, eng, sup = _build(_cfg())
    chaos = Chaos(sup)
    try:
        eng.start()
        with _Load(eng) as load:
            for round_no in (1, 2):
                deadline = time.monotonic() + 20
                base = load.ok
                while load.ok < base + 10 and time.monotonic() < deadline:
                    time.sleep(0.02)
                chaos.kill(chaos.pick(stage=0))
                chaos.wait_death(stage=0, count=round_no, timeout=30)
                chaos.wait_respawn(stage=0, count=round_no, timeout=30)
                assert chaos.wait_stage_full(eng.dispatcher, 0,
                                             timeout=30) == 2
        assert load.ok > 0
    finally:
        eng.shutdown()
        sup.close()
    kinds = [e["kind"] for e in sup.events]
    assert kinds.count("death") >= 2 and kinds.count("respawn") >= 2
    assert "degraded" not in kinds


def test_respawn_budget_exhaustion_degrades_not_wedges():
    """With a budget of 1, the second kill exhausts it: the supervisor
    records a degrade, stops respawning (no storm), and the stage keeps
    serving on its survivor — no wedge, no hang."""
    g, params, eng, sup = _build(_cfg(respawn_budget=1, stable_s=3600.0))
    chaos = Chaos(sup)
    try:
        eng.start()
        x = np.random.default_rng(0).normal(size=(1, D)).astype(np.float32)
        ref = np.asarray(g.apply(params, x))
        chaos.kill(chaos.pick(stage=0))
        chaos.wait_respawn(stage=0, timeout=30)
        chaos.wait_stage_full(eng.dispatcher, 0, timeout=30)
        chaos.kill(chaos.pick(stage=0))
        chaos.wait_event("degraded", stage=0, timeout=30)
        # degraded, not dead: the survivor answers
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
        live = [r for r in eng.dispatcher.stages[0].live_replicas()
                if not r.retiring]
        assert len(live) == 1
        # no respawn storm after the degrade
        time.sleep(2.0)
        assert len(chaos.events("respawn", stage=0)) == 1
    finally:
        eng.shutdown()
        sup.close()


def test_sigkill_during_spawn_fence(monkeypatch):
    """The nastiest window: the replacement worker is killed right after
    start(), while the membership fence that admits it is in flight.
    The heal path must proxy the dead newcomer's fence share (scale()
    un-wedges), and the NEXT respawn attempt restores the stage."""
    g, params, eng, sup = _build(_cfg(spawn_timeout_s=15.0))
    chaos = Chaos(sup)
    kill_next = threading.Event()
    orig_start = WorkerHandle.start

    def start_then_die(self):
        orig_start(self)
        if kill_next.is_set():
            kill_next.clear()
            os.kill(self.proc.pid, signal.SIGKILL)

    monkeypatch.setattr(WorkerHandle, "start", start_then_die)
    try:
        eng.start()
        kill_next.set()     # arms for the NEXT spawn: the respawned worker
        chaos.kill(chaos.pick(stage=0))
        # two deaths: the original kill, then the fence-window kill
        chaos.wait_death(stage=0, count=2, timeout=60)
        assert chaos.wait_stage_full(eng.dispatcher, 0, timeout=60) == 2
        x = np.random.default_rng(0).normal(size=(1, D)).astype(np.float32)
        np.testing.assert_allclose(
            eng.submit(x).result(timeout=60),
            np.asarray(g.apply(params, x)), atol=1e-5)
    finally:
        eng.shutdown()
        sup.close()


def test_slow_but_alive_worker_is_not_falsely_killed():
    """A worker whose compute is dilated way past the heartbeat timeout
    is SLOW, not DEAD: its heartbeat thread stays healthy, so failure
    detection must not page — zero deaths, all futures resolve."""
    g, params, eng, sup = _build(
        _cfg(heartbeat_timeout_s=0.5, stall_timeout_s=None))
    chaos = Chaos(sup)
    try:
        eng.start()
        for h in chaos.workers(stage=0):
            chaos.slow_compute(h, 0.8)      # >> heartbeat_timeout_s
        xs = [np.random.default_rng(i).normal(size=(1, D))
              .astype(np.float32) for i in range(6)]
        outs = [eng.submit(x) for x in xs]
        for x, f in zip(xs, outs):
            np.testing.assert_allclose(
                f.result(timeout=60),
                np.asarray(g.apply(params, x)), atol=1e-5)
        assert not chaos.events("death"), \
            "slow-but-alive worker was falsely declared dead"
    finally:
        eng.shutdown()
        sup.close()


def test_hung_compute_caught_by_stall_detection():
    """The inverse failure mode: a wedged compute thread with a healthy
    heartbeat.  Heartbeat-age detection can never fire; stall detection
    (snapshot frozen + inbox backlog) must kill and heal it, failing the
    wedged batches with NodeError and respawning the replica."""
    g, params, eng, sup = _build(_cfg(stall_timeout_s=1.0))
    chaos = Chaos(sup)
    try:
        eng.start()
        victim = chaos.pick(stage=0)
        chaos.hang_compute(victim)
        with _Load(eng, clients=4):
            death = chaos.wait_death(stage=0, timeout=60)[0]
            assert "stalled" in death["why"]
            chaos.wait_respawn(stage=0, timeout=60)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=60) == 2
    finally:
        eng.shutdown()
        sup.close()


def test_kill_with_replay_zero_client_visible_failures():
    """THE replay contract: SIGKILL one of two stage-0 workers mid-batch
    under closed-loop load WITH a retry policy — every submitted future
    resolves with a numerically correct output.  Zero NodeErrors reach a
    client (the stranded batches are re-admitted through the healed
    routing set), zero hangs, and the replay counters show it actually
    happened rather than the kill landing between batches."""
    g, params, eng, sup = _build(
        _cfg(), retry_policy=RetryPolicy(max_attempts=5, backoff_s=0.05,
                                         retry_budget=64.0,
                                         refill_per_s=32.0))
    chaos = Chaos(sup)
    ref = lambda x: np.asarray(g.apply(params, x))   # noqa: E731
    try:
        eng.start()
        chaos.slow_stage(0, 0.05)   # dwell in compute: kill lands mid-batch
        with _Load(eng, ref=ref) as load:
            deadline = time.monotonic() + 20
            while load.ok < 20 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert load.ok >= 20, "load never ramped"
            chaos.kill(chaos.pick(stage=0))
            chaos.wait_death(stage=0, timeout=30)
            chaos.wait_respawn(stage=0, timeout=30)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=30) == 2
            # keep serving across the heal so replayed work interleaves
            # with fresh admissions
            base = load.ok
            deadline = time.monotonic() + 30
            while load.ok < base + 10 and time.monotonic() < deadline:
                time.sleep(0.02)
        assert load.failed == 0, \
            f"{load.failed} client-visible failures despite replay"
        st = eng.dispatcher.replay_stats
        assert st.replays >= 1, "kill landed between batches: no replay " \
            f"exercised ({st})"
    finally:
        eng.shutdown()
        sup.close()


def test_application_error_is_not_retried():
    """A poison input makes user apply() raise — an APPLICATION error.
    With a generous retry policy the future must still fail with
    NodeError after exactly one attempt (zero replays): retrying
    deterministic user errors would burn budget and double-charge
    side-effecting layers."""
    g, params, eng, sup = _build(
        _cfg(graph_factory=GRAPHS + ":poison_graph"), graph=poison_graph,
        retry_policy=RetryPolicy(max_attempts=5, retry_budget=64.0))
    try:
        eng.start()
        x = np.random.default_rng(0).normal(size=(1, D)).astype(np.float32)
        ref = np.asarray(g.apply(params, x))
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
        bad = x.copy()
        bad[0, 0] = POISON
        with pytest.raises(NodeError, match="poison pill"):
            eng.submit(bad).result(timeout=60)
        st = eng.dispatcher.replay_stats
        assert st.replays == 0, \
            f"application error was replayed ({st})"
        # the chain is unharmed: clean requests still serve
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
    finally:
        eng.shutdown()
        sup.close()


def test_retry_budget_exhaustion_degrades_to_fail_fast():
    """A zero-token bucket (budget 0, no refill) means every would-be
    replay is refused: the kill behaves exactly like PR 7 fail-fast —
    stranded futures fail with NodeError, nothing hangs, and the denial
    is visible in the counters.  This is the crash-storm valve: when
    replays can't be afforded, the layer degrades instead of amplifying
    load."""
    g, params, eng, sup = _build(
        _cfg(), retry_policy=RetryPolicy(max_attempts=5, retry_budget=0.0,
                                         refill_per_s=0.0))
    chaos = Chaos(sup)
    try:
        eng.start()
        chaos.slow_stage(0, 0.05)
        with _Load(eng) as load:
            deadline = time.monotonic() + 20
            while load.ok < 10 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert load.ok >= 10, "load never ramped"
            chaos.kill(chaos.pick(stage=0))
            chaos.wait_death(stage=0, timeout=30)
            chaos.wait_respawn(stage=0, timeout=30)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=30) == 2
        # _Load.stop() (in __exit__) already asserted zero hangs; the
        # kill's stranded batches surfaced as NodeError because the
        # bucket refused their replay
        st = eng.dispatcher.replay_stats
        assert st.budget_denied >= 1, f"no replay was ever denied ({st})"
        assert st.replays == 0, f"replay happened on a dry bucket ({st})"
        assert load.failed >= 1, "kill landed between batches: " \
            "fail-fast degradation not exercised"
    finally:
        eng.shutdown()
        sup.close()


def test_deadline_expires_on_hung_worker_in_bounded_time():
    """Wedge EVERY stage-0 worker (healthy heartbeats, nothing to route
    around) and submit with a deadline: the future must fail with
    DeadlineExceeded in bounded time — the reaper's monotonic clock, not
    stall detection, is what unblocks the client.  Stall detection is
    configured slower than the deadline so the heal demonstrably loses
    the race; it then recovers the stage for a clean shutdown."""
    g, params, eng, sup = _build(_cfg(stall_timeout_s=2.0))
    chaos = Chaos(sup)
    try:
        eng.start()
        x = np.random.default_rng(0).normal(size=(1, D)).astype(np.float32)
        ref = np.asarray(g.apply(params, x))
        # warm the chain so the hang catches a steady state
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
        assert chaos.hang_stage(0) == 2
        t0 = time.monotonic()
        # two requests: lqd spreads them across both wedged workers
        futs = [eng.submit(x, client_id=c, deadline_s=0.5)
                for c in ("da", "db")]
        for fut in futs:
            with pytest.raises(DeadlineExceeded):
                fut.result(timeout=30)
        took = time.monotonic() - t0
        assert took < 10.0, f"deadline took {took:.1f}s to fire"
        assert eng.dispatcher.replay_stats.deadlines_expired >= 2
        # let stall detection heal the wedged stage before teardown —
        # closed-loop load builds the inbox backlog stall detection keys
        # on (their stranded futures legally fail with NodeError)
        with _Load(eng):
            chaos.wait_death(stage=0, count=2, timeout=60)
            chaos.wait_respawn(stage=0, count=2, timeout=60)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=60) == 2
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
    finally:
        eng.shutdown()
        sup.close()


def test_kill_mid_generation_sessions_reprefill_chain_keeps_serving():
    """SIGKILL one of two stage-0 worker processes while decode sessions
    are mid-generation: the victims' resident KV caches die with it, the
    displaced sessions re-prefill their retained history onto the
    survivor (restart='auto' + RetryPolicy) and finish BIT-IDENTICAL to
    the single-device reference, sessions pinned to the survivor never
    notice, the supervisor respawns the replica, and single-shot traffic
    still answers afterwards — no hangs anywhere."""
    g, params, eng, sup = _build(
        _cfg(graph_factory=GRAPHS + ":lm_graph"), graph=lm_graph,
        retry_policy=RetryPolicy(max_attempts=5, backoff_s=0.05,
                                 retry_budget=64.0, refill_per_s=32.0))
    chaos = Chaos(sup)
    prompts = [[1, 5, 9, 2], [3, 3, 7], [2, 8, 4, 6, 1]]
    m = 30
    outs = [[] for _ in prompts]
    errs: list[BaseException] = []

    def one(i, p):
        try:
            for tok in eng.generate(p, m):
                outs[i].append(tok)
        except BaseException as e:      # noqa: BLE001 - asserted below
            errs.append(e)

    try:
        eng.start()
        threads = [threading.Thread(target=one, args=(i, p))
                   for i, p in enumerate(prompts)]
        for t in threads:
            t.start()
        deadline = time.monotonic() + 120
        while not all(len(o) >= 3 for o in outs):
            assert time.monotonic() < deadline, \
                f"sessions never ramped: {[len(o) for o in outs]}"
            assert not errs, f"session died before the kill: {errs}"
            time.sleep(0.01)
        chaos.kill(chaos.pick(stage=0))
        chaos.wait_death(stage=0, timeout=30)
        for t in threads:
            t.join(300)
        assert not any(t.is_alive() for t in threads), "generation hung"
        assert not errs, f"sessions dropped across the kill: {errs}"
        assert outs == [pipeline_decode_reference(g, params, p, m)
                        for p in prompts]
        # the stage heals, and plain single-shot traffic still answers
        chaos.wait_respawn(stage=0, timeout=30)
        assert chaos.wait_stage_full(eng.dispatcher, 0, timeout=30) == 2
        x = np.asarray([prompts[0]], np.int32)
        np.testing.assert_allclose(
            eng.submit(x).result(timeout=60),
            np.asarray(g.apply(params, x)), atol=1e-4)
    finally:
        eng.shutdown()
        sup.close()


def test_severed_sockets_heal_like_a_crash():
    """Cut a worker's data sockets mid-batch (flaky link, process still
    alive): the routers heal exactly as for a crash, the monitor retires
    the unreachable orphan and respawns it, and the chain keeps serving
    throughout — no hang."""
    g, params, eng, sup = _build(_cfg())
    chaos = Chaos(sup)
    try:
        eng.start()
        for h in chaos.workers(stage=0):
            chaos.slow_compute(h, 0.05)
        with _Load(eng) as load:
            deadline = time.monotonic() + 20
            while load.ok < 10 and time.monotonic() < deadline:
                time.sleep(0.02)
            chaos.sever(chaos.pick(stage=0))
            death = chaos.wait_death(stage=0, timeout=30)[0]
            assert "severed" in death["why"]
            chaos.wait_respawn(stage=0, timeout=30)
            assert chaos.wait_stage_full(eng.dispatcher, 0,
                                         timeout=30) == 2
            # the chain kept serving across the whole failover
            base = load.ok
            deadline = time.monotonic() + 30
            while load.ok < base + 10 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert load.ok >= base + 10, \
                "chain stopped serving after a severed link"
    finally:
        eng.shutdown()
        sup.close()
