"""End-to-end system behaviour: emulator vs paper claims, registry
coverage, dry-run machinery (single cheap pair in a subprocess), sharding
rules."""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.registry import (ARCHS, LONG_CONTEXT_OK, all_pairs,
                                    get_config, pair_supported)


def test_registry_covers_all_assigned():
    assert len(ARCHS) == 10
    families = {get_config(a).family for a in ARCHS}
    assert {"dense", "moe", "ssm", "hybrid", "vlm", "audio"} <= families


def test_all_pairs_is_40_with_design_skips():
    pairs = all_pairs()
    assert len(pairs) == 40
    skips = [p for p in pairs if not pair_supported(*p)[0]]
    # long_500k skipped exactly for the non-sub-quadratic archs
    assert {a for a, s in skips} == set(ARCHS) - LONG_CONTEXT_OK
    assert all(s == "long_500k" for _, s in skips)


def test_emulator_reproduces_paper_scaling():
    """DEFER's Fig-2/Fig-3 claims on our emulated chain: ResNet50 with 8
    nodes beats single-device throughput; per-node energy drops with more
    nodes."""
    from repro.core.emulator import CodecConfig, emulate
    from repro.models.cnn import resnet50
    g = resnet50(batch=1)
    cfg = CodecConfig(serializer="zfp", compression="none", zfp_rate=16)
    reports = {n: emulate(g, n, cfg) for n in (4, 6, 8)}
    r8 = reports[8]
    assert r8.speedup > 1.0, f"8-node speedup {r8.speedup:.2f}"
    # per-node energy decreases monotonically with more nodes
    e = [reports[n].per_node_energy_j for n in (4, 6, 8)]
    assert e[2] < e[1] < e[0]
    assert reports[8].per_node_energy_j < reports[8].single_device_energy_j


def test_emulator_codec_table_ordering():
    """Table II: ZFP beats JSON for inter-node data payload."""
    from repro.core.emulator import CodecConfig, emulate
    from repro.models.cnn import resnet50
    g = resnet50(batch=1)
    zfp = emulate(g, 4, CodecConfig("zfp", "none", 16))
    js = emulate(g, 4, CodecConfig("json", "none"))
    assert zfp.total_payload_mb < js.total_payload_mb


def test_sharding_rules_cover_every_param():
    """Every full-config param leaf gets a valid spec with axes only on
    divisible dims (16-way model axis)."""
    from repro.launch import specs as S
    from repro.sharding import param_pspecs
    for arch in ["dbrx-132b", "mamba2-2.7b", "granite-34b", "gemma3-4b"]:
        cfg = get_config(arch)
        ab = S.abstract_params(cfg)
        specs = param_pspecs(ab)
        flat_p = jax.tree_util.tree_leaves(ab)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for dim, ax in enumerate(spec):
                if ax == "model":
                    assert leaf.shape[dim] % 16 == 0, \
                        (arch, leaf.shape, dim, spec)


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %p = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p), replica_groups={}
  %ar.1 = bf16[8,8]{1,0} all-reduce(%q), to_apply=%sum
  %q = bf16[8,8]{1,0} add(%p, %p)
  %cp = f32[4]{0} collective-permute(%r), source_target_pairs={{0,1}}
  %r = f32[4]{0} constant(0)
  %done = f32[4]{0} all-reduce-done(%start)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 8 * 8 * 2
    assert out["collective-permute"] == 4 * 4


_DRYRUN_SMOKE = r"""
from repro.launch.dryrun import dryrun_pair
art = dryrun_pair("starcoder2-3b", "prefill_32k", multi_pod=False,
                  verbose=False)
assert art["status"] == "ok", art
assert art["chips"] == 256
assert art["cost"]["flops"] > 1e9
art2 = dryrun_pair("starcoder2-3b", "prefill_32k", multi_pod=True,
                   verbose=False, with_cost=False)
assert art2["status"] == "ok" and art2["chips"] == 512
print("OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_subprocess():
    """One cheap pair through the full dry-run path on both meshes."""
    r = subprocess.run(
        [sys.executable, "-c", _DRYRUN_SMOKE],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_cost_extrapolation_linear_on_synthetic():
    from repro.launch.dryrun import _extrapolate
    mk = lambda u: {"flops": 10 + 3 * u, "bytes_accessed": 5 + 2 * u,
                    "transcendentals": u * 1.0,
                    "collective_bytes": {"all-reduce": 100 * u}}
    out = _extrapolate(mk(2), mk(4), 32)
    assert abs(out["flops"] - (10 + 3 * 32)) < 1e-6
    assert out["collective_bytes"]["all-reduce"] == 3200
