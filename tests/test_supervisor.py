"""Process-per-replica supervision: control-frame wire round-trips,
cross-process channel completion (expect/dial), the half-open-hello
accept guard, worker graph-factory resolution, spawn-failure cleanup,
and a fast end-to-end smoke over real worker processes — the quick legs;
the long chaos drills live in test_chaos.py."""
import os
import socket
import struct
import threading
import time

import jax
import numpy as np
import pytest

from repro.runtime import NodeError, TopologySpec
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.supervisor import (Supervisor, SupervisorConfig,
                                      supervised_engine)
from repro.runtime.transport import (ChannelClosed, TcpTransport,
                                     dial_channel, recv_framed, send_framed)
from repro.runtime.wire import (FRAME_VERSION, BatchEnvelope, ControlFrame,
                                RowExtent, WireCodec, frame, unframe)
from repro.runtime.worker import load_graph_factory
from tests._worker_graphs import mlp_graph

GRAPHS = os.path.join(os.path.dirname(__file__), "_worker_graphs.py")
RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))


def _cfg(**kw):
    kw.setdefault("graph_factory", GRAPHS + ":mlp_graph")
    kw.setdefault("heartbeat_s", 0.1)
    kw.setdefault("heartbeat_timeout_s", 5.0)
    kw.setdefault("backoff_initial_s", 0.1)
    kw.setdefault("backoff_max_s", 0.5)
    kw.setdefault("spawn_timeout_s", 60.0)
    kw.setdefault("shutdown_grace_s", 5.0)
    return SupervisorConfig(**kw)


# -- ControlFrame on the wire -------------------------------------------------

def test_control_frame_roundtrip_is_version_4():
    cf = ControlFrame("hb", {"snapshot": {"n": 3, "compute_s": 0.5,
                                          "nested": [1, (2, 3), None]}})
    blob = frame(cf)
    # control frames bumped the wire to v2; the reliability fields
    # (extent `attempt` + envelope `retryable`) bumped it to v3; the
    # decode-session fields (extent `kind`/`pos`/`session`) bumped it to
    # v4 — an older speaker must reject the frame loudly, not misparse
    assert blob[2] == FRAME_VERSION == 4
    back = unframe(blob)
    assert isinstance(back, ControlFrame)
    assert back.kind == "hb"
    assert back.payload["snapshot"]["n"] == 3
    assert back.payload["snapshot"]["nested"] == [1, (2, 3), None]


def test_control_frame_framed_stream_roundtrip():
    a, b = socket.socketpair()
    try:
        send_framed(a, ControlFrame("hello", {"token": "t0", "pid": 42}))
        got = recv_framed(b)
        assert got.kind == "hello" and got.payload["pid"] == 42
    finally:
        a.close()
        b.close()


# -- cross-process channels: expect/dial + the accept guard -------------------

def test_expect_dial_channel_roundtrip():
    tr = TcpTransport()
    inbox, cid = tr.expect_channel(4, role="send")
    host, port = tr.address
    peer = dial_channel(host, port, cid, role="recv", capacity=4)
    env = BatchEnvelope([RowExtent(1, 0, 0, 1)], b"xyz")
    inbox.send(env)
    got = peer.recv()
    assert got.blob == b"xyz" and got.extents == env.extents
    inbox.kill()
    peer.kill()
    tr.close()


def test_unexpect_channel_refuses_late_dial():
    tr = TcpTransport()
    ch, cid = tr.expect_channel(2, role="send")
    host, port = tr.address
    tr.unexpect_channel(cid)
    late = dial_channel(host, port, cid, role="recv", capacity=2)
    with pytest.raises(ChannelClosed):
        late.recv()
    ch.kill()
    late.kill()
    tr.close()


def test_accept_loop_survives_half_open_hello():
    """A client that connects and stalls mid-hello (2 of the 4 cid bytes)
    must not pin the accept thread: it is timed out and dropped, and the
    next well-behaved dial completes."""
    tr = TcpTransport()
    tr.handshake_timeout_s = 0.3        # instance override, test-fast
    ch, cid = tr.expect_channel(2, role="send")
    host, port = tr.address
    stalled = socket.create_connection((host, port))
    try:
        stalled.sendall(struct.pack("<I", cid)[:2])     # ...and stall
        t0 = time.monotonic()
        peer = dial_channel(host, port, cid, role="recv", capacity=2)
        ch.send(BatchEnvelope([RowExtent(1, 0, 0, 1)], b"ok"))
        assert peer.recv().blob == b"ok"
        # served the good client shortly after the guard fired, not never
        assert time.monotonic() - t0 < 10.0
    finally:
        stalled.close()
        ch.kill()
        peer.kill()
        tr.close()


# -- worker graph-factory resolution ------------------------------------------

def test_load_graph_factory_module_and_file_forms():
    by_file = load_graph_factory(GRAPHS + ":mlp_graph")
    assert len(by_file().nodes) == 6
    by_mod = load_graph_factory("tests._worker_graphs:mlp_graph")
    assert len(by_mod().nodes) == len(by_file().nodes)


def test_load_graph_factory_rejects_bad_specs():
    with pytest.raises(ValueError):
        load_graph_factory("no_colon_here")
    with pytest.raises(ValueError):
        load_graph_factory(":fn_only")
    with pytest.raises(ImportError):
        load_graph_factory("/nonexistent/path/graphs.py:fn")


# -- spawn failure cleanup ----------------------------------------------------

def test_spawn_timeout_cleans_up_no_orphans():
    """A worker binary that exits without ever dialing back must fail the
    spawn loudly and leave nothing behind (the conftest leak fixtures
    assert the 'nothing behind' half)."""
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    cfg = _cfg(python="/bin/false", spawn_timeout_s=1.0)
    with pytest.raises(ChannelClosed):
        supervised_engine(g, params, TopologySpec.chain(g, 2), cfg,
                          codecs=RAW)


# -- end-to-end over real processes -------------------------------------------

def test_procs_end_to_end_numerics_and_clean_drain():
    """Two worker processes serve a 2-stage chain: reference numerics,
    live telemetry flowing back over heartbeats, then a clean drain
    (workers say bye; nothing is killed)."""
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng, sup = supervised_engine(g, params, TopologySpec.chain(g, 2),
                                 _cfg(), codecs=RAW, max_batch=4)
    try:
        eng.start()
        xs = [np.random.default_rng(i).normal(size=(1, 16))
              .astype(np.float32) for i in range(12)]
        outs = [eng.submit(x) for x in xs]
        for x, f in zip(xs, outs):
            np.testing.assert_allclose(
                f.result(timeout=60),
                np.asarray(g.apply(params, x)), atol=1e-5)
        # telemetry: heartbeat-synthesized snapshots reach the report
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            snaps = [h.snapshot() for h in sup._handles]
            if sum(s["n"] for s in snaps) >= 2 * len(xs):
                break
            time.sleep(0.05)
        assert sum(h.snapshot()["n"] for h in sup._handles) == 2 * len(xs)
        rep = eng.report()
        assert rep.samples >= len(xs)
    finally:
        eng.shutdown()
        sup.close()
    assert not [e for e in sup.events if e["kind"] == "death"]


def test_procs_kill_heals_and_respawns_fast():
    """The CI smoke: 2 process replicas on stage 0, SIGKILL one, the
    stage heals (chain keeps answering) and the supervisor respawns it
    within the backoff window — seconds, not minutes."""
    from tools.chaos import Chaos
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    topo = TopologySpec.chain(g, 2).with_replicas(0, 2)
    eng, sup = supervised_engine(g, params, topo, _cfg(), codecs=RAW,
                                 max_batch=4)
    chaos = Chaos(sup)
    try:
        eng.start()
        x = np.random.default_rng(0).normal(size=(1, 16)).astype(np.float32)
        ref = np.asarray(g.apply(params, x))
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
        chaos.kill(chaos.pick(stage=0))
        chaos.wait_death(stage=0, timeout=30)
        # the chain answers while degraded...
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
        # ...and the stage is re-grown through scale() shortly after
        chaos.wait_respawn(stage=0, timeout=30)
        assert chaos.wait_stage_full(eng.dispatcher, 0, timeout=30) == 2
        np.testing.assert_allclose(eng.submit(x).result(timeout=60), ref,
                                   atol=1e-5)
    finally:
        eng.shutdown()
        sup.close()
