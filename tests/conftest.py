import os
import sys
import threading
import time

import pytest

# smoke tests and benches must see ONE device (the dry-run sets 512 itself,
# in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))
sys.path.insert(0, _REPO_ROOT)

# Runtime lockdep (tools/deferlint/lockdep.py): when DEFERLINT_LOCKDEP=1,
# threading.Lock/RLock created from repro/runtime files are instrumented
# and real acquisition order is recorded; inversions (A held while taking
# B in one place, B held while taking A in another) fail the session.
# Must install BEFORE any runtime module is imported so module- and
# __init__-time locks are wrapped too.
from tools.deferlint import lockdep as _lockdep  # noqa: E402

_LOCKDEP_ON = _lockdep.install_if_enabled()


@pytest.fixture(scope="session", autouse=True)
def _lockdep_session_check():
    yield
    if _LOCKDEP_ON:
        inversions = _lockdep.registry().inversions()
        assert not inversions, (
            "lockdep observed lock-order inversions during the suite:\n"
            + "\n".join(inversions)
        )


def _live_child_pids() -> set[int]:
    """Pids of live (non-zombie) direct children of this process, via
    /proc — no psutil dependency.  Zombies are excluded: an exited child
    awaiting a reap is subprocess bookkeeping, not an orphan that will
    outlive the test run."""
    me = os.getpid()
    pids: set[int] = set()
    try:
        entries = os.listdir("/proc")
    except OSError:
        return pids                 # non-procfs platform: nothing to check
    for ent in entries:
        if not ent.isdigit():
            continue
        try:
            with open(f"/proc/{ent}/stat", "r") as f:
                fields = f.read().rsplit(")", 1)[-1].split()
            # post-comm fields: [0]=state, [1]=ppid
            if int(fields[1]) == me and fields[0] != "Z":
                pids.add(int(ent))
        except (OSError, IndexError, ValueError):
            continue                # raced a pid that just exited
    return pids


@pytest.fixture(autouse=True)
def _no_thread_leaks():
    """Fail any test that leaves stray non-daemon threads OR live child
    processes behind: a non-daemon thread leak means some runtime object
    was not shut down (the interpreter would hang at exit in production),
    and a child-process leak means a supervisor or worker outlived its
    test — an orphan eating a CPU until the CI box is recycled."""
    before = set(threading.enumerate())
    procs_before = _live_child_pids()
    yield
    strays = _lockdep.running_nondaemon_threads(before)
    if strays:
        # give graceful teardown a moment (collector threads finishing a
        # final drain) before declaring a leak
        for t in strays:
            t.join(timeout=1.0)
        strays = _lockdep.running_nondaemon_threads(before)
    assert not strays, (
        "test leaked non-daemon threads (missing shutdown/join): "
        + ", ".join(repr(t) for t in strays)
    )
    leaked = _live_child_pids() - procs_before
    if leaked:
        # same grace for process teardown (a reaped worker needs a moment
        # to leave the process table), then re-scan before declaring
        deadline = time.monotonic() + 2.0
        while leaked and time.monotonic() < deadline:
            time.sleep(0.05)
            leaked = _live_child_pids() - procs_before
    assert not leaked, (
        "test leaked live child processes (missing Supervisor.close()/"
        f"reap): pids {sorted(leaked)}"
    )


@pytest.fixture(autouse=True)
def _no_session_residue():
    """Fail any test that leaves resident decode-session KV caches behind
    in a SessionStore: session-keyed maps in runtime/ must be evicted on
    session end (close frame / fence clear / thread-exit clear — the
    per-client-GC precedent), or long-lived replicas leak one KV cache
    per ephemeral session."""
    yield
    from repro.runtime.session import live_session_stores
    residue = {id(s): s.keys() for s in live_session_stores() if len(s)}
    assert not residue, (
        "test leaked resident decode-session KV caches (session-keyed "
        f"state must be evicted on session end): {residue}"
    )
