import os
import sys
import threading

import pytest

# smoke tests and benches must see ONE device (the dry-run sets 512 itself,
# in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))
sys.path.insert(0, _REPO_ROOT)

# Runtime lockdep (tools/deferlint/lockdep.py): when DEFERLINT_LOCKDEP=1,
# threading.Lock/RLock created from repro/runtime files are instrumented
# and real acquisition order is recorded; inversions (A held while taking
# B in one place, B held while taking A in another) fail the session.
# Must install BEFORE any runtime module is imported so module- and
# __init__-time locks are wrapped too.
from tools.deferlint import lockdep as _lockdep  # noqa: E402

_LOCKDEP_ON = _lockdep.install_if_enabled()


@pytest.fixture(scope="session", autouse=True)
def _lockdep_session_check():
    yield
    if _LOCKDEP_ON:
        inversions = _lockdep.registry().inversions()
        assert not inversions, (
            "lockdep observed lock-order inversions during the suite:\n"
            + "\n".join(inversions)
        )


@pytest.fixture(autouse=True)
def _no_thread_leaks():
    """Fail any test that leaves stray non-daemon threads running: a
    non-daemon leak means some runtime object was not shut down, and the
    whole interpreter would hang at exit in production."""
    before = set(threading.enumerate())
    yield
    strays = _lockdep.running_nondaemon_threads(before)
    if strays:
        # give graceful teardown a moment (collector threads finishing a
        # final drain) before declaring a leak
        for t in strays:
            t.join(timeout=1.0)
        strays = _lockdep.running_nondaemon_threads(before)
    assert not strays, (
        "test leaked non-daemon threads (missing shutdown/join): "
        + ", ".join(repr(t) for t in strays)
    )
