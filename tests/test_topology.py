"""Topology-first serving: TopologySpec validation, replicated-stage
routing + FIFO-per-client ordering (the sequenced merge), elastic
membership (spawn/drain under load with zero loss), pluggable transports,
and per-layer pad-safety."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import LayerGraph
from repro.runtime import (ControllerConfig, InferenceEngine, StageSpec,
                           TopologySpec, decide_scale, register_transport)
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.transport import InprocChannel, InprocTransport, Transport
from repro.runtime.wire import WireCodec

D = 16

RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))


def mlp_graph(depth: int = 6, d: int = D, rank3: bool = False,
              unsafe: set | None = None) -> LayerGraph:
    shape = (1, 4, d) if rank3 else (1, d)
    g = LayerGraph("toy-mlp", jax.ShapeDtypeStruct(shape, np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct(shape, np.float32),
                flops=2.0 * d * d,
                pad_safe=i not in (unsafe or set()))
        prev = f"fc{i}"
    return g


def sample(i: int, shape=(1, D)) -> np.ndarray:
    return np.random.default_rng(i).normal(size=shape).astype(np.float32)


def make_engine(topology, graph=None, **kw):
    g = graph if graph is not None else mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, topology, RAW, **kw)
    eng.configure(params)
    return g, params, eng


# -- TopologySpec -------------------------------------------------------------

def test_spec_validation():
    g = mlp_graph(6)
    spec = TopologySpec.chain(g, 3)
    spec.validate(g)
    assert spec.bounds == [0, 2, 4, 6] and spec.replicas == (1, 1, 1)
    assert spec.with_replicas(1, 3).replicas == (1, 3, 1)
    assert spec.with_layers([0, 1, 2, 6]).cuts == (1, 2)
    with pytest.raises(ValueError):          # hole in the coverage
        TopologySpec((StageSpec((0, 2)), StageSpec((3, 6)))).validate(g)
    with pytest.raises(ValueError):          # doesn't reach the last layer
        TopologySpec((StageSpec((0, 4)),)).validate(g)
    with pytest.raises(ValueError):
        TopologySpec((StageSpec((0, 6), replicas=0),)).validate(g)
    with pytest.raises(ValueError):
        TopologySpec((StageSpec((0, 6), routing="zigzag"),)).validate(g)
    with pytest.raises(ValueError):
        TopologySpec((StageSpec((0, 6), transport="carrier-pigeon"),)
                     ).validate(g)
    with pytest.raises(ValueError):          # wrong per-stage replica list
        TopologySpec.chain(g, 3, replicas=[2, 2])
    assert TopologySpec.chain(g, 2, replicas=2).replicas == (2, 2)
    assert TopologySpec.chain(g, 2, cuts=(5,)).bounds == [0, 5, 6]


def test_engine_accepts_int_as_chain_sugar():
    g, params, eng = make_engine(3)
    assert eng.topology.num_stages == 3
    assert eng.dispatcher.replicas == (1, 1, 1)
    out = eng.submit(sample(0)).result(timeout=60)
    np.testing.assert_allclose(
        out, np.asarray(g.apply(params, jnp.asarray(sample(0)))), atol=1e-5)
    eng.shutdown()


# -- replicated stages: ordering is the contract ------------------------------

def test_replicated_stage_fifo_per_client_random_delays():
    """Property-style: a 3-replica middle stage whose replicas each sleep
    a different random time per batch WILL complete batches out of order;
    every client must still see its own results in submission order —
    asserted on the actual future resolution order (the sequenced merge),
    not just on stream()'s await order — with reference numerics."""
    spec = TopologySpec.chain(mlp_graph(), 3).with_replicas(1, 3)
    g, params, eng = make_engine(spec, max_batch=2)
    eng.start()
    mid = eng.dispatcher.stages[1].replicas
    assert len(mid) == 3
    for k, node in enumerate(mid):           # deterministic, replica-skewed
        rng = np.random.default_rng(k)       # delays out-of-order the chain
        orig = node._apply
        node._apply = (lambda b, o=orig, r=rng, k=k:
                       (time.sleep(float(r.uniform(0.001, 0.02 * (k + 1)))),
                        o(b))[1])
    n_clients, per_client = 4, 12
    resolved: dict[int, list] = {c: [] for c in range(n_clients)}
    res_lock = threading.Lock()
    futs: dict[int, list] = {c: [] for c in range(n_clients)}
    for i in range(per_client):              # interleave clients' submits
        for c in range(n_clients):
            f = eng.submit(sample(100 * c + i), client_id=c)
            f.add_done_callback(
                lambda _, c=c, i=i: (res_lock.acquire(),
                                     resolved[c].append(i),
                                     res_lock.release()))
            futs[c].append(f)
    for c in range(n_clients):
        for i, f in enumerate(futs[c]):
            ref = np.asarray(g.apply(params, jnp.asarray(sample(100 * c + i))))
            np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    eng.shutdown()
    # zero lost, zero duplicated, zero reordered — per client
    for c in range(n_clients):
        assert resolved[c] == list(range(per_client)), resolved[c]
    # the replicas genuinely shared the stage's work
    served = [sum(t.n for t in node.traces) for node in mid]
    assert sum(served) == n_clients * per_client
    assert sum(1 for s in served if s > 0) >= 2, served


def test_replicated_routing_round_robin():
    spec = TopologySpec.chain(mlp_graph(), 2, routing="rr").with_replicas(
        1, 3)
    g, params, eng = make_engine(spec, max_batch=1)
    eng.start()
    futs = [eng.submit(sample(i)) for i in range(9)]
    for f in futs:
        f.result(timeout=60)
    eng.dispatcher.drain()
    served = [sum(t.n for t in node.traces)
              for node in eng.dispatcher.stages[1].replicas]
    eng.shutdown()
    assert sum(served) == 9
    assert all(s >= 1 for s in served), served   # rr touches every replica


# -- elastic membership -------------------------------------------------------

def _stream_clients(eng, g, params, n_clients, per_client, base=0):
    results: dict[int, list] = {}
    errors: list = []

    def client(c):
        try:
            xs = [sample(base + 100 * c + i) for i in range(per_client)]
            results[c] = list(eng.submit_stream(xs, client_id=c))
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    return threads, results, errors


def _check_streams(g, params, results, errors, n_clients, per_client,
                   base=0):
    assert not errors, errors
    for c in range(n_clients):
        assert len(results[c]) == per_client   # zero lost, zero duplicated
        for i, got in enumerate(results[c]):   # zero reordered: result i is
            ref = np.asarray(g.apply(            # exactly input i's output
                params, jnp.asarray(sample(base + 100 * c + i))))
            np.testing.assert_allclose(got, ref, atol=1e-5)


def test_scale_up_under_load_zero_loss():
    """1 -> 3 replicas on the middle stage while clients stream: nothing
    lost/duplicated/reordered, and the spawned replicas take real work."""
    g = mlp_graph(8)
    g, params, eng = make_engine(TopologySpec.chain(g, 3), graph=g,
                                 max_batch=2)
    eng.start()
    threads, results, errors = _stream_clients(eng, g, params, 3, 16)
    rec = eng.scale(1, 3)
    for t in threads:
        t.join()
    # keep serving after the fence so spawned replicas demonstrably work
    threads, r2, e2 = _stream_clients(eng, g, params, 3, 8, base=5000)
    for t in threads:
        t.join()
    served = [sum(t.n for t in node.traces)
              for node in eng.dispatcher.stages[1].replicas]
    rep = eng.report()
    eng.shutdown()
    assert rec["changed"] and rec["spawned"] == 2
    assert rec["shipped_bytes"] > 0            # stage weights went over wire
    _check_streams(g, params, results, errors, 3, 16)
    _check_streams(g, params, r2, e2, 3, 8, base=5000)
    assert rep.replicas == (1, 3, 1) and rep.epoch == 1
    assert sum(1 for s in served if s > 0) >= 2, served


def test_drain_under_load_zero_loss():
    """3 -> 1 replicas on the middle stage while clients stream: the
    drained replicas flush everything already routed to them, their
    threads exit, and no response is lost, duplicated, or reordered."""
    g = mlp_graph(8)
    spec = TopologySpec.chain(g, 3).with_replicas(1, 3)
    g, params, eng = make_engine(spec, graph=g, max_batch=2)
    eng.start()
    before = list(eng.dispatcher.stages[1].replicas)
    threads, results, errors = _stream_clients(eng, g, params, 3, 16)
    time.sleep(0.05)                           # mid-stream drain
    rec = eng.scale(1, 1)
    for t in threads:
        t.join()
    threads, r2, e2 = _stream_clients(eng, g, params, 3, 8, base=7000)
    for t in threads:
        t.join()
    rep = eng.report()
    eng.shutdown()
    assert rec["changed"] and rec["retired"] == 2 and rec["acknowledged"]
    _check_streams(g, params, results, errors, 3, 16)
    _check_streams(g, params, r2, e2, 3, 8, base=7000)
    assert rep.replicas == (1, 1, 1) and rep.epoch == 1
    retired = [n for n in before
               if n not in eng.dispatcher.stages[1].replicas]
    assert len(retired) == 2
    for node in retired:                       # flushed and exited cleanly
        assert not any(t.is_alive() for t in node._threads)


def _drain_fence_shutdown_race(scale_stage: int):
    """shutdown() while a drain fence is still stuck behind the draining
    replica's gated backlog: the last LIVE stop reaches the downstream
    consumer before the straggler's fence copy lowers the stop
    expectation (the drained replica never stops), so the consumer must
    re-check after the barrier — without that, the router (mid-stage leg)
    or collector (tail leg) blocks forever and shutdown deadlocks."""
    g = mlp_graph(6)
    spec = TopologySpec.chain(g, 2, routing="rr").with_replicas(
        scale_stage, 2)
    g, params, eng = make_engine(spec, graph=g, max_batch=1)
    eng.start()
    victim = eng.dispatcher.stages[scale_stage].replicas[1]
    gate = threading.Event()
    entered = threading.Event()
    orig = victim._apply

    def gated(b):
        entered.set()
        gate.wait(timeout=60)
        return orig(b)

    victim._apply = gated
    futs = [eng.submit(sample(i)) for i in range(4)]   # rr: victim holds work
    # the fence is injected directly into the head channel, so it can
    # overtake envelopes still in the admission queue: wait until the
    # victim provably holds PRE-fence work, or the fence clears instantly
    assert entered.wait(timeout=60)
    rec = eng.scale(scale_stage, 1, timeout=0.05)      # fence stuck in flight
    assert rec["changed"] and not rec["acknowledged"]
    done = threading.Event()
    t = threading.Thread(
        target=lambda: (eng.shutdown(drain=False), done.set()))
    t.start()
    time.sleep(0.3)              # let _STOP chase the fence into the chain
    gate.set()
    assert done.wait(timeout=60), "shutdown deadlocked behind drain fence"
    t.join()
    for i, f in enumerate(futs):                       # nothing was lost
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=5), ref, atol=1e-5)


def test_shutdown_races_drain_fence_at_collector():
    _drain_fence_shutdown_race(scale_stage=1)          # tail -> collector


def test_shutdown_races_drain_fence_at_midstage_router():
    _drain_fence_shutdown_race(scale_stage=0)          # -> stage-1 router


def test_unacked_drain_retiree_visible_then_pruned():
    """An un-acked drain keeps the still-flushing replica visible (its
    telemetry is real), but once its threads exit it must be pruned at
    the next membership read — a dead retiree's frozen snapshot epoch
    would otherwise make the controller rebaseline forever."""
    g = mlp_graph(6)
    spec = TopologySpec.chain(g, 2, routing="rr").with_replicas(1, 2)
    g, params, eng = make_engine(spec, graph=g, max_batch=1)
    eng.start()
    victim = eng.dispatcher.stages[1].replicas[1]
    gate = threading.Event()
    entered = threading.Event()
    orig = victim._apply

    def gated(b):
        entered.set()
        gate.wait(timeout=60)
        return orig(b)

    victim._apply = gated
    futs = [eng.submit(sample(i)) for i in range(4)]
    assert entered.wait(timeout=60)           # victim holds pre-fence work
    rec = eng.scale(1, 1, timeout=0.05)
    assert rec["changed"] and not rec["acknowledged"]
    assert victim.retiring
    assert len(eng.dispatcher.stages[1].replicas) == 2   # still flushing
    gate.set()
    for i, f in enumerate(futs):              # zero loss through it all
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    deadline = time.perf_counter() + 30
    while any(t.is_alive() for t in victim._threads) \
            and time.perf_counter() < deadline:
        time.sleep(0.01)
    assert eng.dispatcher.replicas == (1, 1)  # pruned at the read
    assert victim not in eng.dispatcher.stages[1].replicas
    eng.shutdown()


def test_scale_validation_and_noop():
    g, params, eng = make_engine(2)
    eng.start()
    assert eng.scale(0, 1)["changed"] is False
    with pytest.raises(ValueError):
        eng.scale(0, 0)
    with pytest.raises(ValueError):
        eng.scale(7, 2)
    eng.shutdown()


def test_scale_then_repartition_composes():
    """A replicated stage and a later cut migration coexist: all replicas
    of the scaled stage adopt the new boundaries at the fence."""
    g = mlp_graph(8)
    g, params, eng = make_engine(TopologySpec.chain(g, 2), graph=g,
                                 max_batch=2)
    eng.start()
    eng.scale(1, 2)
    rec = eng.dispatcher.reconfigure((3,))
    futs = [eng.submit(sample(i)) for i in range(8)]
    for i, f in enumerate(futs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    eng.shutdown()
    assert rec["changed"] and rec["acknowledged"]
    for node in eng.dispatcher.stages[1].replicas:
        assert node.epoch == 2                # both fences committed
        assert [n.name for n in node._nodes] == [f"fc{i}"
                                                for i in range(3, 8)]
    # the diff shipped once per replica of the resized stage
    assert eng.dispatcher.replicas == (1, 2)


# -- controller's replica dimension -------------------------------------------

def test_decide_scale_up_and_down():
    from repro.core.partitioner import CalibratedCosts
    costs = CalibratedCosts(
        layer_s=np.array([0.1, 0.8, 0.1]), cut_bytes=np.full(3, 4.0),
        head_in_bytes=4.0, tail_out_bytes=4.0)
    # one layer per stage: cuts have no freedom, replicas are the lever
    rec = decide_scale(costs, [0, 1, 2, 3], [1, 1, 1])
    assert rec == {**rec, "stage": 1, "replicas": 2, "direction": "up"}
    # at the ceiling: no recommendation
    assert decide_scale(costs, [0, 1, 2, 3], [1, 4, 1],
                        max_replicas=4) is None
    # an over-provisioned cold stage sheds a replica
    rec = decide_scale(costs, [0, 1, 2, 3], [4, 4, 1])
    assert rec["stage"] == 0 and rec["replicas"] == 3
    assert rec["direction"] == "down"
    # single-stage topology: no runner-up means no measured imbalance —
    # must NOT recommend an unconditional spawn on an idle engine
    assert decide_scale(costs, [0, 3], [1]) is None


def test_controller_scales_unsplittable_bottleneck():
    """One layer per stage (cuts frozen by construction), middle stage
    artificially slow: the repartition arm must hold and the scale arm
    must grow the bottleneck stage — executed live, zero loss."""
    g = mlp_graph(3)
    cfg = ControllerConfig(interval_s=30.0, ewma_alpha=1.0, min_requests=8,
                           cooldown_s=0.0, hysteresis=0.05,
                           replica_scaling=True, execute_scaling=True,
                           precompile_after_swap=False)
    spec = TopologySpec.chain(g, 3)
    g, params, eng = make_engine(spec, graph=g, max_batch=2, controller=cfg)
    eng.start()                                # 30s interval: idle thread
    node = eng.dispatcher.stages[1].replicas[0]
    orig = node._apply
    node._apply = lambda b: (time.sleep(0.03), orig(b))[1]
    futs = [eng.submit(sample(i), client_id=i % 2) for i in range(12)]
    for f in futs:
        f.result(timeout=60)
    action = eng.controller.step()
    assert action.kind == "scale", action
    assert action.detail["stage"] == 1 and action.detail["direction"] == "up"
    assert action.detail["acknowledged"]
    assert eng.dispatcher.replicas == (1, 2, 1)
    futs = [eng.submit(sample(100 + i)) for i in range(8)]
    for i, f in enumerate(futs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(100 + i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    eng.shutdown()
    assert eng.controller.migrations == 1


def test_controller_recommends_without_executing():
    g = mlp_graph(3)
    cfg = ControllerConfig(interval_s=30.0, ewma_alpha=1.0, min_requests=8,
                           cooldown_s=0.0, hysteresis=0.05,
                           replica_scaling=True, execute_scaling=False,
                           adapt_knobs=False)
    g, params, eng = make_engine(TopologySpec.chain(g, 3), graph=g,
                                 max_batch=2, controller=cfg)
    eng.start()
    node = eng.dispatcher.stages[1].replicas[0]
    orig = node._apply
    node._apply = lambda b: (time.sleep(0.03), orig(b))[1]
    for i in range(10):
        eng.submit(sample(i)).result(timeout=60)
    action = eng.controller.step()
    eng.shutdown()
    assert action.kind == "scale_recommend", action
    assert action.detail["stage"] == 1
    assert eng.dispatcher.replicas == (1, 1, 1)   # nothing executed


# -- pluggable transports -----------------------------------------------------

class _CountingChannel(InprocChannel):
    sends = 0

    def send(self, item):
        type(self).sends += 1
        super().send(item)


class _CountingTransport(Transport):
    name = "counting"

    def channel(self, capacity: int = 0):
        return _CountingChannel(capacity)


def test_custom_transport_carries_the_stage():
    register_transport("counting", _CountingTransport)
    _CountingChannel.sends = 0
    spec = TopologySpec.chain(mlp_graph(), 2, transport="counting")
    g, params, eng = make_engine(spec, max_batch=2)
    eng.start()
    futs = [eng.submit(sample(i)) for i in range(5)]
    for i, f in enumerate(futs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    eng.shutdown()
    # every hop (pump->router, router->replica, relay, tail) used the
    # registered backend, envelopes and stop tokens alike
    assert _CountingChannel.sends >= 5 * 3


def test_unknown_transport_rejected():
    spec = TopologySpec((StageSpec((0, 6), transport="udp?"),))
    with pytest.raises(ValueError, match="unknown transport"):
        make_engine(spec)


# -- per-layer pad safety -----------------------------------------------------

def _stalled_pair(eng, node, shapes):
    """Deterministically land ``shapes``' requests in ONE compute merge: a
    plug request provably occupies the gated apply first (so it cannot
    absorb them), the pair is decoded into the compute queue behind it,
    and the gate opens only once every pair extent is queued — the next
    merge then drains them together."""
    gate = threading.Event()
    entered = threading.Event()
    orig = node._apply

    def gated(b):
        entered.set()
        gate.wait(timeout=60)
        return orig(b)

    node._apply = gated
    plug = eng.submit(sample(39, (1, 3, D)))
    assert entered.wait(timeout=60)     # compute thread is inside apply
    futs = [eng.submit(sample(40 + i, s)) for i, s in enumerate(shapes)]

    def decoded_parts():                # pair extents decoded and queued
        return sum(len(d.extents) for w in list(node._to_compute.queue)
                   if isinstance(w, list) for d in w)

    deadline = time.perf_counter() + 10
    while decoded_parts() < len(shapes) and time.perf_counter() < deadline:
        time.sleep(0.01)
    assert decoded_parts() == len(shapes)
    gate.set()
    plug.result(timeout=60)
    return futs


def test_pad_unsafe_layer_falls_back_to_exact_buckets():
    """A segment containing a pad-unsafe layer must NOT pow2-pad: the
    near-miss shapes stay in separate buckets (two encodes), numerics are
    exact, while a safe segment of the same graph still merges."""
    g = mlp_graph(6, rank3=True, unsafe={1})   # fc1 is stage 0's layer
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, TopologySpec.chain(g, 2, cuts=(3,)), RAW,
                          max_batch=8, shape_buckets="pow2")
    eng.configure(params)
    node0 = eng.dispatcher.stages[0].replicas[0]
    node1 = eng.dispatcher.stages[1].replicas[0]
    assert not node0._pad_safe and node1._pad_safe
    xs = [(1, 5, D), (1, 7, D)]
    futs = _stalled_pair(eng, node0, xs)
    outs = [f.result(timeout=60) for f in futs]
    eng.dispatcher.drain()
    eng.shutdown()
    for shape, out in zip(xs, outs):
        assert out.shape == shape
        ref = np.asarray(g.apply(params, jnp.asarray(sample(
            40 + xs.index(shape), shape))))
        np.testing.assert_allclose(out, ref, atol=1e-5)
    # unsafe segment: one codec pass PER REQUEST (no bucket merge)
    merged0 = max(node0.traces, key=lambda t: t.n)
    assert merged0.encodes == merged0.n


def test_pad_safe_graph_still_merges():
    g = mlp_graph(6, rank3=True)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=8, shape_buckets="pow2")
    eng.configure(params)
    node0 = eng.dispatcher.stages[0].replicas[0]
    futs = _stalled_pair(eng, node0, [(1, 5, D), (1, 7, D)])
    for f in futs:
        f.result(timeout=60)
    eng.shutdown()
    merged = max(node0.traces, key=lambda t: t.n)
    assert merged.n == 2 and merged.encodes == 1
