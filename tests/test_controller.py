"""Serving-time feedback controller: cost calibration from synthetic
traces, hysteresis, adaptive knobs, weighted admission / quotas, bucketed
pad-to-shape batching, and zero-loss live repartitioning under load."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import LayerGraph
from repro.runtime import (AdmissionFull, ControllerConfig, CostCalibrator,
                           InferenceEngine, TopologySpec, decide_repartition,
                           decide_scale, suggest_knobs)
from repro.runtime.dispatcher import (DispatcherCodecs,
                                      _WeightedAdmissionQueue)
from repro.runtime.node import _STOP
from repro.runtime.wire import WireCodec

D = 16

RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))


def mlp_graph(depth: int = 8, d: int = D, rank3: bool = False) -> LayerGraph:
    shape = (1, 4, d) if rank3 else (1, d)
    g = LayerGraph("toy-mlp", jax.ShapeDtypeStruct(shape, np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct(shape, np.float32),
                flops=2.0 * d * d)
        prev = f"fc{i}"
    return g


def snap(node, n=16, compute_s=0.1, ser=0.01, des=0.01, mb=8, co=0.005,
         qd=1.0, bm=2.0):
    return {"node": node, "n": n, "compute_s": compute_s,
            "serialize_s": ser, "deserialize_s": des,
            "busy_decode_s": des, "busy_compute_s": compute_s,
            "busy_encode_s": ser, "queue_depth_mean": qd, "batch_mean": bm,
            "max_batch": mb, "coalesce_s": co, "payload_bytes": 0,
            "encodes": 1, "epoch": 0}


def sample(i: int, shape=(1, D)) -> np.ndarray:
    return np.random.default_rng(i).normal(size=shape).astype(np.float32)


# -- calibrator + decision (synthetic traces) --------------------------------

def test_skewed_compute_moves_predicted_cut():
    """Node 0 measures 3x the per-request compute of node 1: the
    calibrated DP moves the cut to shrink node 0's range."""
    g = mlp_graph(8)
    cal = CostCalibrator(g, alpha=1.0)
    cal.update([snap(0, compute_s=0.30 * 16 / 16),
                snap(1, compute_s=0.10)], [(0, 4), (4, 8)])
    assert cal.ready
    # measured per-layer time: node0's layers 3x node1's
    assert cal.layer_s[0] == pytest.approx(3 * cal.layer_s[4])
    dec = decide_repartition(cal.costs(), [0, 4, 8], 2, hysteresis=0.1)
    assert dec is not None
    assert dec["cuts"][0] < 4                  # fewer layers for node 0
    assert dec["predicted_new_s"] < dec["predicted_current_s"]


def test_hysteresis_holds_on_noisy_traces():
    """A few percent of imbalance (noise) must NOT trigger a migration."""
    g = mlp_graph(8)
    cal = CostCalibrator(g, alpha=1.0)
    cal.update([snap(0, compute_s=0.105), snap(1, compute_s=0.100)],
               [(0, 4), (4, 8)])
    assert decide_repartition(cal.costs(), [0, 4, 8], 2,
                              hysteresis=0.15) is None


def test_calibrator_not_ready_until_all_nodes_report():
    g = mlp_graph(8)
    cal = CostCalibrator(g)
    cal.update([snap(0), snap(1, n=0)], [(0, 4), (4, 8)])
    assert not cal.ready                       # node 1 had no traffic yet
    cal.update([snap(0), snap(1)], [(0, 4), (4, 8)])
    assert cal.ready


def test_ewma_converges_and_smooths():
    g = mlp_graph(4)
    cal = CostCalibrator(g, alpha=0.5)
    first = cal.layer_s.copy()
    for _ in range(12):
        cal.update([snap(0, compute_s=0.2)], [(0, 4)])
    per_layer = 0.2 / 16 / 4                   # per-request / layers
    assert np.allclose(cal.layer_s, per_layer, rtol=0.02)
    assert not np.allclose(first, cal.layer_s)


def test_suggest_knobs_codec_vs_compute_bound():
    codec_bound = snap(0, compute_s=0.05, ser=0.5, des=0.4, qd=6.0, bm=5.0)
    mb, co = suggest_knobs(codec_bound, cap=16)
    assert co > codec_bound["coalesce_s"]      # grow the coalescing window
    assert mb > codec_bound["max_batch"]       # backlogged: grow batches
    compute_bound = snap(0, compute_s=0.5, ser=0.01, des=0.01, qd=0.2,
                         bm=1.0)
    mb2, co2 = suggest_knobs(compute_bound, cap=16)
    assert co2 < compute_bound["coalesce_s"]   # shrink toward low latency
    assert mb2 < compute_bound["max_batch"]
    # clamps hold at the extremes (backlogged codec-bound node at the cap)
    lo, hi = 0.0005, 0.04
    s = snap(0, compute_s=0.01, ser=1.0, des=1.0, co=hi, qd=6.0, bm=2.0)
    assert suggest_knobs(s, cap=16, coalesce_bounds=(lo, hi))[1] == hi
    # no backlog: a codec-bound node still SHRINKS its window (coalescing
    # a trickle only adds latency, amortizes nothing)
    s = snap(0, compute_s=0.01, ser=1.0, des=1.0, co=0.01, qd=0.5, bm=1.0)
    assert suggest_knobs(s, cap=16)[1] < 0.01
    # the window never grows past the measured per-wave service time
    s = snap(0, n=16, compute_s=0.001, ser=0.008, des=0.008, co=0.005,
             qd=6.0, bm=2.0)
    wave_service = (0.001 + 0.016) / (16 / 2)
    assert suggest_knobs(s, cap=16)[1] <= wave_service
    # fully saturated codec-bound node (every wave FULL): max_batch still
    # grows toward the cap even though the coalesce branch is inactive
    s = snap(0, compute_s=0.05, ser=0.5, des=0.4, qd=8.0, bm=8.0, mb=8)
    mb3, co3 = suggest_knobs(s, cap=32)
    assert mb3 == 16 and co3 == s["coalesce_s"]


# -- weighted admission queue + quotas ---------------------------------------

def test_weighted_dequeue_proportional_no_starvation():
    q = _WeightedAdmissionQueue(64)
    for i in range(10):
        q.put(("p0", i), priority=0)
        q.put(("p1", i), priority=1)
    first9 = [q.get()[0] for _ in range(9)]
    # weight 2:1 — priority 1 gets ~2/3 of dequeues while both backlogged
    assert first9.count("p1") == 6 and first9.count("p0") == 3
    # FIFO within a band
    p1_idx = [item[1] for item in
              ([("p1", i) for i in range(10)])]
    assert p1_idx == sorted(p1_idx)
    rest = [q.get() for _ in range(11)]
    assert len(rest) == 11                     # nothing lost


def test_stop_never_overtakes_queued_requests():
    q = _WeightedAdmissionQueue(8)
    q.put("a", priority=0)
    q.put("b", priority=5)
    q.put(_STOP)
    assert q.get() is not _STOP
    assert q.get() is not _STOP
    assert q.get() is _STOP                    # surfaced only when drained


def test_client_quota_enforced_and_released():
    g = mlp_graph(6)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=2, client_quota=3)
    eng.configure(params)
    gate = threading.Event()
    node0 = eng.dispatcher.nodes[0]
    orig = node0._apply
    node0._apply = lambda b: (gate.wait(timeout=60), orig(b))[1]
    futs = [eng.submit(sample(i), client_id="greedy") for i in range(3)]
    with pytest.raises(AdmissionFull, match="quota"):
        eng.submit(sample(9), client_id="greedy")
    # another client is unaffected by the greedy one's quota
    other = eng.submit(sample(10), client_id="polite")
    gate.set()
    for f in futs + [other]:
        f.result(timeout=60)
    # quota released: the greedy client can admit again
    eng.submit(sample(11), client_id="greedy").result(timeout=60)
    eng.shutdown()


def test_priority_submit_end_to_end():
    g = mlp_graph(6)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=4)
    eng.configure(params)
    futs = [eng.submit(sample(i), client_id=i % 2, priority=i % 3)
            for i in range(9)]
    for i, f in enumerate(futs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    eng.shutdown()


# -- bucketed pad-to-shape (heterogeneous trailing shapes) -------------------

def test_pow2_buckets_merge_near_miss_shapes():
    """(1, 5, D) and (1, 7, D) pad to (1, 8, D), merge into ONE apply and
    ONE encode, and come back trimmed to their original shapes with
    per-request reference numerics."""
    g = mlp_graph(6, rank3=True)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=8, shape_buckets="pow2")
    eng.configure(params)
    gate = threading.Event()
    node0 = eng.dispatcher.nodes[0]
    orig = node0._apply
    node0._apply = lambda b: (gate.wait(timeout=60), orig(b))[1]
    xs = [sample(1, (1, 5, D)), sample(2, (1, 7, D))]
    futs = [eng.submit(x) for x in xs]
    deadline = time.perf_counter() + 10
    while node0._to_compute.qsize() < 1 and time.perf_counter() < deadline:
        time.sleep(0.01)
    time.sleep(0.2)
    gate.set()
    outs = [f.result(timeout=60) for f in futs]
    eng.shutdown()
    for x, out in zip(xs, outs):
        assert out.shape == x.shape            # trimmed back, not padded
        ref = np.asarray(g.apply(params, jnp.asarray(x)))
        np.testing.assert_allclose(out, ref, atol=1e-5)
    merged = max(node0.traces, key=lambda t: t.n)
    assert merged.n == 2 and merged.encodes == 1   # one bucket, one pass


def test_exact_buckets_keep_shapes_separate():
    """Default mode: near-miss shapes stay in their own buckets."""
    g = mlp_graph(4, rank3=True)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=8)
    eng.configure(params)
    gate = threading.Event()
    node0 = eng.dispatcher.nodes[0]
    orig = node0._apply
    node0._apply = lambda b: (gate.wait(timeout=60), orig(b))[1]
    futs = [eng.submit(sample(1, (1, 5, D))), eng.submit(sample(2, (1, 7, D)))]
    time.sleep(0.2)
    gate.set()
    for f in futs:
        f.result(timeout=60)
    eng.shutdown()
    assert all(t.encodes == t.n or t.n == 1 for t in node0.traces)


# -- live repartition: zero loss, FIFO preserved -----------------------------

def test_live_repartition_zero_loss_fifo_under_load():
    """Two hot repartitions while client threads stream: every request
    resolves with reference numerics, per-client FIFO holds, and the
    chain's threads survive."""
    g = mlp_graph(8)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, TopologySpec.chain(g, 3, cuts=(5, 7)), RAW,
                          max_batch=4)
    eng.configure(params)
    eng.start()
    per_client, n_clients = 14, 3
    results: dict[int, list] = {}
    errors: list = []

    def client(c):
        try:
            xs = [sample(100 * c + i) for i in range(per_client)]
            results[c] = list(eng.submit_stream(xs, client_id=c))
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    rec1 = eng.dispatcher.reconfigure((3, 6))
    rec2 = eng.dispatcher.reconfigure((2, 4))
    for t in threads:
        t.join()
    rep = eng.report()
    eng.shutdown()
    assert not errors
    assert rec1["changed"] and rec1["acknowledged"]
    assert rec2["changed"] and rec2["acknowledged"]
    assert rep.epoch == 2 and rep.cuts == (2, 4)
    # zero loss + per-client FIFO: result i is exactly input i's output
    for c in range(n_clients):
        assert len(results[c]) == per_client
        for i, got in enumerate(results[c]):
            ref = np.asarray(g.apply(params, jnp.asarray(sample(100 * c + i))))
            np.testing.assert_allclose(got, ref, atol=1e-5)


def test_reconfigure_ships_only_weight_diff():
    """A one-layer boundary shift ships ~one layer of weights, not the
    whole model."""
    g = mlp_graph(8)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=2)
    eng.configure(params)
    eng.start()
    one_layer = D * D * 4
    rec = eng.dispatcher.reconfigure((3,))     # (0,4),(4,8) -> (0,3),(3,8)
    eng.shutdown()
    assert rec["moved_layers"] == 1
    assert one_layer <= rec["shipped_bytes"] <= 3 * one_layer


def test_reconfigure_across_paramless_layers():
    """CNN-style graphs interleave param-less layers (pool / add /
    activation): they produce no wire weights, and a migration across
    them must still commit (regression: the weight-diff check used to
    demand an entry for every layer and killed the compute thread)."""
    g = LayerGraph("mixed", jax.ShapeDtypeStruct((1, D), np.float32))
    prev = ""
    for i in range(8):
        if i % 2:
            g.layer(f"relu{i}", lambda p, x: jnp.maximum(x, 0.0), {},
                    (prev,), jax.ShapeDtypeStruct((1, D), np.float32),
                    flops=float(D))
            prev = f"relu{i}"
        else:
            g.layer(f"fc{i}",
                    lambda p, x: jnp.tanh(x @ p["w"]),
                    {"w": jax.ShapeDtypeStruct((D, D), np.float32)},
                    (prev,), jax.ShapeDtypeStruct((1, D), np.float32),
                    flops=2.0 * D * D)
            prev = f"fc{i}"
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=2)
    eng.configure(params)
    eng.start()
    rec = eng.dispatcher.reconfigure((3,))     # boundary lands on relu3
    assert rec["changed"] and rec["acknowledged"]
    out = eng.submit(sample(5)).result(timeout=60)
    ref = np.asarray(g.apply(params, jnp.asarray(sample(5))))
    np.testing.assert_allclose(out, ref, atol=1e-5)
    for node in eng.dispatcher.nodes:
        assert all(t.is_alive() for t in node._threads)
    eng.shutdown()


def test_reconfigure_noop_and_validation():
    g = mlp_graph(8)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW)
    eng.configure(params)
    eng.start()
    assert eng.dispatcher.reconfigure((4,))["changed"] is False
    with pytest.raises(ValueError):
        eng.dispatcher.reconfigure((2, 5))     # wrong stage count
    eng.shutdown()


# -- controller closes the loop on a real chain ------------------------------

def test_controller_migrates_off_slow_node_and_keeps_serving():
    """Make node 0 artificially slow, drive controller steps under load:
    it must calibrate, migrate layers off node 0 (epoch advances), and
    every request before/during/after must resolve correctly."""
    g = mlp_graph(9)
    params = g.init(jax.random.PRNGKey(0))
    cfg = ControllerConfig(interval_s=30.0, ewma_alpha=1.0, hysteresis=0.05,
                           min_requests=8, cooldown_s=0.0,
                           precompile_after_swap=False)
    eng = InferenceEngine(g, 3, RAW, max_batch=4, controller=cfg)
    eng.configure(params)
    eng.start()                                # controller thread idles (30s)
    node0 = eng.dispatcher.nodes[0]
    orig = node0._apply
    node0._apply = lambda b: (time.sleep(0.05), orig(b))[1]
    futs = [eng.submit(sample(i), client_id=i % 2) for i in range(12)]
    for f in futs:
        f.result(timeout=60)
    action = eng.controller.step()             # deterministic control period
    assert action.kind == "repartition", action
    assert action.detail["acknowledged"]
    assert eng.dispatcher.partition.ranges()[0][1] < 3   # node 0 shrank
    # chain keeps serving correctly after the swap (the slow wrapper was
    # replaced by the migrated partition's fresh apply)
    futs = [eng.submit(sample(100 + i)) for i in range(6)]
    for i, f in enumerate(futs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(100 + i))))
        np.testing.assert_allclose(f.result(timeout=60), ref, atol=1e-5)
    rep = eng.report()
    eng.shutdown()
    assert rep.epoch == 1
    assert eng.controller.migrations == 1


def test_controller_holds_on_balanced_chain():
    """On a cost-balanced chain the deadband keeps the cuts put.  Tiny
    windows on tiny layers are noisy, so this uses a wide hysteresis —
    the tight-threshold semantics are covered synthetically above."""
    g = mlp_graph(9)
    params = g.init(jax.random.PRNGKey(0))
    cfg = ControllerConfig(interval_s=30.0, min_requests=4, hysteresis=0.75,
                           cooldown_s=0.0, adapt_knobs=False)
    eng = InferenceEngine(g, 3, RAW, max_batch=4, controller=cfg)
    eng.configure(params)
    eng.start()
    for i in range(8):
        eng.submit(sample(i)).result(timeout=60)
    action = eng.controller.step()
    eng.shutdown()
    assert action.kind == "hold"
    assert eng.controller.migrations == 0


def test_report_raw_utilization_unclamped():
    """util_*_raw report busy/wall honestly (can exceed the clamped 1.0
    ceiling); clamped fields stay within [0, 1]."""
    g = mlp_graph(6)
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=4)
    eng.configure(params)
    _, rep = eng.run([sample(i) for i in range(6)])
    eng.shutdown()
    for pn in rep.per_node:
        for stage in ("decode", "compute", "encode"):
            raw, clamped = pn[f"util_{stage}_raw"], pn[f"util_{stage}"]
            assert raw >= 0.0 and 0.0 <= clamped <= 1.0
            assert clamped == min(1.0, raw)
        assert pn["max_batch"] >= 1 and pn["coalesce_s"] >= 0.0
