"""Autoregressive decode through the pipeline (beyond-paper feature):
token-exact vs single-device greedy decode."""
import subprocess
import sys

import importlib

import jax
import jax.numpy as jnp
import pytest

from repro.launch.mesh import make_mesh_compat
from repro.launch.serve import build_pipeline_decoder
from repro.models import transformer as T


def _ref_greedy(cfg, params, start_m, mb, steps, max_len):
    caches = T.init_caches(cfg, mb, max_len, jnp.float32)
    tok = start_m
    out = []
    for p in range(steps):
        lg, caches = T.decode_step(params, cfg, tok,
                                   jnp.full((mb,), p, jnp.int32), caches)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        out.append(tok[:, 0])
    return jnp.stack(out)


@pytest.mark.parametrize("arch,M", [("phi3_mini_3_8b", 2),
                                    ("mamba2_2_7b", 3),
                                    ("zamba2_2_7b", 2)])
def test_pipeline_decode_matches_greedy_single_stage(arch, M):
    cfg = importlib.import_module(f"repro.configs.{arch}").smoke_config()
    params = T.init_lm(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh_compat((1,), ("stage",))
    mb, steps, max_len = 2, 4, 16
    start = jax.random.randint(jax.random.PRNGKey(1), (M, mb, 1), 0,
                               cfg.vocab)
    start_pos = jnp.zeros((M, mb), jnp.int32)
    fn, sw, caches0, head = build_pipeline_decoder(
        cfg, params, mesh, 1, M, mb, max_len, steps)
    with mesh:
        toks, _ = jax.jit(fn)(sw, caches0, start, start_pos, head)
    for m in range(M):
        ref = _ref_greedy(cfg, params, start[m], mb, steps, max_len)
        assert bool((toks[m] == ref).all()), (arch, m)


_MULTISTAGE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, importlib
from repro.launch.mesh import make_mesh_compat
from repro.launch.serve import build_pipeline_decoder
from repro.models import transformer as T

cfg = importlib.import_module("repro.configs.phi3_mini_3_8b").smoke_config()
params = T.init_lm(cfg, jax.random.PRNGKey(0))
mesh = make_mesh_compat((4,), ("stage",))
M, mb, steps, max_len = 6, 2, 5, 16
start = jax.random.randint(jax.random.PRNGKey(1), (M, mb, 1), 0, cfg.vocab)
start_pos = jnp.zeros((M, mb), jnp.int32)
fn, sw, caches0, head = build_pipeline_decoder(
    cfg, params, mesh, 4, M, mb, max_len, steps)
with mesh:
    toks, _ = jax.jit(fn)(sw, caches0, start, start_pos, head)
for m in range(M):
    caches = T.init_caches(cfg, mb, max_len, jnp.float32)
    tok = start[m]
    for p in range(steps):
        lg, caches = T.decode_step(params, cfg, tok,
                                   jnp.full((mb,), p, jnp.int32), caches)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        assert bool((toks[m, p] == tok[:, 0]).all()), (m, p)
print("OK")
"""


@pytest.mark.slow
def test_pipeline_decode_multistage_subprocess():
    r = subprocess.run([sys.executable, "-c", _MULTISTAGE],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
