"""Async serving runtime: concurrent multi-client submit, FIFO-per-client
ordering, admission backpressure, continuous batching, clean shutdown."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import LayerGraph
from repro.runtime import AdmissionFull, InferenceEngine
from repro.runtime.dispatcher import DispatcherCodecs
from repro.runtime.wire import WireCodec

D = 16


def mlp_graph(depth: int = 6, d: int = D) -> LayerGraph:
    g = LayerGraph("toy-mlp", jax.ShapeDtypeStruct((1, d), np.float32))
    prev = ""
    for i in range(depth):
        g.layer(f"fc{i}",
                lambda p, x: jnp.tanh(x @ p["w"]),
                {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
                (prev,),
                jax.ShapeDtypeStruct((1, d), np.float32),
                flops=2.0 * d * d)
        prev = f"fc{i}"
    return g


RAW = DispatcherCodecs(data=WireCodec("raw", "none"),
                       weights=WireCodec("raw", "none"))


def make_engine(num_nodes=4, **kw):
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, num_nodes, RAW, **kw)
    eng.configure(params)
    return g, params, eng


def sample(i: int) -> np.ndarray:
    rng = np.random.default_rng(i)
    return rng.normal(size=(1, D)).astype(np.float32)


def test_concurrent_submit_from_many_threads():
    """N client threads stream disjoint inputs concurrently; every client
    sees its own results, in its own submission order, numerically equal
    to the single-device reference."""
    g, params, eng = make_engine(num_nodes=4, max_batch=4)
    n_clients, per_client = 6, 5
    refs = {c: [np.asarray(g.apply(params, jnp.asarray(sample(100 * c + i))))
                for i in range(per_client)] for c in range(n_clients)}
    results: dict[int, list] = {}
    errors: list = []

    def client(c):
        try:
            xs = [sample(100 * c + i) for i in range(per_client)]
            results[c] = list(eng.submit_stream(xs, client_id=c))
        except Exception as e:                      # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    eng.shutdown()
    assert not errors
    for c in range(n_clients):
        assert len(results[c]) == per_client
        for got, ref in zip(results[c], refs[c]):
            np.testing.assert_allclose(got, ref, atol=1e-5)


def test_fifo_per_client_ordering_under_interleaving():
    """Interleaved submits from two clients: each client's futures resolve
    to exactly its own inputs' outputs, in submission order."""
    g, params, eng = make_engine(num_nodes=3, max_batch=8)
    futs = {0: [], 1: []}
    inputs = {0: [], 1: []}
    for i in range(10):
        c = i % 2
        x = sample(i)
        inputs[c].append(x)
        futs[c].append(eng.submit(x, client_id=c))
    for c in (0, 1):
        for fut, x in zip(futs[c], inputs[c]):
            ref = np.asarray(g.apply(params, jnp.asarray(x)))
            np.testing.assert_allclose(fut.result(timeout=30), ref,
                                       atol=1e-5)
    eng.shutdown()


def test_backpressure_bounded_admission():
    """With the head of the chain stalled, the bounded admission queue
    fills and submit() raises (non-blocking) or times out (blocking)."""
    g, params, eng = make_engine(num_nodes=2, max_batch=1,
                                 admission_depth=2, queue_depth=1)
    gate = threading.Event()
    node0 = eng.dispatcher.nodes[0]
    orig_apply = node0._apply

    def stalled(boundary):
        gate.wait(timeout=60)
        return orig_apply(boundary)

    node0._apply = stalled
    # saturate: with the head stalled the system reaches a fixed point of
    # admitted requests (processing + inbox + pump hand + admission queue);
    # past that every put fails
    admitted = []
    fails = 0
    for i in range(32):                     # far more than total capacity
        try:
            admitted.append((i, eng.submit(sample(i), block=False)))
        except AdmissionFull:
            fails += 1
            time.sleep(0.02)
    assert fails > 0
    assert 2 <= len(admitted) < 32
    with pytest.raises(AdmissionFull):      # blocking submit times out too
        eng.submit(sample(99), block=True, timeout=0.2)
    gate.set()                              # unblock and let them finish
    for i, fut in admitted:
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(fut.result(timeout=60), ref, atol=1e-5)
    eng.shutdown()


def test_clean_shutdown_with_inflight_requests():
    """shutdown(drain=True) completes every admitted request before
    stopping the chain; later submits are refused."""
    g, params, eng = make_engine(num_nodes=3, max_batch=2)
    futs = [eng.submit(sample(i)) for i in range(12)]
    eng.shutdown(drain=True)
    for i, fut in enumerate(futs):
        assert fut.done()
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(fut.result(), ref, atol=1e-5)
    for node in eng.dispatcher.nodes:
        assert not any(t.is_alive() for t in node._threads)
    with pytest.raises(RuntimeError):
        eng.submit(sample(0))


def test_continuous_batching_actually_batches():
    """Stall the head node's compute stage, pile requests up, release: the
    next merge must compute >1 request in one apply (BatchTrace.n > 1), and
    the staged egress must encode the merged batch in fewer codec passes
    than it has requests (batch-level wire encoding)."""
    g, params, eng = make_engine(num_nodes=2, max_batch=8,
                                 admission_depth=64, queue_depth=8)
    gate = threading.Event()
    node0 = eng.dispatcher.nodes[0]
    orig_apply = node0._apply
    node0._apply = lambda b: (gate.wait(timeout=60), orig_apply(b))[1]
    futs = [eng.submit(sample(i)) for i in range(6)]
    # all six are admitted (submit returns post-admission); give the
    # ingress stage a moment to decode them into the compute queue
    deadline = time.perf_counter() + 10
    while node0._to_compute.qsize() < 2 and time.perf_counter() < deadline:
        time.sleep(0.01)
    time.sleep(0.1)
    gate.set()
    outs = [f.result(timeout=60) for f in futs]
    eng.shutdown()
    big = max(node0.traces, key=lambda t: t.n)
    assert big.n > 1
    assert big.encodes < big.n          # one encode per bucket, not per req
    for i, out in enumerate(outs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(out, ref, atol=1e-5)


def test_report_serving_metrics():
    """EngineReport exposes per-node per-stage utilization, queue depth,
    batch occupancy, and latency percentiles over the measurement window.
    Stage utilizations are fractions of the reset->report wall clock, so
    each stays in [0, 1] even though the three stages overlap."""
    g, params, eng = make_engine(num_nodes=4, max_batch=4)
    xs = [sample(i) for i in range(8)]
    outs, rep = eng.run(xs)
    eng.shutdown()
    assert rep.samples == 8 and len(outs) == 8
    assert rep.p50_latency_s > 0 and rep.p99_latency_s >= rep.p50_latency_s
    for pn in rep.per_node:
        for key in ("utilization", "util_decode", "util_compute",
                    "util_encode"):
            assert 0.0 <= pn[key] <= 1.0
        assert pn["queue_depth_max"] >= 1
        assert pn["batch_mean"] >= 1.0
    assert any(pn["utilization"] > 0 for pn in rep.per_node)


def test_stage_overlap_observable():
    """The 3-stage split books codec time on the ingress/egress threads:
    after a real run every node shows nonzero decode and encode busy time
    recorded separately from compute (the overlap the staging buys)."""
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(
        g, 3, DispatcherCodecs(data=WireCodec("zfp", "none", zfp_rate=16),
                               weights=WireCodec("raw", "none")),
        max_batch=4)
    eng.configure(params)
    outs, rep = eng.run([sample(i) for i in range(12)])
    eng.shutdown()
    for node in eng.dispatcher.nodes:
        assert node.busy_decode_s > 0
        assert node.busy_compute_s > 0
        assert node.busy_encode_s > 0
    assert len(outs) == 12


def test_error_propagation_fails_future_keeps_node_alive():
    """An exception inside a node's apply fails exactly the affected
    requests' futures (with the remote traceback) and the chain keeps
    serving subsequent batches."""
    from repro.runtime import NodeError
    g, params, eng = make_engine(num_nodes=3, max_batch=2)
    node1 = eng.dispatcher.nodes[1]
    orig_apply = node1._apply
    state = {"boom": True}

    def flaky(boundary):
        if state["boom"]:
            state["boom"] = False
            raise ValueError("injected-apply-failure")
        return orig_apply(boundary)

    node1._apply = flaky
    bad = eng.submit(sample(0))
    with pytest.raises(NodeError) as ei:
        bad.result(timeout=60)
    assert "injected-apply-failure" in str(ei.value)   # remote traceback
    # the node survived: a later request completes correctly
    good = eng.submit(sample(1)).result(timeout=60)
    ref = np.asarray(g.apply(params, jnp.asarray(sample(1))))
    np.testing.assert_allclose(good, ref, atol=1e-5)
    for node in eng.dispatcher.nodes:
        assert all(t.is_alive() for t in node._threads)
    eng.shutdown()


def test_error_propagation_codec_failure():
    """A decode failure mid-chain also fails the future instead of
    stranding it (corrupt blob injected at the head node's outbox)."""
    from repro.runtime import NodeError
    g, params, eng = make_engine(num_nodes=2, max_batch=1)
    node1 = eng.dispatcher.nodes[1]
    state = {"boom": True}

    class Corrupting:
        def __init__(self, inner):
            self._inner = inner

        def decode_tree(self, blob):
            if state["boom"]:
                state["boom"] = False
                raise ValueError("injected-decode-failure")
            return self._inner.decode_tree(blob)

        def __getattr__(self, name):
            return getattr(self._inner, name)

    node1.data_codec = Corrupting(node1.data_codec)
    bad = eng.submit(sample(0))
    with pytest.raises(NodeError):
        bad.result(timeout=60)
    good = eng.submit(sample(1)).result(timeout=60)
    ref = np.asarray(g.apply(params, jnp.asarray(sample(1))))
    np.testing.assert_allclose(good, ref, atol=1e-5)
    eng.shutdown()


def test_error_isolated_to_failing_bucket():
    """When a merged group spans two shape buckets and only one bucket's
    apply raises, the sibling bucket's requests still succeed."""
    from repro.runtime import NodeError
    g, params, eng = make_engine(num_nodes=2, max_batch=8)
    node0 = eng.dispatcher.nodes[0]
    gate = threading.Event()
    orig_apply = node0._apply

    def selective(boundary):
        gate.wait(timeout=60)
        if next(iter(boundary.values())).ndim == 3:   # the (1, 8, D) bucket
            raise ValueError("bucket-poison")
        return orig_apply(boundary)

    node0._apply = selective
    x_ok = sample(0)                                  # (1, D)
    x_bad = np.stack([sample(1)] * 8, axis=1)         # (1, 8, D): own bucket
    f_ok = eng.submit(x_ok)
    f_bad = eng.submit(x_bad)
    deadline = time.perf_counter() + 10
    while (node0._to_compute.qsize() + node0.inbox.qsize()) < 1 \
            and time.perf_counter() < deadline:
        time.sleep(0.01)
    time.sleep(0.1)
    gate.set()
    with pytest.raises(NodeError, match="bucket-poison"):
        f_bad.result(timeout=60)
    ref = np.asarray(g.apply(params, jnp.asarray(x_ok)))
    np.testing.assert_allclose(f_ok.result(timeout=60), ref, atol=1e-5)
    eng.shutdown()


def test_unstaged_mode_parity():
    """The kept PR 1 single-thread path (staged=False, per-request wire)
    still produces correct results — it is the serve_load A/B baseline."""
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 3, RAW, max_batch=4, staged=False)
    eng.configure(params)
    outs, rep = eng.run([sample(i) for i in range(8)])
    eng.shutdown()
    for i, out in enumerate(outs):
        ref = np.asarray(g.apply(params, jnp.asarray(sample(i))))
        np.testing.assert_allclose(out, ref, atol=1e-5)
    # per-request wire: one encode per request, not per bucket
    tr = [t for n in eng.dispatcher.nodes for t in n.traces if t.n]
    assert all(t.encodes == t.n for t in tr if t.encodes)


# -- per-request deadlines (the reliability layer's reaper) -------------------

def slow_mlp_graph(delay_s: float = 0.4, d: int = D) -> LayerGraph:
    """One-layer MLP whose compute dwells ``delay_s`` on the host (via a
    callback, so the dwell survives jit) — deterministic loser of any
    race against a sub-dwell deadline."""
    g = LayerGraph("slow-mlp", jax.ShapeDtypeStruct((1, d), np.float32))

    def nap(xh):
        time.sleep(delay_s)
        return np.asarray(xh)

    def fn(p, x):
        x = jax.pure_callback(nap, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return jnp.tanh(x @ p["w"])

    g.layer("fc0", fn, {"w": jax.ShapeDtypeStruct((d, d), np.float32)},
            ("",), jax.ShapeDtypeStruct((1, d), np.float32),
            flops=2.0 * d * d)
    return g


def test_deadline_expires_before_slow_result_late_result_dropped():
    """A 0.05s deadline against a 0.4s compute: the future fails with
    DeadlineExceeded well before the result exists, the late result is
    dropped by the at-most-once merge (never delivered), retention is
    cleaned up, and the chain keeps serving."""
    from repro.runtime.dispatcher import DeadlineExceeded
    g = slow_mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 1, RAW, max_batch=1)
    eng.configure(params)
    eng.start()
    # warm: compile outside the timed window
    eng.submit(sample(0)).result(timeout=60)

    t0 = time.monotonic()
    fut = eng.submit(sample(1), deadline_s=0.05)
    with pytest.raises(DeadlineExceeded, match="0.05"):
        fut.result(timeout=30)
    took = time.monotonic() - t0
    assert took < 5.0, f"deadline fired after {took:.2f}s, not ~0.05s"
    assert eng.dispatcher.replay_stats.deadlines_expired == 1
    # the late result resolves to a no-op; the NEXT submit still works
    # and retention holds no ghost of the expired request
    ref = np.asarray(g.apply(params, jnp.asarray(sample(2))))
    np.testing.assert_allclose(eng.submit(sample(2)).result(timeout=60),
                               ref, atol=1e-5)
    assert not eng.dispatcher._retained
    eng.shutdown()


def test_deadline_met_resolves_normally_and_cleans_retention():
    """A generous deadline never fires: the result arrives, the timer
    event resolves to a no-op, and the retained entry is dropped on
    delivery, not on expiry."""
    g = mlp_graph()
    params = g.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(g, 2, RAW, max_batch=2)
    eng.configure(params)
    eng.start()
    ref = np.asarray(g.apply(params, jnp.asarray(sample(3))))
    out = eng.submit(sample(3), deadline_s=60.0).result(timeout=60)
    np.testing.assert_allclose(out, ref, atol=1e-5)
    assert eng.dispatcher.replay_stats.deadlines_expired == 0
    assert not eng.dispatcher._retained
    eng.shutdown()
