"""Autoregressive decode serving: v4 session extents on the wire, the
small-payload bypass, end-to-end multi-session generation (inproc AND
tcp) bit-identical to the single-device reference, per-step cross-hop
payloads O(d_model) instead of O(sequence), session survival across
scale()/reconfigure() fences, SessionLost semantics when recovery is
forbidden, LRU-eviction recovery, and the stream()/submit_stream()
deprecation shim.  The SIGKILL-mid-generation drill lives in
test_chaos.py."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.runtime import InferenceEngine, TopologySpec
from repro.runtime.dispatcher import (DispatcherCodecs, NodeError,
                                      RetryPolicy)
from repro.runtime.session import (SessionLost, SessionStore,
                                   live_session_stores)
from repro.runtime.wire import (_RAW_BYPASS_MAGIC, BatchEnvelope, RowExtent,
                                WireCodec, frame, unframe)
from repro.models.lm_graph import decode_lm_graph, pipeline_decode_reference
from tests._worker_graphs import lm_graph, mlp_graph

# lossless data path so greedy decode is bit-identical across hops; the
# bypass threshold exercises the small-frame fast path on every step
DATA = WireCodec("raw", "lz4", small_bypass=4096)
CODECS = DispatcherCodecs(data=DATA, weights=WireCodec("raw", "none"))

PROMPTS = [[1, 5, 9, 2], [3, 3, 7], [2, 8, 4, 6, 1], [11, 0, 5, 5]]


def build(topology=None, graph=None, **kw):
    g = graph if graph is not None else lm_graph()
    params = g.init(jax.random.PRNGKey(0))
    topo = topology if topology is not None else TopologySpec.chain(g, 2)
    kw.setdefault("codecs", CODECS)
    kw.setdefault("max_batch", 4)
    eng = InferenceEngine(g, topo, **kw)
    eng.configure(params)
    return g, params, eng


def refs(g, params, prompts, m):
    return [pipeline_decode_reference(g, params, p, m) for p in prompts]


def run_sessions(eng, prompts, m, **gen_kw):
    """Drive one generate() per prompt on its own thread (concurrent
    sessions at DIFFERENT sequence positions); return the token lists,
    re-raising the first worker failure."""
    outs: list[list[int]] = [[] for _ in prompts]
    errs: list[BaseException] = []

    def one(i, p):
        try:
            for tok in eng.generate(p, m, **gen_kw):
                outs[i].append(tok)
        except BaseException as e:      # noqa: BLE001 - re-raised below
            errs.append(e)

    ts = [threading.Thread(target=one, args=(i, p))
          for i, p in enumerate(prompts)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(300)
    assert not any(t.is_alive() for t in ts), "generation hung"
    if errs:
        raise errs[0]
    return outs


# -- wire: v4 session extents -------------------------------------------------

def test_session_extents_roundtrip_on_the_wire():
    exts = [RowExtent(7, "c1", 0, 1, kind=1, pos=0, session="sess-a"),
            RowExtent(8, "c2", 1, 1, kind=2, pos=13, session="sess-b"),
            RowExtent(9, "c3", 2, 1)]  # plain rows carry the defaults
    blob = frame(BatchEnvelope(exts, b"payload"))
    back = unframe(blob)
    assert [(e.kind, e.pos, e.session) for e in back.extents] == \
        [(1, 0, "sess-a"), (2, 13, "sess-b"), (0, 0, None)]
    assert back.extents[0].client_id == "c1"
    assert back.blob == b"payload"


# -- wire: small-payload bypass -----------------------------------------------

def test_small_bypass_is_lossless_through_a_lossy_codec():
    lossy = WireCodec("q8", "lz4", small_bypass=4096)
    small = np.random.default_rng(0).normal(size=(1, 1, 16)) \
        .astype(np.float32)
    blob = lossy.encode_array(small)
    assert blob.startswith(_RAW_BYPASS_MAGIC)
    np.testing.assert_array_equal(lossy.decode_array(blob), small)
    # above the threshold the configured (lossy) codec path still runs
    big = np.random.default_rng(1).normal(size=(1, 300, 16)) \
        .astype(np.float32)
    blob = lossy.encode_array(big)
    assert not blob.startswith(_RAW_BYPASS_MAGIC)
    back = lossy.decode_array(blob)
    assert not np.array_equal(back, big)        # quantized, not copied
    np.testing.assert_allclose(back, big, atol=1e-1)


def test_small_bypass_zero_disables():
    codec = WireCodec("q8", "none", small_bypass=0)
    arr = np.ones((1, 4), np.float32)
    assert not codec.encode_array(arr).startswith(_RAW_BYPASS_MAGIC)


# -- end-to-end decode: inproc + tcp ------------------------------------------

def test_decode_inproc_multi_session_bit_identical():
    g, params, eng = build()
    try:
        eng.start()
        m = 8
        outs = run_sessions(eng, PROMPTS[:3], m)
        assert outs == refs(g, params, PROMPTS[:3], m)
    finally:
        eng.shutdown()


def test_decode_tcp_replicated_multi_session_bit_identical():
    g0 = lm_graph()
    topo = TopologySpec.chain(g0, 2, transport="tcp").with_replicas(0, 2)
    g, params, eng = build(topology=topo, graph=g0)
    try:
        eng.start()
        m = 8
        outs = run_sessions(eng, PROMPTS, m)
        assert outs == refs(g, params, PROMPTS, m)
    finally:
        eng.shutdown()


def test_step_payload_is_10x_smaller_than_full_sequence_resend():
    """THE decode contract: after prefill, each hop ships one token's
    activations — O(d_model) — not the growing sequence.  Measured on the
    stage-0 replica's outbound wire bytes, against what resending the
    full sequence through the same codec would cost per step."""
    g, params, eng = build()
    prompt, m = [1, 2, 3, 4, 5, 6, 7, 8], 30
    try:
        eng.start()
        gen = eng.generate(prompt, m)
        next(gen)                      # prefill + first token
        node = eng.dispatcher.stages[0].live_replicas()[0]
        node.reset_stats()
        for _ in range(m - 1):
            next(gen)
        per_step = node.snapshot()["payload_bytes"] / (m - 1)
        gen.close()
        # the full-sequence alternative: the final-prefix boundary
        # activations through the SAME codec
        d_model = 16
        full = np.zeros((1, len(prompt) + m, d_model), np.float32)
        full_bytes = len(DATA.encode_array(full))
        assert full_bytes / per_step >= 10.0, \
            f"per-step hop payload {per_step:.0f}B vs full-sequence " \
            f"resend {full_bytes}B: less than 10x saving"
    finally:
        eng.shutdown()


# -- elasticity: sessions survive scale() and reconfigure() -------------------

def _wait_tokens(outs, k, timeout=120.0):
    deadline = time.monotonic() + timeout
    while not all(len(o) >= k for o in outs):
        assert time.monotonic() < deadline, \
            f"sessions never reached {k} tokens: {[len(o) for o in outs]}"
        time.sleep(0.01)


def test_scale_during_generation_drops_zero_sessions():
    g0 = lm_graph()
    topo = TopologySpec.chain(g0, 2).with_replicas(0, 2)
    g, params, eng = build(
        topology=topo, graph=g0,
        retry_policy=RetryPolicy(max_attempts=4, backoff_s=0.05,
                                 retry_budget=64.0, refill_per_s=32.0))
    m = 12
    outs: list[list[int]] = [[] for _ in PROMPTS]
    errs: list[BaseException] = []

    def one(i, p):
        try:
            for tok in eng.generate(p, m):      # restart='auto' + policy
                outs[i].append(tok)
        except BaseException as e:      # noqa: BLE001 - asserted below
            errs.append(e)

    try:
        eng.start()
        ts = [threading.Thread(target=one, args=(i, p))
              for i, p in enumerate(PROMPTS)]
        for t in ts:
            t.start()
        _wait_tokens(outs, 2)
        # drain one replica (displaces its pinned sessions), then regrow
        eng.scale(0, 1)
        eng.scale(0, 2)
        for t in ts:
            t.join(300)
        assert not any(t.is_alive() for t in ts), "generation hung"
        assert not errs, f"sessions dropped across scale(): {errs}"
        assert outs == refs(g, params, PROMPTS, m)
    finally:
        eng.shutdown()


def test_reconfigure_during_generation_migrates_sessions():
    """A repartition invalidates EVERY stage's resident KV (layer ranges
    moved); active sessions must re-prefill onto the new partitioning and
    finish bit-identical — restart='always' needs no retry policy."""
    g, params, eng = build()
    m = 12
    outs: list[list[int]] = [[] for _ in PROMPTS[:3]]
    errs: list[BaseException] = []

    def one(i, p):
        try:
            for tok in eng.generate(p, m, restart="always"):
                outs[i].append(tok)
        except BaseException as e:      # noqa: BLE001 - asserted below
            errs.append(e)

    try:
        eng.start()
        ts = [threading.Thread(target=one, args=(i, p))
              for i, p in enumerate(PROMPTS[:3])]
        for t in ts:
            t.start()
        _wait_tokens(outs, 2)
        eng.dispatcher.reconfigure([2])     # 6 layers: [0,3,6] -> [0,2,6]
        for t in ts:
            t.join(300)
        assert not any(t.is_alive() for t in ts), "generation hung"
        assert not errs, f"sessions dropped across reconfigure(): {errs}"
        assert outs == refs(g, params, PROMPTS[:3], m)
    finally:
        eng.shutdown()


# -- loss of residency: SessionLost vs re-prefill -----------------------------

def test_eviction_with_restart_never_raises_sessionlost():
    """KV capacity 1: opening a second session evicts the first.  With
    restart='never' the evicted session raises SessionLost
    (retryable=False) — and the chain keeps serving other sessions AND
    plain single-shot traffic."""
    g0 = lm_graph()
    topo = TopologySpec.chain(g0, 2, session_capacity=1)
    g, params, eng = build(topology=topo, graph=g0)
    try:
        eng.start()
        s1 = eng.generate(PROMPTS[0], 4, restart="never")
        t1 = [next(s1)]                         # s1 resident
        s2 = eng.generate(PROMPTS[1], 4, restart="never")
        t2 = [next(s2)]                         # evicts s1 (capacity 1)
        assert SessionLost.retryable is False
        with pytest.raises(SessionLost):
            next(s1)
        # the survivor and one-shot traffic are unharmed
        t2.append(next(s2))
        s2.close()
        assert t2 == pipeline_decode_reference(g, params, PROMPTS[1], 4)[:2]
        x = np.asarray([PROMPTS[2]], np.int32)
        np.testing.assert_allclose(
            eng.submit(x).result(timeout=60),
            np.asarray(g.apply(params, x)), atol=1e-4)
    finally:
        eng.shutdown()


def test_eviction_thrash_recovered_by_reprefill():
    """Same capacity-1 store, restart='always': two interleaved sessions
    evict each other every step, and every step recovers by re-prefilling
    the retained history — slow, but bit-identical."""
    g0 = lm_graph()
    topo = TopologySpec.chain(g0, 2, session_capacity=1)
    g, params, eng = build(topology=topo, graph=g0)
    m = 5
    try:
        eng.start()
        gens = [eng.generate(p, m, restart="always") for p in PROMPTS[:2]]
        outs = [[], []]
        for _ in range(m):
            for o, gen in zip(outs, gens):
                o.append(next(gen))
        for gen in gens:
            gen.close()
        assert outs == refs(g, params, PROMPTS[:2], m)
    finally:
        eng.shutdown()


def test_legacy_unstaged_runtime_refuses_sessions():
    g, params, eng = build(staged=False)
    try:
        eng.start()
        with pytest.raises(SessionLost) as ei:
            next(eng.generate(PROMPTS[0], 2, restart="never"))
        assert "staged" in str(ei.value.__cause__)
    finally:
        eng.shutdown()


# -- generate() argument validation -------------------------------------------

def test_generate_validates_arguments():
    g, params, eng = build()
    try:
        eng.start()
        with pytest.raises(ValueError, match="non-empty prompt"):
            next(eng.generate([], 4))
        with pytest.raises(ValueError, match="max_new_tokens"):
            next(eng.generate([1, 2], 0))
        with pytest.raises(ValueError, match="KV capacity"):
            next(eng.generate([1, 2], 10_000))      # cache_len is 48
        with pytest.raises(ValueError, match="restart"):
            next(eng.generate([1, 2], 4, restart="sometimes"))
    finally:
        eng.shutdown()


def test_generate_requires_decode_capable_graph():
    g, params, eng = build(graph=mlp_graph())
    try:
        with pytest.raises(ValueError, match="not decode-capable"):
            next(eng.generate([1, 2], 4))
    finally:
        eng.shutdown(drain=False)


# -- stream() deprecation shim ------------------------------------------------

def test_stream_is_a_deprecated_alias_for_submit_stream():
    g, params, eng = build()
    xs = [np.asarray([p], np.int32) for p in PROMPTS[:2]]
    try:
        eng.start()
        want = [np.asarray(g.apply(params, x)) for x in xs]
        with pytest.warns(DeprecationWarning, match="submit_stream"):
            got = list(eng.stream(xs))
        for w, o in zip(want, got):
            np.testing.assert_allclose(o, w, atol=1e-4)
    finally:
        eng.shutdown()


# -- SessionStore unit semantics ----------------------------------------------

def test_session_store_lru_eviction_and_registry():
    store = SessionStore(capacity=2)
    assert store in live_session_stores()
    store.put("a", 1)
    store.put("b", 2)
    assert store.get("a") == 1          # refreshes a's LRU slot
    store.put("c", 3)                   # evicts b, the least recent
    assert store.get("b") is None
    assert sorted(store.keys()) == ["a", "c"]
    assert store.pop("a") == 1 and store.pop("a") is None
    store.put("d", 4)
    store.clear()                       # the conftest residue guard's path
    assert len(store) == 0
