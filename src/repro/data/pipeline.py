"""Deterministic synthetic data pipeline (token LM + image batches).

Seeded, shard-aware, infinite; a background thread keeps a small prefetch
queue full so the train loop never blocks on generation.  The token stream
is a structured Markov-ish source (not uniform noise) so cross-entropy has
learnable signal — the end-to-end example's loss must visibly drop.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class TokenStream:
    """Synthetic LM batches {tokens [B,S], labels [B,S]}.

    A per-sequence hidden phase drives a noisy arithmetic progression over
    the vocab, giving next-token structure a model can learn.  ``shard``/
    ``num_shards`` slice the global batch for multi-host feeding.
    """

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0,
                 shard: int = 0, num_shards: int = 1,
                 prefix_embeds: tuple[int, int] | None = None,
                 encoder_embeds: tuple[int, int] | None = None):
        assert batch % num_shards == 0
        self.vocab = vocab
        self.local_batch = batch // num_shards
        self.seq_len = seq_len
        self.seed = seed
        self.shard = shard
        self.prefix_embeds = prefix_embeds       # (n, d) stub frontend output
        self.encoder_embeds = encoder_embeds
        self._step = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self._step) * 97 + self.shard)
        self._step += 1
        B, S, V = self.local_batch, self.seq_len, self.vocab
        start = rng.integers(0, V, (B, 1))
        stride = rng.integers(1, 7, (B, 1))
        base = (start + stride * np.arange(S + 1)[None]) % V
        noise = rng.integers(0, V, (B, S + 1))
        mask = rng.random((B, S + 1)) < 0.1
        toks = np.where(mask, noise, base).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.prefix_embeds:
            n, d = self.prefix_embeds
            out["prefix_embeds"] = rng.normal(size=(B, n, d)).astype(np.float32) * 0.02
        if self.encoder_embeds:
            n, d = self.encoder_embeds
            out["encoder_embeds"] = rng.normal(size=(B, n, d)).astype(np.float32) * 0.02
        return out


class ImageStream:
    """Synthetic NHWC image batches (for the CNN / edge-emulation path)."""

    def __init__(self, batch: int, image: int = 224, channels: int = 3,
                 seed: int = 0):
        self.batch, self.image, self.channels = batch, image, channels
        self.seed = seed
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 7919 + self._step)
        self._step += 1
        # smooth, activation-like images (compressible, like real photos)
        x = rng.normal(size=(self.batch, self.image, self.image, self.channels))
        x = x.cumsum(axis=1).cumsum(axis=2)
        x /= np.abs(x).max() + 1e-9
        return x.astype(np.float32)


class Prefetcher:
    """Background-thread prefetch wrapper around any iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_lm_iter(cfg, batch: int, seq_len: int, seed: int = 0,
                 shard: int = 0, num_shards: int = 1, prefetch: int = 2):
    """Token iterator matched to a ModelConfig (adds stub frontend embeds)."""
    prefix = (cfg.num_prefix_embeds, cfg.d_model) \
        if cfg.num_prefix_embeds and not cfg.encoder_layers else None
    enc = (cfg.num_prefix_embeds, cfg.d_model) if cfg.encoder_layers else None
    stream = TokenStream(cfg.vocab, batch, seq_len, seed, shard, num_shards,
                         prefix_embeds=prefix, encoder_embeds=enc)
    return Prefetcher(stream, prefetch) if prefetch else stream
