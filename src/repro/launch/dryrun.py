import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

Proves the distribution config is coherent without hardware: ``jax.jit``
with the baseline shardings must lower AND compile for the 16x16 single-pod
mesh and the 2x16x16 multi-pod mesh, for every assigned architecture and
input shape.  Emits per-pair JSON artifacts (memory analysis, cost analysis,
per-collective byte counts parsed from the partitioned HLO) that
``benchmarks/roofline.py`` turns into the §Roofline table.

Usage:
    python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import (ARCHS, all_pairs, get_config, get_shape,  # noqa: E402
                                    pair_supported)
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import step_for  # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+[\w\-]+\(")
_OPND_RE = re.compile(r"(%[\w.\-]+)")
_OP_RE = re.compile(r"=\s*(?:\([^=]*?\)|\S+)\s+([a-z0-9\-]+)(?:-start)?\(")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes of every collective op in (partitioned) HLO text.

    This HLO dialect does not print operand types inline, so first build an
    SSA-name -> result-shape-bytes map from every defining line, then charge
    each collective op the sum of its operands' bytes.  (Per-device program:
    shapes are already the post-SPMD shards.)
    """
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = sum(
                _shape_bytes(s) for s in _SHAPE_RE.finditer(m.group(2)))
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        args = stripped[m.end():]
        depth, end = 1, len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        out[base] += sum(sizes.get(name, 0)
                         for name in _OPND_RE.findall(args[:end]))
    return out


def _compile_pair(cfg, shape, mesh, unroll: bool, fsdp: bool | None = None):
    step = step_for(cfg, shape.kind, unroll=unroll)
    args = S.input_specs(cfg, shape)
    shardings = S.to_shardings(S.input_pspecs(cfg, shape, mesh, fsdp=fsdp),
                               mesh)
    order = list(args.keys())
    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(step, in_shardings=tuple(shardings[k] for k in order))
        lowered = jitted.lower(*(args[k] for k in order))
        compiled = lowered.compile()
    return compiled, time.perf_counter() - t0


def _mem_dict(compiled) -> dict:
    mem = compiled.memory_analysis()
    return {"argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0))}


def _cost_dict(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):    # JAX <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
           "transcendentals": float(cost.get("transcendentals", 0.0))}
    out["collective_bytes"] = collective_bytes(compiled.as_text())
    return out


def reduced_depth(cfg, k: int):
    """Same config with k repeating units (remainder layers kept)."""
    import dataclasses
    u = cfg.unit_layers
    rem = cfg.num_layers % u
    upd = {"num_layers": u * k + rem}
    if cfg.encoder_layers:
        upd["encoder_layers"] = k
    return dataclasses.replace(cfg, **upd)


def _extrapolate(c2: dict, c4: dict, n_units: int) -> dict:
    """Linear-in-units extrapolation of per-device cost terms.

    XLA's cost analysis counts while-loop bodies once, so the full-depth
    scan lowering undercounts; instead we lower the model UNROLLED at 2 and
    4 units (cheap to compile), take the exact per-unit delta, and
    extrapolate: cost(L) = cost(4u) + (n_units - 4)/2 * (cost(4u) - cost(2u)).
    Unit costs stack exactly linearly (verified in tests on 2/4/6 units).
    """
    scale = (n_units - 4) / 2.0

    def ext(a, b):
        return max(0.0, b + scale * (b - a))

    out = {k: ext(c2[k], c4[k]) for k in ("flops", "bytes_accessed",
                                          "transcendentals")}
    out["collective_bytes"] = {
        k: int(ext(c2["collective_bytes"][k], c4["collective_bytes"][k]))
        for k in c4["collective_bytes"]}
    return out


def dryrun_pair(arch: str, shape_name: str, multi_pod: bool = False,
                verbose: bool = True, with_cost: bool = True,
                cfg_overrides: dict | None = None) -> dict:
    """Lower + compile one (arch, shape, mesh); return the roofline artifact.

    Three lowerings: the full-depth scan program (the production program —
    its compile success is the dry-run gate, its memory analysis has real
    buffer reuse) and, for single-pod cost accounting, two reduced-depth
    unrolled programs whose per-unit cost delta extrapolates to full depth.

    ``cfg_overrides``: dataclasses.replace overrides for §Perf variants
    (e.g. {"vocab_pad_multiple": 128}).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    ok, reason = pair_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skip", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    fsdp = S.needs_fsdp(cfg, shape.kind, mesh)
    compiled, t_full = _compile_pair(cfg, shape, mesh, unroll=False,
                                     fsdp=fsdp)
    art = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "chips": mesh.size, "kind": shape.kind,
        "fsdp": fsdp, "compile_s": round(t_full, 2),
        "memory": _mem_dict(compiled),
    }
    if with_cost and not multi_pod:
        c2, t2 = _compile_pair(reduced_depth(cfg, 2), shape, mesh,
                               unroll=True, fsdp=fsdp)
        c4, t4 = _compile_pair(reduced_depth(cfg, 4), shape, mesh,
                               unroll=True, fsdp=fsdp)
        n_units = cfg.num_layers // cfg.unit_layers
        art["cost"] = _extrapolate(_cost_dict(c2), _cost_dict(c4), n_units)
        art["cost_compile_s"] = round(t2 + t4, 2)
        art["collective_total"] = int(
            sum(art["cost"]["collective_bytes"].values()))
    if verbose:
        mb = art["memory"]
        msg = (f"{arch:26s} {shape_name:12s} pods={2 if multi_pod else 1} "
               f"compile={t_full:.1f}s arg={mb['argument_bytes']/1e9:.2f}GB "
               f"temp={mb['temp_bytes']/1e9:.2f}GB")
        if "cost" in art:
            msg += (f" flops={art['cost']['flops']:.3g} "
                    f"coll={art['collective_total']/1e6:.1f}MB")
        print(msg, flush=True)
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    pairs = all_pairs() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in pairs:
        for mp in meshes:
            try:
                art = dryrun_pair(arch, shape, multi_pod=mp)
            except Exception as e:  # a failure here is a bug in our sharding
                art = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                failures.append(art)
                print(f"FAIL {arch} {shape} multi_pod={mp}: {e}")
            fn = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}.json"
            with open(os.path.join(args.out, fn), "w") as f:
                json.dump(art, f, indent=1)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures")
    print("all dry-runs OK")


if __name__ == "__main__":
    main()
