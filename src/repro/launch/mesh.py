"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain the placeholder devices.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); "pod" is pure
data parallelism across the DCI/ICI-linked pods (DEFER's independent chains).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape: tuple[int, ...], axes: tuple[str, ...], **kw):
    """``jax.make_mesh`` across JAX versions.

    ``axis_types`` only exists on newer JAX (>= 0.5); the pinned 0.4.37
    raises ``AttributeError`` on ``jax.sharding.AxisType``.  Every mesh in
    this repo wants plain Auto axes, so simply omit the argument when the
    enum is unavailable — Auto is the default there anyway.
    """
    if hasattr(jax.sharding, "AxisType"):
        kw.setdefault(
            "axis_types", (jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int | None = None):
    """Mesh over whatever devices exist (CPU tests / smoke runs)."""
    n = jax.device_count()
    model = model or 1
    return make_mesh_compat((n // model, model), ("data", "model"))


def make_pipeline_mesh(num_stages: int, *, multi_pod: bool = False):
    """DEFER pipeline mesh: the chain lives on the "stage" axis (the
    single-pod "model" axis re-labelled); data axes replicate chains."""
    if multi_pod:
        shape = (2, 512 // (2 * num_stages), num_stages)
        axes = ("pod", "data", "stage")
    else:
        shape = (256 // num_stages, num_stages)
        axes = ("data", "stage")
    return make_mesh_compat(shape, axes)
