"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) pair.

``input_specs`` returns abstract inputs for the step the shape's *kind*
lowers (train_step / prefill_step / serve_step) — weak-type-correct,
shardable, zero allocation.  The modality frontends are stubs by
assignment: VLM/audio entries get precomputed patch/frame embeddings of
the right shape.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import init_opt_state

PARAM_DTYPE = jnp.bfloat16
ACT_DTYPE = jnp.bfloat16


def abstract_params(cfg: ModelConfig, dtype=PARAM_DTYPE):
    return T.abstract_params(cfg, dtype)


def abstract_opt_state(cfg: ModelConfig, dtype=PARAM_DTYPE):
    return jax.eval_shape(init_opt_state, abstract_params(cfg, dtype))


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=ACT_DTYPE):
    return jax.eval_shape(
        functools.partial(T.init_caches, cfg, batch, max_len, dtype))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, B: int, S: int, kind: str) -> dict:
    """The data-batch part of the step inputs."""
    out: dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    if kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
    if cfg.num_prefix_embeds and not cfg.encoder_layers:
        out["prefix_embeds"] = _sds((B, cfg.num_prefix_embeds, cfg.d_model),
                                    ACT_DTYPE)
    if cfg.encoder_layers:
        out["encoder_embeds"] = _sds((B, cfg.num_prefix_embeds, cfg.d_model),
                                     ACT_DTYPE)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract args for the step this shape lowers.

    train   -> {params, opt_state, batch}
    prefill -> {params, batch}
    decode  -> {params, token, pos, caches}
    """
    B, S = shape.global_batch, shape.seq_len
    params = abstract_params(cfg)
    if shape.kind == "train":
        return {"params": params,
                "opt_state": abstract_opt_state(cfg),
                "batch": batch_specs(cfg, B, S, "train")}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs(cfg, B, S, "prefill")}
    return {"params": params,
            "token": _sds((B, 1), jnp.int32),
            "pos": _sds((B,), jnp.int32),
            "caches": abstract_caches(cfg, B, S)}


HBM_BUDGET_PER_DEV = 6e9   # leave headroom below v5e's 16 GB for activations


def needs_fsdp(cfg: ModelConfig, kind: str, mesh: Mesh) -> str:
    """Weight/optimizer distribution mode for this (model, step, mesh).

    "none"  — tensor sharding alone fits.
    "zero1" — weights fit tensor-sharded but Adam state doesn't: shard ONLY
              the optimizer moments over the data axes (§Perf HC4 — full
              FSDP costs per-layer weight gathers + GSPMD reshards; on
              granite-34b ZeRO-1 cut collective bytes 8x and FLOPs 3x).
    "fsdp"  — even the bf16 weights exceed budget (dbrx, llama4): shard
              weights AND moments over the data axes.
    """
    bytes_per_param = 10 if kind == "train" else 2   # bf16 + 2x f32 moments
    model = mesh.shape["model"]
    if cfg.param_count() * bytes_per_param / model <= HBM_BUDGET_PER_DEV:
        return "none"
    if cfg.param_count() * 2 / model <= HBM_BUDGET_PER_DEV:
        return "zero1" if kind == "train" else "none"
    return "fsdp"


def input_pspecs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 fsdp: str | None = None) -> dict:
    """PartitionSpecs matching ``input_specs`` (baseline data x tensor;
    ZeRO-1 / FSDP auto-enabled for over-HBM models, see ``needs_fsdp``).

    ``fsdp=None`` decides from this config's size; the dry-run passes the
    FULL model's decision explicitly so its reduced-depth cost lowerings
    use the same scheme."""
    B = shape.global_batch
    b_axes = sh.input_batch_axes(B, mesh)
    bspec = P(b_axes) if b_axes else P()

    def batch_tree(tree):
        return jax.tree_util.tree_map(
            lambda l: P(b_axes, *([None] * (len(l.shape) - 1)))
            if b_axes else P(), tree)

    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    data_sizes = tuple(mesh.shape[a] for a in data_axes)
    if fsdp is None:
        fsdp = needs_fsdp(cfg, shape.kind, mesh)
    ab = abstract_params(cfg)
    params = sh.param_pspecs(
        ab, model_size=mesh.shape["model"],
        fsdp_axes=data_axes if fsdp == "fsdp" else None,
        fsdp_sizes=data_sizes if fsdp == "fsdp" else ())
    if shape.kind == "train":
        moments = params if fsdp != "zero1" else sh.param_pspecs(
            ab, model_size=mesh.shape["model"],
            fsdp_axes=data_axes, fsdp_sizes=data_sizes)
        return {"params": params,
                "opt_state": {"mu": moments, "nu": moments, "step": P()},
                "batch": batch_tree(batch_specs(cfg, B, shape.seq_len, "train"))}
    if shape.kind == "prefill":
        return {"params": params,
                "batch": batch_tree(batch_specs(cfg, B, shape.seq_len,
                                                "prefill"))}
    return {"params": params,
            "token": bspec if b_axes else P(),
            "pos": bspec if b_axes else P(),
            "caches": sh.cache_pspecs(abstract_caches(cfg, B, shape.seq_len),
                                      mesh)}


def to_shardings(pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
