"""Serving launcher: the DEFER pipeline as a first-class deployment path.

The dispatcher role (paper Algorithm 1) maps to this module: plan the
partition (units -> stages), shard the stacked stage weights over the
"stage" mesh axis, stream microbatches through the ppermute chain, collect
FIFO results.  The wire codec (int8 block quantization, the ZFP adaptation)
is a flag, exactly like the paper's codec configurations.

    python -m repro.launch.serve --arch phi3-mini-3.8b --stages 4 \
        --microbatches 8 --requests 32 --seq 64 [--compress]

``build_pipeline_lm`` is the reusable bridge: any ModelConfig ->
(stage weights, unit_fn, head/tail fns) consumable by core.pipeline.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, get_config, get_smoke
from repro.core.pipeline import PipelineConfig, make_pipeline, stack_stages
from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh, make_mesh_compat
from repro.models import layers as L
from repro.models import transformer as T


@dataclasses.dataclass
class PipelineLM:
    cfg: ModelConfig
    pipe_cfg: PipelineConfig
    stage_params: Any            # (stacked units, valid mask), stage-sharded
    extra: Any                   # replicated pytree (shared block) or None
    params: Any                  # full params (embed/head/rem live outside)
    fn: Callable                 # the sharded pipeline callable

    def __call__(self, tokens: jax.Array, prefix_embeds=None,
                 encoder_embeds=None) -> jax.Array:
        """tokens [B, S] with B = M * mb -> logits [B, S, V]."""
        cfg, M = self.cfg, self.pipe_cfg.num_microbatches
        B, S = tokens.shape
        assert B % M == 0, f"batch {B} must be M={M} microbatches"
        mb = B // M
        x = L.embed(self.params["embed"], tokens)
        x = T._fuse_prefix(cfg, x, prefix_embeds)

        if cfg.encoder_layers:
            enc_out, _ = T._encode(self.params, cfg, encoder_embeds)
            stream = {"h": x.reshape(M, mb, S, -1),
                      "enc": enc_out.reshape(M, mb, *enc_out.shape[1:])}
        else:
            stream = x.reshape(M, mb, S, -1)

        out = (self.fn(self.stage_params, stream) if self.extra is None
               else self.fn(self.stage_params, stream, self.extra))
        x = (out["h"] if isinstance(out, dict) else out).reshape(B, S, -1)

        # remainder layers + head run dispatcher-side (the tail of the chain)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        _, rem = divmod(cfg.num_layers, cfg.unit_layers)[0], \
            cfg.num_layers % cfg.unit_layers
        if rem:
            aux = jnp.zeros((), jnp.float32)
            for i in range(rem):
                up = T._tree_at(self.params["rem"], i)
                x, aux = T._apply_layer(up["pos0"], cfg, x, positions, aux,
                                        T._window_at(cfg, i))
        x = L.rmsnorm(self.params["final_ln"], x, cfg.norm_eps)
        logits = (L.unembed(self.params["embed"], x) if cfg.tie_embeddings
                  else L.linear(self.params["unembed"], x))
        return T._mask_pad_vocab(cfg, logits)


def make_unit_fn(cfg: ModelConfig, with_extra: bool, unroll: bool = False):
    """Masked multi-unit stage body over ``T._apply_unit``."""

    def apply_unit(up, x, extra):
        if isinstance(x, dict):
            h, enc = x["h"], x["enc"]
        else:
            h, enc = x, None
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        aux = jnp.zeros((), jnp.float32)
        shared = extra.get("shared") if extra else None
        h, _ = T._apply_unit(up, cfg, h, positions, aux, shared=shared,
                             enc_out=enc)
        return {"h": h, "enc": enc} if isinstance(x, dict) else h

    def stage_fn(local, x, extra=None):
        units, valid = local

        def body(hh, inp):
            up, ok = inp
            y = apply_unit(up, hh, extra)
            keep = jax.tree_util.tree_map(
                lambda a, b: jnp.where(ok, a, b), y, hh)
            return keep, None

        u = jax.tree_util.tree_leaves(units)[0].shape[0]
        out, _ = jax.lax.scan(body, x, (units, valid),
                              unroll=u if unroll else 1)
        return out

    if with_extra:
        return stage_fn
    return lambda local, x: stage_fn(local, x, None)


def build_pipeline_lm(cfg: ModelConfig, params: Any, mesh,
                      num_stages: int, num_microbatches: int,
                      compress: bool = False, quant_impl: str = "jnp",
                      axis: str = "stage",
                      data_axes: tuple[str, ...] = (),
                      unroll: bool = False) -> PipelineLM:
    n_units = cfg.num_layers // cfg.unit_layers
    stacked, valid = stack_stages(params["units"], n_units, num_stages)
    extra = {"shared": params["shared"]} if "shared" in params else None
    pipe_cfg = PipelineConfig(num_stages=num_stages,
                              num_microbatches=num_microbatches,
                              axis=axis, compress=compress,
                              quant_impl=quant_impl, unroll_ticks=unroll)
    fn = make_pipeline(mesh, pipe_cfg,
                       make_unit_fn(cfg, extra is not None, unroll=unroll),
                       data_axes=data_axes, with_extra=extra is not None)
    return PipelineLM(cfg, pipe_cfg, (stacked, valid), extra, params, fn)


# -- autoregressive decode THROUGH the pipeline (beyond-paper) -------------------

def build_pipeline_decoder(cfg: ModelConfig, params: Any, mesh,
                           num_stages: int, num_microbatches: int, mb: int,
                           max_len: int, steps: int, compress: bool = False,
                           axis: str = "stage"):
    """Decode pipeline: returns (fn, stage_params, caches0, head).

    fn(stage_params, caches, start_tok [M,mb,1], start_pos [M,mb])
        -> (tokens [M, steps, mb], new_caches)
    """
    from repro.core.pipeline import stack_stages
    from repro.core.pipeline_decode import make_pipeline_decoder

    assert cfg.num_layers % cfg.unit_layers == 0, \
        "decode pipeline needs an integral unit stack (no remainder layers)"
    n_units = cfg.num_layers // cfg.unit_layers
    stacked, valid = stack_stages(params["units"], n_units, num_stages)

    # per-microbatch cache slabs: [n_units, M, mb, ...] -> [S, u, M, mb, ...]
    M = num_microbatches
    base = T.init_caches(cfg, mb, max_len, jnp.float32)

    def stack_m(a):
        return jnp.broadcast_to(a[:, None], (a.shape[0], M) + a.shape[1:])

    unit_caches = jax.tree_util.tree_map(stack_m, base["units"])
    caches0, _ = stack_stages(unit_caches, n_units, num_stages)

    head = {"embed": params["embed"], "final_ln": params["final_ln"]}
    if not cfg.tie_embeddings:
        head["unembed"] = params["unembed"]
    if "shared" in params:
        head["shared"] = params["shared"]

    def embed_fn(hd, tok):
        return L.embed(hd["embed"], tok)

    def head_fn(hd, h):
        x = L.rmsnorm(hd["final_ln"], h, cfg.norm_eps)
        logits = (L.unembed(hd["embed"], x) if cfg.tie_embeddings
                  else L.linear(hd["unembed"], x))
        return T._mask_pad_vocab(cfg, logits)

    def decode_unit_fn(local_w, h, pos, mcache, hd):
        units, vmask = local_w
        shared = hd.get("shared")

        def body(carry, inp):
            hh = carry
            (up, ok), uc = inp
            h2 = hh
            ncs = {}
            for i in range(cfg.unit_layers):
                h2, nc = T._decode_layer(up[f"pos{i}"], cfg, h2, pos,
                                         uc[f"pos{i}"], T._window_at(cfg, i),
                                         None, False)
                ncs[f"pos{i}"] = nc
            if shared is not None:
                sc = uc["shared"]
                from repro.models import attention as attn_mod
                s = T.attn_spec(cfg, None)
                h2, nkv, nkpos = attn_mod.attention_decode(
                    shared["attn"], s, h2, pos, sc, sc["kpos"], cfg.norm_eps)
                h2 = L.mlp(shared["mlp"], h2, cfg.norm_eps)
                ncs["shared"] = {**nkv, "kpos": nkpos}
            hh_out = jnp.where(ok, h2, hh)
            ncs = jax.tree_util.tree_map(
                lambda n, o: jnp.where(ok, n, o), ncs, uc)
            return hh_out, ncs

        h_out, new_caches = jax.lax.scan(body, h, ((units, vmask), mcache))
        return h_out, new_caches

    pipe_cfg = PipelineConfig(num_stages=num_stages, num_microbatches=M,
                              axis=axis, compress=compress)
    fn = make_pipeline_decoder(mesh, pipe_cfg, decode_unit_fn=decode_unit_fn,
                               embed_fn=embed_fn, head_fn=head_fn,
                               steps=steps)
    return fn, (stacked, valid), caches0, head


def wire_bytes_per_relay(cfg: ModelConfig, mb: int, seq: int,
                         compress: bool) -> int:
    """Bytes one stage relays per microbatch (the paper's 'data' payload)."""
    shape = (mb * seq, cfg.d_model)
    if not compress:
        return mb * seq * cfg.d_model * 2          # bf16
    raw, wire = kops.quant_bytes(shape, jnp.bfloat16)
    return wire


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    if jax.device_count() < args.stages:
        raise SystemExit(f"need >= {args.stages} devices "
                         f"(run under XLA_FLAGS=--xla_force_host_platform_"
                         f"device_count={args.stages})")
    mesh = make_mesh_compat((args.stages,), ("stage",))
    params = T.init_lm(cfg, jax.random.PRNGKey(0))
    lm = build_pipeline_lm(cfg, params, mesh, args.stages, args.microbatches,
                           compress=args.compress)
    B = args.requests
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, args.seq), 0,
                                cfg.vocab)
    kw = {}
    if cfg.num_prefix_embeds and not cfg.encoder_layers:
        kw["prefix_embeds"] = jnp.zeros((B, cfg.num_prefix_embeds, cfg.d_model))
    if cfg.encoder_layers:
        kw["encoder_embeds"] = jnp.zeros((B, cfg.num_prefix_embeds,
                                          cfg.d_model))
    with mesh:
        run = jax.jit(lambda t: lm(t, **kw))
        logits = run(tokens)
        logits.block_until_ready()
        t0 = time.perf_counter()
        logits = run(tokens)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
    mb = B // args.microbatches
    wire = wire_bytes_per_relay(cfg, mb, args.seq, args.compress)
    print(f"arch={args.arch} stages={args.stages} M={args.microbatches} "
          f"compress={args.compress}")
    print(f"logits {logits.shape}; wall {dt*1e3:.1f} ms; "
          f"relay payload/microbatch {wire/1e6:.3f} MB")


if __name__ == "__main__":
    main()
