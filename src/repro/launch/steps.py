"""The three canonical steps each input-shape kind lowers.

Signatures match ``launch.specs.input_specs`` keys exactly; all three are
pure functions of pytrees so ``jax.jit(...).lower(**specs)`` works with
ShapeDtypeStructs.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import OptConfig, apply_updates


def make_train_step(cfg: ModelConfig, opt: OptConfig | None = None,
                    unroll: bool = False) -> Callable:
    opt = opt or OptConfig()

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = T.loss_fn(p, cfg, batch, unroll=unroll)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, stats = apply_updates(params, grads, opt_state, opt)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch["tokens"],
                         prefix_embeds=batch.get("prefix_embeds"),
                         encoder_embeds=batch.get("encoder_embeds"),
                         unroll=unroll)

    return prefill_step


def make_serve_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    """One decode token with a KV/SSM cache of seq_len (the serve_step)."""
    def serve_step(params, token, pos, caches):
        return T.decode_step(params, cfg, token, pos, caches, unroll=unroll)

    return serve_step


def step_for(cfg: ModelConfig, kind: str, unroll: bool = False) -> Callable:
    if kind == "train":
        return make_train_step(cfg, unroll=unroll)
    if kind == "prefill":
        return make_prefill_step(cfg, unroll=unroll)
    return make_serve_step(cfg, unroll=unroll)
