"""Training launcher: pjit train loop on whatever mesh is available.

On the production mesh this is the baseline data x tensor layout from
``repro.sharding``; on this CPU container it runs reduced (smoke) configs on
the host mesh — the same code path either way.

    python -m repro.launch.train --arch phi3-mini-3.8b --steps 100 \
        --batch 8 --seq 128 [--smoke] [--ckpt-dir ckpts]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import sharding as sh
from repro.configs.registry import ARCHS, get_config, get_smoke
from repro.data.pipeline import make_lm_iter
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state


def run(arch: str, steps: int, batch: int, seq: int, smoke: bool = True,
        ckpt_dir: str | None = None, ckpt_every: int = 100,
        log_every: int = 10, lr: float = 1e-3, seed: int = 0,
        callback=None):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    mesh = make_host_mesh()
    opt = OptConfig(lr=lr, warmup_steps=max(2, steps // 20), total_steps=steps)
    key = jax.random.PRNGKey(seed)

    params = T.init_lm(cfg, key)
    start = 0
    if ckpt_dir and (latest := ckpt.latest_step(ckpt_dir)) is not None:
        like = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        params = ckpt.restore(ckpt_dir, latest, like)
        start = latest
        print(f"resumed from step {latest}")
    opt_state = init_opt_state(params)

    p_sh = sh.param_shardings(params, mesh)
    params = jax.device_put(params, p_sh)
    step_fn = jax.jit(make_train_step(cfg, opt))

    it = make_lm_iter(cfg, batch, seq, seed=seed)
    history = []
    t0 = time.perf_counter()
    with mesh:
        for step in range(start, start + steps):
            batch_np = next(it)
            metrics = None
            params, opt_state, metrics = step_fn(params, opt_state, batch_np)
            if step % log_every == 0 or step == start + steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, wall_s=time.perf_counter() - t0)
                history.append(m)
                print(f"step {step:5d} loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e} "
                      f"({m['wall_s']:.1f}s)", flush=True)
                if callback:
                    callback(m)
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1, params)
    if ckpt_dir:
        ckpt.save(ckpt_dir, start + steps, params)
    return params, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs a real cluster)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    run(args.arch, args.steps, args.batch, args.seq, smoke=not args.full,
        ckpt_dir=args.ckpt_dir, lr=args.lr)


if __name__ == "__main__":
    main()
