"""Logical-axis sharding rules (maxtext-style) for every model family.

The baseline distribution (used for the 40-pair dry-run) is classic 2D/3D
data x tensor parallelism:

* batch            -> ("pod", "data")     (pod axis only on the 512-chip mesh)
* attention heads / MLP hidden / experts / vocab -> "model"
* everything small (norms, routers, scalars)     -> replicated

Rules are *path-based*: the leaf's key names decide its PartitionSpec, with
any leading stacked-unit dims left unsharded.  This gives one rule table for
dense / MoE / SSM / hybrid / enc-dec params alike.

The DEFER pipeline path (core/pipeline.py) uses a different scheme — stage
axis over "model" — built in launch/serve.py.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def axis_size(name: str) -> int:
    """Size of a named mesh axis from inside shard_map, across JAX
    versions: ``jax.lax.axis_size`` is missing on 0.4.x, where
    ``psum(1, axis)`` constant-folds to the same static int."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


# leaf name -> (which matrix dim gets "model")
_SHARD_LAST = {"wq", "wk", "wv", "up", "gate", "in_proj"}   # d_in x d_out: out
_SHARD_FIRST = {"wo", "down", "out_proj"}                   # d_in x d_out: in
_REPLICATE = {"scale", "bias", "b", "router", "conv_w", "conv_b",
              "A_log", "D", "dt_bias"}


def _leaf_spec(path: tuple, leaf, model_axis: str, model_size: int) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    parents = set(keys[:-1])
    ndim = len(leaf.shape)

    def first_fitting(*candidates: tuple) -> P:
        """First candidate tail whose sharded dims divide evenly."""
        for tail in candidates:
            lead = ndim - len(tail)
            dims = leaf.shape[lead:]
            if all(ax is None or d % model_size == 0
                   for ax, d in zip(tail, dims)):
                return P(*([None] * lead + list(tail)))
        return P()

    if name == "table":                      # embedding [V, d]
        return first_fitting((model_axis, None), (None, model_axis))
    if name == "w" and "unembed" in parents:
        return first_fitting((None, model_axis), (model_axis, None))
    if "moe" in parents and name in ("up", "gate", "down"):
        # experts [.., E, d, f] -> expert-sharded; fall back to hidden dim
        return first_fitting((model_axis, None, None),
                             (None, None, model_axis))
    if name in _REPLICATE or ndim <= 1:
        return P()
    if name in _SHARD_LAST:
        return first_fitting((None, model_axis), (model_axis, None))
    if name in _SHARD_FIRST:
        return first_fitting((model_axis, None), (None, model_axis))
    if name == "w":                           # generic linear
        return first_fitting((None, model_axis), (model_axis, None))
    return P()


def param_pspecs(params: Any, model_axis: str = "model",
                 model_size: int = 16,
                 fsdp_axes: tuple[str, ...] | None = None,
                 fsdp_sizes: tuple[int, ...] = ()) -> Any:
    """Pytree of PartitionSpec matching ``params`` (arrays or SDStructs).

    ``model_size`` is the tensor axis length; dims that don't divide fall
    back to the other matrix dim (mamba2's 50280 vocab, seamless' 256206)
    or to replication.

    ``fsdp_axes``: additionally shard the largest still-unsharded dim of
    every matrix over the data axes (ZeRO-3 / FSDP style) — required for
    dbrx-132b / llama4-400b whose params + Adam state exceed per-device HBM
    under tensor sharding alone.  GSPMD turns this into either weight
    all-gathers or partial-sum compute, whichever is cheaper.
    """
    specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, model_axis, model_size),
        params)
    if not fsdp_axes:
        return specs
    fsdp_n = int(np.prod(fsdp_sizes))

    def add_fsdp(leaf, spec: P) -> P:
        if len(leaf.shape) < 2:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # biggest unsharded dim divisible by the fsdp factor
        cands = [(d, i) for i, (d, ax) in enumerate(zip(leaf.shape, entries))
                 if ax is None and d % fsdp_n == 0 and d >= fsdp_n]
        if not cands:
            return spec
        _, i = max(cands)
        entries[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map(add_fsdp, params, specs)


def param_shardings(params: Any, mesh: Mesh, model_axis: str = "model") -> Any:
    specs = param_pspecs(params, model_axis,
                         model_size=mesh.shape[model_axis])
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), specs)


def batch_pspec(mesh: Mesh) -> P:
    """Batch sharded over every non-model axis present in the mesh."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return P(axes)


def batch_pspecs(batch: Any, mesh: Mesh) -> Any:
    axes = tuple(a for a in mesh.axis_names if a != "model")

    def per_leaf(leaf):
        return P(axes, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(per_leaf, batch)


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), batch_pspecs(batch, mesh))


def opt_state_pspecs(params: Any, model_axis: str = "model") -> Any:
    """Adam moments shard exactly like their parameters."""
    p = param_pspecs(params, model_axis)
    return {"mu": p, "nu": p, "step": P()}


# -- decode caches ----------------------------------------------------------------

def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def cache_pspecs(caches: Any, mesh: Mesh, model_axis: str = "model") -> Any:
    """Sharding for KV / SSM decode caches.

    Batch shards over the data axes when divisible; the cache *sequence* dim
    shards over "model" (or over data+model when batch is unsharded, the
    long_500k B=1 case) — this is what keeps a 524k-token cache inside HBM.
    Head/state dims shard over "model" where the sequence dim doesn't.
    """
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)

    def spec(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        nd = len(leaf.shape)

        def tail(tail_spec: tuple) -> P:
            lead = nd - len(tail_spec)
            return P(*([None] * lead + list(tail_spec)))

        if name in ("k", "v", "kpos", "kscale", "vscale"):
            b_dim = nd - (4 if name in ("k", "v") else
                          3 if name in ("kscale", "vscale") else 2)
            B, C = leaf.shape[b_dim], leaf.shape[b_dim + 1]
            if B > 1 and B % _axes_size(mesh, data_axes) == 0:
                b_ax, seq_ax = data_axes, (model_axis,)
            else:
                b_ax, seq_ax = None, data_axes + (model_axis,)
            if C % _axes_size(mesh, seq_ax) != 0:
                seq_ax = (model_axis,) if C % mesh.shape[model_axis] == 0 else None
            rest = ((None, None) if name in ("k", "v")
                    else (None,) if name in ("kscale", "vscale") else ())
            return tail((b_ax, seq_ax) + rest)
        if name == "conv":
            ch = leaf.shape[-1]
            m = model_axis if ch % mesh.shape[model_axis] == 0 else None
            return tail((_batch_or_none(leaf.shape[nd - 3], mesh, data_axes),
                         None, m))
        if name == "ssd":
            H = leaf.shape[-3]
            m = model_axis if H % mesh.shape[model_axis] == 0 else None
            return tail((_batch_or_none(leaf.shape[nd - 4], mesh, data_axes),
                         m, None, None))
        if name == "enc_out":
            return tail((_batch_or_none(leaf.shape[0], mesh, data_axes),
                         None, None))
        return P()

    return jax.tree_util.tree_map_with_path(spec, caches)


def _batch_or_none(B: int, mesh: Mesh, data_axes: tuple[str, ...]):
    return data_axes if (B > 1 and B % _axes_size(mesh, data_axes) == 0) else None


def input_batch_axes(B: int, mesh: Mesh, model_axis: str = "model"):
    """Largest prefix of the data axes that divides the global batch."""
    axes = tuple(a for a in mesh.axis_names if a != model_axis)
    while axes and B % _axes_size(mesh, axes) != 0:
        axes = axes[1:]
    return axes
