"""Hardware constants and energy accounting.

Two hardware profiles:

* EDGE  — the paper's setting: edge-class CPU nodes on emulated Ethernet
          (CORE).  Energy model is the paper's: serialization time x TDP
          plus 10 pJ/bit network energy.
* TPU_V5E — the adaptation target used for the roofline analysis
          (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI; per the
          assignment's constants).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float              # FLOP/s (per node / chip)
    hbm_bw: float                  # bytes/s
    link_bw: float                 # bytes/s per link
    tdp_w: float
    energy_per_bit_j: float
    # baseline draw of a powered-on node doing nothing (W).  The paper's
    # per-node energy is measured on nodes that exist whether or not they
    # are busy — an IDLE replica of an over-provisioned stage still burns
    # this.  Default 0 keeps every pre-replica energy figure unchanged;
    # replica-aware accounting (emulate(replicas=...) / EngineReport
    # idle_energy_j) prices it when a profile sets it.
    idle_w: float = 0.0


def idle_energy_j(idle_s: float, hw: HardwareProfile) -> float:
    """Baseline burn of a powered-on but idle node over ``idle_s``."""
    return max(0.0, idle_s) * hw.idle_w


EDGE = HardwareProfile(
    name="edge-cpu",
    peak_flops=20e9,               # edge CPU w/ SIMD (Raspberry-Pi-4-class x4)
    hbm_bw=8e9,
    link_bw=12.5e6,                # 100 Mbit Ethernet
    tdp_w=15.0,
    energy_per_bit_j=10e-12,       # paper: 10 pJ/bit Ethernet
)

TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    peak_flops=197e12,             # bf16
    hbm_bw=819e9,
    link_bw=50e9,                  # per ICI link
    tdp_w=170.0,
    energy_per_bit_j=3e-12,        # ICI-class serdes
)


def compute_energy_j(time_s: float, hw: HardwareProfile) -> float:
    """Paper's methodology: busy time x TDP."""
    return time_s * hw.tdp_w


def network_energy_j(payload_bytes: float, hw: HardwareProfile) -> float:
    """Paper's methodology: payload x energy-per-bit."""
    return payload_bytes * 8.0 * hw.energy_per_bit_j


@dataclasses.dataclass
class LatencySummary:
    """Request-latency distribution over a serving window."""

    count: int
    mean_s: float
    p50_s: float
    p99_s: float

    @staticmethod
    def from_values(values) -> "LatencySummary":
        import numpy as np
        if not len(values):
            return LatencySummary(0, 0.0, 0.0, 0.0)
        a = np.asarray(values, dtype=float)
        return LatencySummary(int(a.size), float(a.mean()),
                              float(np.percentile(a, 50)),
                              float(np.percentile(a, 99)))


@dataclasses.dataclass
class RooflineTerms:
    """The three per-step roofline terms (seconds), per the assignment."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             chips: int, hw: HardwareProfile = TPU_V5E) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo_flops / (chips * hw.peak_flops),
        memory_s=hlo_bytes / (chips * hw.hbm_bw),
        collective_s=collective_bytes / (chips * hw.link_bw),
    )
