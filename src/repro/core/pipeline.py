"""DEFER's compute-node chain as TPU pipeline parallelism.

The paper's architecture — a dispatcher feeding a chain of compute nodes that
each run a contiguous model partition and relay activations FIFO-style — maps
onto a ``shard_map`` over a *stage* mesh axis:

* compute node  ->  mesh slice along the stage axis (one stage = one node)
* TCP relay     ->  ``jax.lax.ppermute`` (circular, stage i -> i+1)
* FIFO stream   ->  microbatch scan over ``num_microbatches + S - 1`` ticks
* ZFP wire codec -> optional fixed-rate int8 block quantization of the
  relayed activation (see ``repro.kernels.block_quant``); both the int8
  payload and the f32 scale sidecar ride the same ppermute.

Semantics: tick t has stage s processing microbatch t - s (valid when
0 <= t - s < M).  Bubble ticks compute on garbage and are masked at output
collection, the standard GPipe inference schedule.  Steady-state throughput
is bounded by the slowest stage + its relay — exactly the paper's
``1 / max_i service_i`` law, with ICI taking the role of Ethernet.

The stage body is caller-supplied (``unit_fn``), so the same pipeline drives
every assigned architecture: dense/MoE/SSM units all relay ``[mb, seq, d]``
activations; hybrid relays carry the shared-attention activation the same
way (state is recomputed per stage's own layers).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    num_microbatches: int
    axis: str = "stage"            # mesh axis the chain lives on
    compress: bool = False         # int8 block-quant the relayed activation
    quant_impl: str = "jnp"        # "jnp" (GSPMD-friendly) | "pallas"
    unroll_ticks: bool = False     # dry-run cost accounting (see dryrun.py)


# -- wire codec (the ZFP adaptation applied to the relay) -----------------------

def _wire_encode(y: jax.Array, impl: str):
    """y [mb, seq, d] -> (q int8, scales f32, shape meta is static)."""
    mb, s, d = y.shape
    flat = y.reshape(mb * s, d)
    R, C = flat.shape
    padr, padc = (-R) % kref.TILE_R, (-C) % kref.TILE_C
    if padr or padc:
        flat = jnp.pad(flat, ((0, padr), (0, padc)))
    if impl == "pallas":
        from repro.kernels import ops as kops
        q, sc, _ = kops.quantize_blocks(flat)
    else:
        q, sc = kref.quantize_blocks_ref(flat)
    return q, sc


def _wire_decode(q: jax.Array, sc: jax.Array, shape, dtype, impl: str):
    mb, s, d = shape
    if impl == "pallas":
        from repro.kernels import ops as kops
        flat = kops.dequantize_blocks(q, sc, (q.shape, q.shape[0], q.shape[1]),
                                      dtype=dtype)
    else:
        flat = kref.dequantize_blocks_ref(q, sc, dtype=dtype)
    return flat[: mb * s, :d].reshape(mb, s, d)


# -- the chain -------------------------------------------------------------------

def pipeline_apply(stage_params: Any, x_mb: Any, extra: Any = None, *,
                   unit_fn: Callable[..., Any],
                   cfg: PipelineConfig) -> Any:
    """Per-device body (run under shard_map over ``cfg.axis``).

    stage_params: local stage slice (leading dim 1, squeezed here).
    x_mb: microbatch-stream PYTREE, every leaf [M, ...] (replicated; only
    stage 0 reads it — XLA DCEs the rest after sharding propagation).  A
    plain array is the common single-activation case; enc-dec chains relay
    {"h": ..., "enc": ...} so the encoder output rides the wire as a
    pass-through activation, exactly DEFER's crossing-edge payload.
    extra: replicated pytree every stage needs whole (zamba2's weight-tied
    shared-attention block); passed as ``unit_fn(local, x, extra)``.
    Returns the same pytree with leaves [M, ...], valid on the LAST stage.
    """
    S, M = cfg.num_stages, cfg.num_microbatches
    axis = cfg.axis
    sid = jax.lax.axis_index(axis)
    tmap = jax.tree_util.tree_map
    local = tmap(lambda a: a[0], stage_params)
    perm = [(i, (i + 1) % S) for i in range(S)]

    def relay(y):
        if not cfg.compress:
            return tmap(lambda a: jax.lax.ppermute(a, axis, perm), y)

        def one(a):
            q, sc = _wire_encode(a, cfg.quant_impl)
            q = jax.lax.ppermute(q, axis, perm)
            sc = jax.lax.ppermute(sc, axis, perm)
            return _wire_decode(q, sc, a.shape, a.dtype, cfg.quant_impl)

        return tmap(one, y)

    def tick(carry, t):
        state, outbuf = carry
        minj = jnp.clip(t, 0, M - 1)
        inject = tmap(
            lambda a: jax.lax.dynamic_index_in_dim(a, minj, 0, keepdims=False),
            x_mb)
        x_in = tmap(lambda i, s: jnp.where(sid == 0, i, s), inject, state)
        y = unit_fn(local, x_in) if extra is None \
            else unit_fn(local, x_in, extra)
        # collect: last stage finished microbatch t - (S-1)
        oidx = t - (S - 1)
        take = (sid == S - 1) & (oidx >= 0)
        safe = jnp.clip(oidx, 0, M - 1)

        def collect(buf, yl):
            cur = jax.lax.dynamic_index_in_dim(buf, safe, 0, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                buf, jnp.where(take, yl, cur), safe, 0)

        outbuf = tmap(collect, outbuf, y)
        return (relay(y), outbuf), None

    state0 = tmap(lambda a: jnp.zeros(a.shape[1:], a.dtype), x_mb)
    out0 = tmap(jnp.zeros_like, x_mb)
    total = M + S - 1
    (_, outbuf), _ = jax.lax.scan(tick, (state0, out0), jnp.arange(total),
                                  unroll=total if cfg.unroll_ticks else 1)
    return outbuf


def make_pipeline(mesh: Mesh, cfg: PipelineConfig,
                  unit_fn: Callable[..., jax.Array],
                  data_axes: tuple[str, ...] = (),
                  with_extra: bool = False):
    """Build the sharded pipeline callable.

    Returns ``fn(stage_params, x_mb) -> y_mb`` where

    * ``stage_params``: pytree with leading dim ``num_stages`` (sharded over
      ``cfg.axis``),
    * ``x_mb [M, mb, seq, d]``: microbatch stream, batch sharded over
      ``data_axes`` (the paper's "independent chains" scale-out),
    * ``y_mb [M, mb, seq, d]``: outputs in FIFO order.

    The per-stage output buffer stays sharded over the stage axis
    ([S, M, ...]); the last-stage slice is taken outside shard_map so XLA
    moves only the finished microbatches.
    """
    from jax.experimental.shard_map import shard_map

    pspec_w = P(cfg.axis)
    pspec_x = P(None, *data_axes)
    pspec_y = P(cfg.axis, None, *data_axes)

    tmap = jax.tree_util.tree_map

    if with_extra:
        def per_device(w, x, extra):
            out = pipeline_apply(w, x, extra, unit_fn=unit_fn, cfg=cfg)
            return tmap(lambda a: a[None], out)   # [1, M, ...] local

        sharded = shard_map(per_device, mesh=mesh,
                            in_specs=(pspec_w, pspec_x, P()),
                            out_specs=pspec_y, check_rep=False)

        def fn(stage_params, x_mb, extra):
            return tmap(lambda a: a[-1], sharded(stage_params, x_mb, extra))
    else:
        def per_device(w, x):
            out = pipeline_apply(w, x, unit_fn=unit_fn, cfg=cfg)
            return tmap(lambda a: a[None], out)   # [1, M, ...] local

        sharded = shard_map(per_device, mesh=mesh,
                            in_specs=(pspec_w, pspec_x),
                            out_specs=pspec_y, check_rep=False)

        def fn(stage_params, x_mb):
            # last stage's outputs
            return tmap(lambda a: a[-1], sharded(stage_params, x_mb))

    return fn


# -- stage-stacking helpers ---------------------------------------------------------

def stack_stages(unit_params: Any, n_units: int, num_stages: int):
    """[n_units, ...] unit stack -> ([S, u_per_stage, ...], valid [S, u]).

    DEFER pads the chain when layers don't divide evenly; here padded unit
    slots carry zero params and a False validity mask — ``stage_unit_fn``
    turns them into identity relays (masked residual), preserving exact
    model semantics for any (L, S).
    """
    u = -(-n_units // num_stages)              # ceil
    pad = u * num_stages - n_units

    def pad_stack(a):
        if pad:
            z = jnp.zeros((pad,) + a.shape[1:], a.dtype)
            a = jnp.concatenate([a, z], axis=0)
        return a.reshape((num_stages, u) + a.shape[1:])

    stacked = jax.tree_util.tree_map(pad_stack, unit_params)
    valid = (jnp.arange(num_stages * u) < n_units).reshape(num_stages, u)
    return stacked, valid


def make_stage_unit_fn(apply_unit: Callable[[Any, jax.Array], jax.Array]):
    """Wrap a single-unit apply into a masked multi-unit stage body.

    ``apply_unit(unit_params, x) -> y``; the stage scans its local units,
    replacing padded units with identity.
    """
    def stage_fn(stage_local, x):
        units, valid = stage_local             # units: [u, ...], valid: [u]

        def body(h, inp):
            up, ok = inp
            y = apply_unit(up, h)
            return jnp.where(ok, y, h), None

        out, _ = jax.lax.scan(body, x, (units, valid))
        return out

    return stage_fn
