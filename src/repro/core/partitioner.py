"""DEFER model partitioning.

The paper cuts the layer DAG into ``k`` contiguous sub-networks, choosing
layers "based on what would split the model up into a similar number of layers
for each partition".  We implement that strategy (``equal_layers``) plus two
cost-aware ones the dispatcher can plan with:

* ``balanced_flops`` — classic linear-partition DP minimizing the maximum
  per-partition FLOPs (the pipeline bottleneck term),
* ``balanced_latency`` — same DP but on stage *service time* =
  compute_time + outbound transfer time under a :class:`LinkModel`, which is
  the quantity that actually bounds DEFER's steady-state throughput.

All strategies return a :class:`Partition` — the cut indices plus per-stage
cost summaries that the emulator / pipeline runtime consume.

Online recalibration (the serving-time feedback loop) plans on *measured*
costs instead of the static models: :class:`CalibratedCosts` carries
per-layer compute seconds plus per-byte codec/wire rates learned from real
``BatchTrace`` telemetry, :func:`calibrated_partition` re-runs the DP on
them (optionally warm-started in a window around the current cuts, which
also bounds how many layers a live migration has to ship), and
:func:`bounds_bottleneck` is the cost-delta API — it prices *any* candidate
cuts under the same calibrated costs so a controller can compare "stay"
vs "move" before committing a live repartition.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import numpy as np

from repro.core.graph import LayerGraph

Strategy = Literal["equal_layers", "balanced_flops", "balanced_latency"]


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-hop network model (the CORE-emulated Ethernet in the paper)."""

    bandwidth_bytes_per_s: float = 12.5e6     # 100 Mbit Ethernet
    latency_s: float = 2e-4
    energy_per_bit_j: float = 10e-12          # paper: 10 pJ/bit (Ethernet)
    compression_ratio: float = 1.0            # payload multiplier (<1 = compressed)

    def transfer_time(self, payload_bytes: float) -> float:
        wire = payload_bytes * self.compression_ratio
        return self.latency_s + wire / self.bandwidth_bytes_per_s

    def transfer_energy(self, payload_bytes: float) -> float:
        wire = payload_bytes * self.compression_ratio
        return wire * 8.0 * self.energy_per_bit_j


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """Per-node compute model (an edge CPU in the paper, a TPU chip here)."""

    flops_per_s: float = 20e9                 # edge-class CPU w/ SIMD
    tdp_w: float = 15.0                       # paper's energy = time * TDP

    def compute_time(self, flops: float) -> float:
        return flops / self.flops_per_s


@dataclasses.dataclass
class StageCost:
    start: int                  # node index range [start, stop)
    stop: int
    flops: float
    param_bytes: int
    out_bytes: int              # activation bytes crossing the outbound cut
    compute_time_s: float = 0.0
    transfer_time_s: float = 0.0
    replicas: int = 1           # identical nodes serving this stage

    @property
    def service_time_s(self) -> float:
        # A DEFER node can't accept sample t+1 until it computed AND relayed
        # sample t (single socket thread pair) -> service = compute + transfer.
        # This is the PER-REQUEST time: replicating the stage does not make
        # any single request faster.
        return self.compute_time_s + self.transfer_time_s

    @property
    def throughput_service_s(self) -> float:
        """The stage's effective contribution to the pipeline bottleneck:
        ``replicas`` identical nodes each take a 1/replicas share of the
        request stream, so compute and codec/transfer amortize — but only
        for throughput, never for a request's own latency."""
        return self.service_time_s / self.replicas


@dataclasses.dataclass
class Partition:
    graph_name: str
    cuts: tuple[int, ...]       # k-1 cut indices: cut after node i
    stages: list[StageCost]

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    @property
    def bottleneck_s(self) -> float:
        """Max per-request stage service time (replica-blind: the paper's
        single-node-per-partition law)."""
        return max(s.service_time_s for s in self.stages)

    @property
    def throughput_bottleneck_s(self) -> float:
        """Max replica-amortized stage service time — what actually bounds
        steady-state throughput on a replicated topology."""
        return max(s.throughput_service_s for s in self.stages)

    @property
    def replicas(self) -> tuple[int, ...]:
        return tuple(s.replicas for s in self.stages)

    def ranges(self) -> list[tuple[int, int]]:
        return [(s.start, s.stop) for s in self.stages]


def _computes(compute, num_stages: int) -> list[ComputeModel]:
    """Normalize to one ComputeModel per stage (heterogeneous nodes — the
    paper's stated future work: 'heterogeneous model partitions can be more
    effectively distributed for higher inference throughput')."""
    if isinstance(compute, ComputeModel):
        return [compute] * num_stages
    compute = list(compute)
    assert len(compute) == num_stages, \
        f"{len(compute)} compute models for {num_stages} stages"
    return compute


def _stage_costs(graph: LayerGraph, bounds: Sequence[int],
                 link: LinkModel, computes: list[ComputeModel],
                 replicas: Sequence[int] | None = None) -> list[StageCost]:
    stages: list[StageCost] = []
    for si in range(len(bounds) - 1):
        lo, hi = bounds[si], bounds[si + 1]
        nodes = graph.nodes[lo:hi]
        flops = sum(n.flops for n in nodes)
        pbytes = sum(n.param_bytes for n in nodes)
        obytes = graph.cut_cost(hi - 1) if hi < len(graph.nodes) else nodes[-1].out_bytes
        st = StageCost(lo, hi, flops, pbytes, obytes,
                       replicas=replicas[si] if replicas else 1)
        st.compute_time_s = computes[si].compute_time(flops)
        st.transfer_time_s = link.transfer_time(obytes)
        stages.append(st)
    return stages


def partition(graph: LayerGraph, num_stages: int,
              strategy: Strategy = "balanced_latency",
              link: LinkModel | None = None,
              compute: "ComputeModel | Sequence[ComputeModel] | None" = None,
              cuts: Sequence[int] | None = None,
              replicas: Sequence[int] | None = None) -> Partition:
    """Cut ``graph`` into ``num_stages`` contiguous partitions.

    ``compute`` may be a sequence of per-node models (heterogeneous edge
    cluster): the balanced strategies then assign more work to faster
    nodes (stage i runs on node i — the chain order is fixed by DEFER's
    topology).

    ``cuts`` overrides the strategy with explicit interior cut indices
    (cut after layer ``c``): how a dispatcher rebuilds its Partition after
    a live repartition, and how benchmarks pin a deliberately bad plan.

    ``replicas`` records per-stage replica counts: stage costs price the
    throughput bottleneck as (compute + transfer) / replicas — replication
    amortizes a stage's service RATE, never a single request's latency.
    The strategies themselves still place cuts per-request; the serving
    controller owns the replica dimension.
    """
    link = link or LinkModel()
    computes = _computes(compute or ComputeModel(), num_stages)
    hetero = len({c.flops_per_s for c in computes}) > 1
    n = len(graph.nodes)
    if not 1 <= num_stages <= n:
        raise ValueError(f"num_stages={num_stages} out of range for {n} layers")

    if cuts is not None:
        bounds = [0, *sorted(cuts), n]
        if len(bounds) != num_stages + 1 or len(set(bounds)) != len(bounds) \
                or any(not 0 < c < n for c in cuts):
            raise ValueError(f"cuts {tuple(cuts)} do not split {n} layers "
                             f"into {num_stages} non-empty stages")
    elif strategy == "equal_layers":
        # The paper's strategy: similar number of layers per partition.
        bounds = [round(i * n / num_stages) for i in range(num_stages + 1)]
        bounds = sorted(set(bounds))
        while len(bounds) < num_stages + 1:  # degenerate tiny graphs
            for i in range(len(bounds) - 1):
                if bounds[i + 1] - bounds[i] > 1:
                    bounds.insert(i + 1, bounds[i] + 1)
                    break
    elif strategy in ("balanced_flops", "balanced_latency"):
        if strategy == "balanced_flops" and not hetero:
            w = np.array([node.flops for node in graph.nodes], dtype=np.float64)
            edge = np.zeros(n, dtype=np.float64)
            rates = np.ones(num_stages)
        else:
            w = np.array([node.flops for node in graph.nodes],
                         dtype=np.float64)
            rates = np.array([c.flops_per_s for c in computes])
            if strategy == "balanced_latency":
                edge = np.array(
                    [link.transfer_time(graph.cut_cost(i))
                     for i in range(n - 1)] + [0.0], dtype=np.float64)
            else:
                edge = np.zeros(n, dtype=np.float64)
        bounds = _linear_partition_dp(w, edge, num_stages, rates)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    if replicas is not None and len(replicas) != num_stages:
        raise ValueError(f"{len(replicas)} replica counts for "
                         f"{num_stages} stages")
    stages = _stage_costs(graph, bounds, link, computes, replicas)
    return Partition(graph.name, tuple(bounds[1:-1]), stages)


def _linear_partition_dp(w: np.ndarray, edge: np.ndarray, k: int,
                         rates: np.ndarray | None = None,
                         stage_cost=None,
                         prev_bounds: Sequence[int] | None = None,
                         window: int | None = None) -> list[int]:
    """Minimize the max of (sum of w in stage / rate_j + edge at the cut).

    O(n^2 k) DP — n is layer count (<= a few hundred), fine.
    ``edge[i]`` is the cost charged to a stage whose last node is i
    (the outbound transfer of the cut after node i; edge[n-1] = 0).
    ``rates[j]`` divides stage j's work (heterogeneous nodes); None = 1.

    ``stage_cost(lo, hi, j)`` overrides the additive cost above with an
    arbitrary per-stage pricing (the calibrated staged-runtime max-of-stages
    model); the DP itself only needs costs to be monotone in [lo, hi).

    ``prev_bounds``/``window`` warm-start the search: every interior bound j
    is constrained to ``prev_bounds[j] ± window``.  Besides shrinking the
    search, this caps how many layers a live repartition can shift at once
    (each shifted layer is weights on the wire).  The full DP is the
    ``window=None`` special case.
    """
    n = len(w)
    prefix = np.concatenate([[0.0], np.cumsum(w)])
    if rates is None:
        rates = np.ones(k)

    if stage_cost is None:
        def stage_cost(lo: int, hi: int, j: int) -> float:  # nodes [lo, hi)
            return (prefix[hi] - prefix[lo]) / rates[j] + edge[hi - 1]

    # hi_ok[j][i]: may the boundary after stage j land at layer i?
    hi_ok = np.full((k + 1, n + 1), True)
    if prev_bounds is not None and window is not None:
        for j in range(1, k):
            hi_ok[j] = False
            lo = max(1, prev_bounds[j] - window)
            hi = min(n - 1, prev_bounds[j] + window)
            hi_ok[j][lo:hi + 1] = True

    INF = float("inf")
    # dp[j][i] = minimal bottleneck splitting first i nodes into j stages
    dp = np.full((k + 1, n + 1), INF)
    cut = np.zeros((k + 1, n + 1), dtype=np.int64)
    dp[0][0] = 0.0
    for j in range(1, k + 1):
        for i in range(j, n - (k - j) + 1):
            if not hi_ok[j][i]:
                continue
            best, arg = INF, j - 1
            for m in range(j - 1, i):
                if dp[j - 1][m] == INF:
                    continue
                c = max(dp[j - 1][m], stage_cost(m, i, j - 1))
                if c < best:
                    best, arg = c, m
            dp[j][i] = best
            cut[j][i] = arg
    if dp[k][n] == INF:        # window too tight to be feasible: full search
        assert window is not None
        return _linear_partition_dp(w, edge, k, rates, stage_cost)
    bounds = [n]
    i = n
    for j in range(k, 0, -1):
        i = int(cut[j][i])
        bounds.append(i)
    return bounds[::-1]


# -- online cost calibration (the serving-time feedback loop) ----------------

@dataclasses.dataclass
class CalibratedCosts:
    """Measured serving costs, in seconds, for pricing candidate cuts.

    ``layer_s[i]`` is the calibrated compute time of layer i for one
    request (EWMA of real per-node apply time, spread over the node's
    layer range by static FLOPs share).  The codec/wire rates convert a
    cut's crossing bytes (``cut_bytes[i]``, static graph property) into
    per-request encode time at the sender and decode time at the receiver
    — both measured amortized over real batches, so batching efficiency is
    priced in.  ``head_in_bytes`` is what stage 0 decodes (the admitted
    input); ``tail_out_bytes`` is what the last stage encodes for the
    collector.
    """

    layer_s: np.ndarray                 # [n] per-layer compute seconds
    cut_bytes: np.ndarray               # [n] bytes crossing cut after layer i
    encode_s_per_byte: float = 0.0
    decode_s_per_byte: float = 0.0
    wire_s_per_byte: float = 0.0        # modeled link time (0 = in-process)
    head_in_bytes: float = 0.0
    tail_out_bytes: float = 0.0

    def __post_init__(self):
        # prefix sums make stage_service_s O(1): the DP calls it O(n^2 k)
        # times per re-plan, every control period, possibly on 100+-layer
        # graphs — an O(n) slice-sum inside would steal whole cores from
        # serving
        self._prefix = np.concatenate([[0.0], np.cumsum(self.layer_s)])

    def stage_service_s(self, lo: int, hi: int, staged: bool = True,
                        replicas: int = 1) -> float:
        """Predicted service time of a stage covering layers [lo, hi).

        A staged node overlaps its decode / compute / encode threads, so
        its steady-state service rate is set by the *max* stage time
        (paper: throughput = 1 / max_i service_i); an unstaged node pays
        the sum.  ``replicas`` identical nodes split the request stream,
        so compute and codec amortize by 1/replicas — for the stage's
        service RATE, which is what this function prices; a request's own
        latency through one replica is unchanged by its siblings.
        """
        in_b = self.head_in_bytes if lo == 0 else float(self.cut_bytes[lo - 1])
        out_b = (self.tail_out_bytes if hi == len(self.layer_s)
                 else float(self.cut_bytes[hi - 1]))
        dec = self.decode_s_per_byte * in_b
        cmp = float(self._prefix[hi] - self._prefix[lo])
        enc = (self.encode_s_per_byte + self.wire_s_per_byte) * out_b
        per_req = max(dec, cmp, enc) if staged else dec + cmp + enc
        return per_req / max(1, replicas)


def bounds_bottleneck(costs: CalibratedCosts, bounds: Sequence[int],
                      staged: bool = True,
                      replicas: Sequence[int] | None = None) -> float:
    """Cost-delta API: predicted bottleneck service time of ANY cuts under
    the calibrated costs — price the current plan and a candidate with the
    same ruler before paying for a live migration.  ``replicas`` prices a
    replicated topology (stage i's rate amortized by replicas[i])."""
    return max(costs.stage_service_s(lo, hi, staged,
                                     replicas[j] if replicas else 1)
               for j, (lo, hi) in enumerate(zip(bounds, bounds[1:])))


def calibrated_partition(costs: CalibratedCosts, num_stages: int,
                         staged: bool = True,
                         prev_bounds: Sequence[int] | None = None,
                         window: int | None = None,
                         replicas: Sequence[int] | None = None
                         ) -> tuple[list[int], float]:
    """Re-run the partition DP on calibrated (measured) costs.

    Returns ``(bounds, predicted_bottleneck_s)``.  ``prev_bounds`` +
    ``window`` warm-start the DP around the live cuts (bounding both the
    search and the weight bytes a migration ships); infeasible windows
    fall back to the full search.  ``replicas`` makes the DP place cuts
    for the CURRENT replicated topology: a 2-replica stage can profitably
    hold twice the layers (its service rate halves), which a replica-blind
    plan would miscount as the bottleneck.
    """
    n = len(costs.layer_s)

    def stage_cost(lo: int, hi: int, j: int) -> float:
        return costs.stage_service_s(lo, hi, staged,
                                     replicas[j] if replicas else 1)

    bounds = _linear_partition_dp(
        costs.layer_s, np.zeros(n), num_stages, stage_cost=stage_cost,
        prev_bounds=prev_bounds, window=window)
    return bounds, bounds_bottleneck(costs, bounds, staged, replicas)
