"""Layer-graph IR — the JAX analogue of the Keras layer DAG that DEFER traverses.

DEFER partitions a model by walking its layer DAG and cutting it into
contiguous sub-networks.  We represent any model (CNN or transformer) as a
:class:`LayerGraph` of :class:`LayerNode`s.  Each node carries

* ``fn``        — a pure function ``(params, *inputs) -> output`` (JAX),
* ``param_spec``— pytree of ShapeDtypeStructs for its parameters,
* cost terms    — FLOPs, parameter bytes, and output-activation bytes,

so the partitioner can cost a cut without running anything, exactly like the
paper's dispatcher plans partitions before shipping them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # pytree


def tree_bytes(tree: Any) -> int:
    """Total bytes of every leaf (works for arrays and ShapeDtypeStructs)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += size * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) if l.shape else 1 for l in leaves)


@dataclasses.dataclass
class LayerDecode:
    """Autoregressive view of a stateful layer (attention with a KV cache).

    ``prefill_fn(params, x)`` runs the layer over a full prompt
    ``[B, S, ...]`` and returns ``(y, cache)`` — the cache pytree holds
    everything the layer needs to continue from position ``S`` (e.g.
    K/V buffers of fixed capacity plus a slot-position vector), with a
    leading batch axis so per-session caches (``B=1``) stack into one
    decode batch.  ``step_fn(params, cache, x, pos)`` consumes ONE new
    token per row (``x: [B, 1, ...]``, ``pos: [B] int32`` — rows may sit
    at *different* sequence positions) and returns ``(y, new_cache)``.
    Both must be jit-traceable; cache leaves must keep a fixed shape so a
    stacked decode batch specializes once per batch size, not per step.
    """

    prefill_fn: Callable[..., Any]         # (params, x) -> (y, cache)
    step_fn: Callable[..., Any]            # (params, cache, x, pos) -> (y, new_cache)


@dataclasses.dataclass
class LayerNode:
    """One layer (or fused block) in the model DAG."""

    name: str
    fn: Callable[..., Any]                 # (params, *inputs) -> output
    param_spec: Any                        # pytree of ShapeDtypeStruct
    inputs: Sequence[str]                  # names of producer nodes ('' = graph input)
    out_spec: jax.ShapeDtypeStruct         # activation this node emits
    flops: float                           # fwd FLOPs for one sample batch
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # True iff this layer preserves its middle axes and acts independently
    # along them (token-wise MLP/FFN, norms, elementwise): the runtime may
    # then zero-pad those axes to merge near-miss shapes into one batch
    # bucket.  Layers that mix positions (attention over the padded axis,
    # pooling with edge effects) must set False — a serving segment
    # containing any pad-unsafe layer falls back to exact bucketing.
    pad_safe: bool = True
    # stateful autoregressive view; None for stateless layers, whose ``fn``
    # already works one token at a time (embeddings, norms, token-wise MLP)
    decode: LayerDecode | None = None

    @property
    def param_bytes(self) -> int:
        return tree_bytes(self.param_spec)

    @property
    def out_bytes(self) -> int:
        return tree_bytes(self.out_spec)


class LayerGraph:
    """A topologically-ordered DAG of layers plus init/apply utilities.

    Mirrors the role of the Keras model object in DEFER: it can be traversed,
    cut into contiguous partitions, and each partition materialized as a
    standalone callable (the "new model of just the partitioned layers").
    """

    def __init__(self, name: str, input_spec: jax.ShapeDtypeStruct):
        self.name = name
        self.input_spec = input_spec
        self.nodes: list[LayerNode] = []
        self._by_name: dict[str, LayerNode] = {}

    # -- construction -----------------------------------------------------
    def add(self, node: LayerNode) -> str:
        if node.name in self._by_name:
            raise ValueError(f"duplicate layer name {node.name!r}")
        for inp in node.inputs:
            if inp and inp not in self._by_name:
                raise ValueError(
                    f"layer {node.name!r} consumes unknown producer {inp!r}"
                )
        self.nodes.append(node)
        self._by_name[node.name] = node
        return node.name

    def layer(self, name: str, fn, param_spec, inputs, out_spec, flops,
              pad_safe: bool = True, decode: LayerDecode | None = None,
              **meta):
        return self.add(
            LayerNode(name, fn, param_spec, tuple(inputs), out_spec, flops,
                      meta, pad_safe=pad_safe, decode=decode)
        )

    @property
    def decode_capable(self) -> bool:
        """True iff the graph declares an autoregressive view: at least one
        stateful :class:`LayerDecode` node AND a pure chain shape (every
        node consumes exactly one producer), so any contiguous partition
        has a single boundary activation for token-step frames to carry."""
        return (any(n.decode is not None for n in self.nodes)
                and all(len(n.inputs) == 1 for n in self.nodes))

    def __len__(self) -> int:
        return len(self.nodes)

    def __getitem__(self, name: str) -> LayerNode:
        return self._by_name[name]

    # -- aggregate costs ---------------------------------------------------
    @property
    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    @property
    def total_param_bytes(self) -> int:
        return sum(n.param_bytes for n in self.nodes)

    # -- cut legality -------------------------------------------------------
    def cut_cost(self, i: int) -> int:
        """Bytes crossing a cut placed after node index ``i``.

        A cut is the wire between two DEFER compute nodes: every edge from a
        producer at index <= i to a consumer at index > i crosses it.  The
        transferred payload is the union of crossing producer activations
        (each is sent once, the receiving partition fans it out locally).
        """
        total = 0
        for name in self.crossing_names(i):
            total += (
                tree_bytes(self.input_spec)
                if name == ""
                else self._by_name[name].out_bytes
            )
        return total

    def crossing_names(self, i: int) -> list[str]:
        """Activations crossing a cut placed after node index ``i``.

        Every edge from a producer at index <= i (or the graph input '') to
        a consumer at index > i crosses the cut.  Each crossing activation
        is sent once; activations produced before an intermediate stage and
        consumed after it pass through that stage's wire too (the chain has
        no other path).
        """
        consumed_after = {inp for n in self.nodes[i + 1:] for inp in n.inputs}
        names = [n.name for n in self.nodes[: i + 1] if n.name in consumed_after]
        if "" in consumed_after:
            names.insert(0, "")
        return names

    # -- init / apply --------------------------------------------------------
    def init(self, key: jax.Array, scale: float = 0.02) -> Params:
        """Materialize real parameters for every node (normal init)."""
        params: dict[str, Any] = {}
        for node in self.nodes:
            leaves, treedef = jax.tree_util.tree_flatten(node.param_spec)
            keys = jax.random.split(jax.random.fold_in(key, hash(node.name) % (2**31)),
                                    max(1, len(leaves)))
            mats = []
            for k, leaf in zip(keys, leaves):
                if jnp.issubdtype(leaf.dtype, jnp.floating):
                    mats.append(
                        (jax.random.normal(k, leaf.shape, jnp.float32) * scale
                         ).astype(leaf.dtype)
                    )
                else:
                    mats.append(jnp.zeros(leaf.shape, leaf.dtype))
            params[node.name] = jax.tree_util.tree_unflatten(treedef, mats)
        return params

    def apply(self, params: Params, x: jax.Array,
              nodes: Sequence[LayerNode] | None = None,
              boundary_inputs: Mapping[str, jax.Array] | None = None) -> jax.Array:
        """Run (a slice of) the graph.

        ``boundary_inputs`` supplies activations produced by an earlier
        partition — this is exactly what a DEFER compute node receives on its
        incoming socket.
        """
        nodes = list(self.nodes) if nodes is None else list(nodes)
        acts: dict[str, jax.Array] = {"": x}
        if boundary_inputs:
            acts.update(boundary_inputs)
        out = x
        for node in nodes:
            args = [acts[i] for i in node.inputs]
            out = node.fn(params[node.name], *args)
            acts[node.name] = out
        return out

    # -- partition materialization -------------------------------------------
    def slice_nodes(self, lo: int, hi: int) -> list[LayerNode]:
        """Nodes of partition [lo, hi) in topological order."""
        return self.nodes[lo:hi]

    def boundary_names(self, lo: int, hi: int) -> tuple[list[str], list[str]]:
        """(required_inputs, exported_outputs) for partition [lo, hi).

        required: activations produced before ``lo`` (or the graph input '')
        that nodes in [lo, hi) consume.  exported: activations produced inside
        that nodes at >= hi consume (plus the final node if it is the last).
        """
        inside = {n.name for n in self.nodes[lo:hi]}
        required: list[str] = []
        for n in self.nodes[lo:hi]:
            for inp in n.inputs:
                if inp not in inside and inp not in required:
                    required.append(inp)
        consumed_after = {inp for n in self.nodes[hi:] for inp in n.inputs}
        exported = [n.name for n in self.nodes[lo:hi] if n.name in consumed_after]
        if hi == len(self.nodes) and self.nodes and self.nodes[-1].name not in exported:
            exported.append(self.nodes[-1].name)
        return required, exported
