"""Pipeline + expert parallelism (PP x EP x DP) — §Perf HC2 iteration 3.

Big-MoE serving (llama4-maverick 400B, dbrx 132B) cannot keep a stage's
full expert set on one chip, and GSPMD's global-view dispatch all-reduces
whole capacity buffers (measured 2.25 TB/device for llama4 prefill).  The
production layout is the DEFER chain *per group of chips*:

    mesh = (data, expert, stage)        e.g. (2, 8, 16) = 256 chips

* stage  — the paper's compute-node chain (ppermute relays, microbatches)
* expert — within a stage: attention is head-sharded TP (one psum/layer),
           MoE is GShard expert parallelism (explicit all_to_all of routed
           tokens via ``moe_block_local``)
* data   — replicated chains (DEFER's parallel inference jobs)

Everything is explicit shard_map code — no GSPMD guessing.  Per layer the
exchanged bytes are one [mb,S,d] psum + one token all-gather + two
token-capacity all_to_alls, instead of full-buffer all-reduces.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.pipeline import PipelineConfig, pipeline_apply, stack_stages
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.models.attention import AttnSpec, _chunked_attention, apply_rope

tmap = jax.tree_util.tree_map


def ep_unit_fn(cfg: ModelConfig, expert_axis: str = "expert",
               unroll: bool = False):
    """Stage body: per-device code with head-TP attention + EP MoE."""
    spec = T.moe_spec(cfg)
    scale = 1.0 / np.sqrt(cfg.head_dim)

    def apply_layer(lp, x):
        from repro.sharding import axis_size
        ax = axis_size(expert_axis)
        mb, S, d = x.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (mb, S))
        # -- attention, heads sharded over the expert axis ---------------
        h = L.rmsnorm(lp["attn"]["ln"], x, cfg.norm_eps)
        Hl = cfg.num_heads // ax
        kvl = max(1, cfg.kv_heads // ax)
        q = (h @ lp["attn"]["wq"]["w"]).reshape(mb, S, Hl, cfg.head_dim)
        k = (h @ lp["attn"]["wk"]["w"]).reshape(mb, S, kvl, cfg.head_dim)
        v = (h @ lp["attn"]["wv"]["w"]).reshape(mb, S, kvl, cfg.head_dim)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        s_local = AttnSpec(d, Hl, kvl, cfg.head_dim)       # local-head view
        C = min(s_local.q_chunk, S)
        if S % C:
            C = S
        qs = q.reshape(mb, S // C, C, Hl, cfg.head_dim)
        pos_q = pos.reshape(mb, S // C, C)
        o = _chunked_attention(qs, k, v, pos_q, pos, s_local, scale, C)
        o = o.reshape(mb, S, Hl * cfg.head_dim) @ lp["attn"]["wo"]["w"]
        x = x + jax.lax.psum(o, expert_axis)               # partial over heads
        # -- MoE, tokens split over the expert axis, GShard a2a ----------
        i = jax.lax.axis_index(expert_axis)
        T_tot = mb * S
        T_l = T_tot // ax
        x_flat = x.reshape(T_tot, d)
        x_l = jax.lax.dynamic_slice_in_dim(x_flat, i * T_l, T_l)[None]
        y_l, _ = moe_mod.moe_block_local(lp["moe"], spec, x_l, expert_axis,
                                         cfg.norm_eps)
        y = jax.lax.all_gather(y_l[0], expert_axis, tiled=True)  # [T, d]
        return y.reshape(mb, S, d)

    def stage_fn(local, x):
        units, valid = local

        def body(hh, inp):
            up, ok = inp
            y = apply_layer(up["pos0"], hh)
            return jnp.where(ok, y, hh), None

        u = jax.tree_util.tree_leaves(units)[0].shape[0]
        out, _ = jax.lax.scan(body, x, (units, valid),
                              unroll=u if unroll else 1)
        return out

    return stage_fn


def _ep_weight_specs(units: Any, stage_axis: str, expert_axis: str):
    """Per-leaf specs: [S, u, ...] with head/expert dims over the EP axis."""
    def spec(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        nd = len(leaf.shape)
        if "moe" in keys and name in ("up", "gate", "down"):
            return P(stage_axis, None, expert_axis, *([None] * (nd - 3)))
        if name == "w" and "wo" in keys:
            return P(stage_axis, None, expert_axis, None)
        if name == "w" and any(k in keys for k in ("wq", "wk", "wv")):
            return P(stage_axis, None, None, expert_axis)
        return P(stage_axis, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec, units)


def build_ep_pipeline(cfg: ModelConfig, mesh: Mesh, num_stages: int,
                      num_microbatches: int, compress: bool = False,
                      unroll: bool = False,
                      data_axes: tuple[str, ...] = ("data",),
                      expert_axis: str = "expert",
                      stage_axis: str = "stage"):
    """Returns fn(units_stacked_valid, x_mb) -> y_mb for MoE decoder archs.

    ``units_stacked_valid`` = stack_stages(params["units"], ...); weights
    must be sliced per EP shard by the in_specs below (sharded arrays in,
    local shards inside).
    """
    from jax.experimental.shard_map import shard_map

    pipe_cfg = PipelineConfig(num_stages=num_stages,
                              num_microbatches=num_microbatches,
                              axis=stage_axis, compress=compress,
                              unroll_ticks=unroll)
    unit_fn = ep_unit_fn(cfg, expert_axis, unroll=unroll)

    def per_device(w, x):
        out = pipeline_apply(w, x, unit_fn=unit_fn, cfg=pipe_cfg)
        return tmap(lambda a: a[None], out)

    def fn_factory(units_stacked, valid):
        w_specs = (_ep_weight_specs(units_stacked, stage_axis, expert_axis),
                   P(stage_axis))
        pspec_x = P(None, data_axes)
        pspec_y = P(stage_axis, None, data_axes)
        sharded = shard_map(per_device, mesh=mesh,
                            in_specs=(w_specs, pspec_x),
                            out_specs=pspec_y, check_rep=False)

        def fn(w, x_mb):
            return tmap(lambda a: a[-1], sharded(w, x_mb))

        return fn

    return fn_factory
