"""Autoregressive decoding THROUGH the DEFER pipeline (beyond-paper).

The paper pipelines independent inference samples; autoregressive LMs add a
twist the paper never faced: token t+1 cannot enter the chain until token t
leaves it.  A naive chain would idle S-1 of S stages.  The fix is the
paper's own FIFO insight applied to decode: keep M >= S *microbatches* (groups
of sequences) in flight — while microbatch m's token is at stage s,
microbatch m+1's token is at stage s-1.  The generated token ppermutes from
the LAST stage straight back to stage 0 on the same circular ring that
relays hidden states, so the dispatcher round-trip of the original
architecture disappears entirely: steady-state emits one token per tick per
microbatch with zero host involvement.

Schedule: tick t, stage s serves microbatch m = (t-s) mod M at decode step
p = (t-s) div M (valid while 0 <= t-s < M*steps).  Per-stage state: the
KV/SSM caches of its own units for ALL M microbatches (leading dim M).

The relayed payload is a pytree {h, tok, logit_tok}: stages 1..S-1 consume
``h``; stage 0 consumes ``tok`` (the token the last stage just sampled) and
embeds it.  With ``compress=True`` the hidden ``h`` rides the int8 wire.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.pipeline import PipelineConfig, _wire_decode, _wire_encode

tmap = jax.tree_util.tree_map


def pipeline_decode_apply(stage_params: Any, caches: Any, start_tok: Any,
                          start_pos: Any, head: Any, *,
                          decode_unit_fn: Callable, embed_fn: Callable,
                          head_fn: Callable, steps: int,
                          cfg: PipelineConfig):
    """Per-device body (under shard_map over ``cfg.axis``).

    stage_params: (units [1, u, ...], valid [1, u]) local slice.
    caches: local unit caches, leaves [1, u, M, ...].
    start_tok [M, mb, 1] int32; start_pos [M, mb] int32.
    head: replicated embed/final-norm/unembed params.
    Returns (tokens [M, steps, mb], final caches local slice).
    """
    S, M = cfg.num_stages, cfg.num_microbatches
    axis = cfg.axis
    sid = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % S) for i in range(S)]
    local_w = tmap(lambda a: a[0], stage_params)
    local_caches = tmap(lambda a: a[0], caches)       # [u, M, ...]
    mb = start_tok.shape[1]
    d = None  # hidden dim from embed

    def relay(y):
        if not cfg.compress:
            return tmap(lambda a: jax.lax.ppermute(a, axis, perm), y)

        def one(a):
            if a.dtype in (jnp.int32, jnp.uint32) or a.ndim < 2:
                return jax.lax.ppermute(a, axis, perm)
            q, sc = _wire_encode(a, cfg.quant_impl)
            q = jax.lax.ppermute(q, axis, perm)
            sc = jax.lax.ppermute(sc, axis, perm)
            return _wire_decode(q, sc, a.shape, a.dtype, cfg.quant_impl)

        return tmap(one, y)

    total = M * steps + S - 1

    def tick(carry, t):
        state, tok_buf, cach, outbuf = carry
        # 1. bank the arriving wrapped token: the payload reaching stage 0 at
        # tick t left the last stage at t-1, which served k_arr = t-S, i.e.
        # microbatch (t-S) mod M.  A single relay slot would be overwritten
        # over the M-S idle ticks before that microbatch's next turn, so
        # stage 0 keeps a per-microbatch token buffer.
        k_arr = t - S
        m_arr = jnp.clip(k_arr % M, 0, M - 1)
        arr_cur = jax.lax.dynamic_index_in_dim(tok_buf, m_arr, 0, False)
        tok_buf = jax.lax.dynamic_update_index_in_dim(
            tok_buf, jnp.where(k_arr >= 0, state["tok"], arr_cur), m_arr, 0)

        # 2. which microbatch / decode step this stage serves now
        k = t - sid
        valid = (k >= 0) & (k < M * steps)
        m = jnp.clip(k % M, 0, M - 1)
        p = jnp.clip(k // M, 0, steps - 1)

        tok_in = jnp.where(
            k < M,                                     # first round: prompt
            jax.lax.dynamic_index_in_dim(start_tok, m, 0, False),
            jax.lax.dynamic_index_in_dim(tok_buf, m, 0, False))
        pos_in = jax.lax.dynamic_index_in_dim(start_pos, m, 0, False) + p

        h_in = jnp.where(sid == 0, embed_fn(head, tok_in), state["h"])
        mcache = tmap(lambda a: jax.lax.dynamic_index_in_dim(a, m, 1, False),
                      cach)                            # [u, ...]
        h_out, new_mcache = decode_unit_fn(local_w, h_in, pos_in, mcache,
                                           head)
        # only commit the cache when this tick is real
        new_mcache = tmap(lambda n, o: jnp.where(valid, n, o), new_mcache,
                          mcache)
        cach = tmap(lambda a, nm: jax.lax.dynamic_update_index_in_dim(
            a, nm, m, 1), cach, new_mcache)

        # last stage: head + greedy sample
        logits = head_fn(head, h_out)                  # [mb, 1, V]
        new_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [mb, 1]

        # record the token this stage just produced (only last stage real)
        write = valid & (sid == S - 1)
        cur = jax.lax.dynamic_slice(outbuf, (m, p, 0), (1, 1, mb))
        upd = jnp.where(write, new_tok[None, :, 0][:, None], cur)
        outbuf = jax.lax.dynamic_update_slice(outbuf, upd, (m, p, 0))

        nxt = relay({"h": h_out, "tok": new_tok})
        return (nxt, tok_buf, cach, outbuf), None

    h0 = embed_fn(head, start_tok[0])                  # shape donor
    state0 = {"h": jnp.zeros_like(h0),
              "tok": jnp.zeros((mb, 1), jnp.int32)}
    tok_buf0 = jnp.zeros((M, mb, 1), jnp.int32)
    out0 = jnp.zeros((M, steps, mb), jnp.int32)
    (_, _, final_caches, outbuf), _ = jax.lax.scan(
        tick, (state0, tok_buf0, local_caches, out0), jnp.arange(total))
    return outbuf, tmap(lambda a: a[None], final_caches)


def make_pipeline_decoder(mesh: Mesh, cfg: PipelineConfig, *,
                          decode_unit_fn, embed_fn, head_fn, steps: int):
    """Sharded decode-pipeline callable.

    fn(stage_params, caches, start_tok, start_pos, head)
      -> (tokens [M, steps, mb], new caches)

    stage_params leaves [S, u, ...]; caches leaves [S, u, M, ...] — both
    sharded over the stage axis.  ``head`` (embed/norm/unembed) replicated.
    """
    from jax.experimental.shard_map import shard_map

    pspec_w = P(cfg.axis)

    def per_device(w, cach, tok, pos, head):
        toks, new_c = pipeline_decode_apply(
            w, cach, tok, pos, head, decode_unit_fn=decode_unit_fn,
            embed_fn=embed_fn, head_fn=head_fn, steps=steps, cfg=cfg)
        return toks[None], new_c

    sharded = shard_map(
        per_device, mesh=mesh,
        in_specs=(pspec_w, pspec_w, P(), P(), P()),
        out_specs=(P(cfg.axis), pspec_w),
        check_rep=False)

    def fn(stage_params, caches, start_tok, start_pos, head):
        toks, new_c = sharded(stage_params, caches, start_tok, start_pos,
                              head)
        return toks[-1], new_c                  # last stage's token record

    return fn
