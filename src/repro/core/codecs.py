"""Wire-format codecs for DEFER: JSON, ZFP-like fixed-rate, and LZ4.

The paper serializes three payload types (architecture spec, weights,
inter-node activations) with {JSON, ZFP} x {LZ4, uncompressed} and measures
energy / overhead / payload for each combination (Table I) plus the resulting
inference throughput (Table II).  These are *real* codecs, not models:

* :class:`JsonCodec`   — JSON of nested lists (the paper's NumPy-JSON path).
* :class:`ZfpCodec`    — fixed-rate blockwise float compressor in the spirit
  of ZFP (Lindstrom 2014): 4x4 blocks, per-block common exponent
  (block-floating-point), orthogonal decorrelating lift, bitplane truncation
  to ``rate`` bits/value.  Lossy with a fixed-rate error bound; round-trip
  accuracy is asserted in tests.
* :class:`Lz4Codec`    — LZ4 *block format* compressor/decompressor in pure
  Python (greedy hash-chain match finder).  Byte-exact round trip; the
  decompressor accepts any spec-conformant stream.

``serialize``/``deserialize`` compose a serializer with an optional
compressor, returning (payload_bytes, timing) so the emulator can charge
overhead and energy exactly the way the paper does.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import time
from typing import Literal

import numpy as np

# --------------------------------------------------------------------------
# JSON serializer (paper: "JSON serialization of NumPy arrays")
# --------------------------------------------------------------------------


class JsonCodec:
    name = "json"
    lossless = True

    def encode(self, arr: np.ndarray) -> bytes:
        payload = {
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": arr.ravel().tolist(),
        }
        return json.dumps(payload).encode("utf-8")

    def decode(self, blob: bytes) -> np.ndarray:
        payload = json.loads(blob.decode("utf-8"))
        return np.asarray(payload["data"], dtype=np.dtype(payload["dtype"])).reshape(
            payload["shape"]
        )


# --------------------------------------------------------------------------
# ZFP-like fixed-rate codec
# --------------------------------------------------------------------------

# ZFP's 1D integer lift on a block of 4 (canonical forward/inverse pair from
# the zfp reference implementation).  Applied along both axes of each 4x4
# block; exactly invertible on int64.
def _fwd_lift(arr: np.ndarray, axis: int) -> np.ndarray:
    v = np.moveaxis(arr, axis, 0).astype(np.int64)
    x, y, z, w = v[0].copy(), v[1].copy(), v[2].copy(), v[3].copy()
    x += w; x >>= 1; w -= x
    z += y; z >>= 1; y -= z
    x += z; x >>= 1; z -= x
    w += y; w >>= 1; y -= w
    w += y >> 1; y -= w >> 1
    out = np.stack([x, y, z, w])
    return np.moveaxis(out, 0, axis)


def _inv_lift(arr: np.ndarray, axis: int) -> np.ndarray:
    v = np.moveaxis(arr, axis, 0).astype(np.int64)
    x, y, z, w = v[0].copy(), v[1].copy(), v[2].copy(), v[3].copy()
    y += w >> 1; w -= y >> 1
    y += w; w <<= 1; w -= y
    z += x; x <<= 1; x -= z
    y += z; z <<= 1; z -= y
    w += x; x <<= 1; x -= w
    out = np.stack([x, y, z, w])
    return np.moveaxis(out, 0, axis)


@dataclasses.dataclass
class ZfpCodec:
    """Fixed-rate blockwise transform coder (ZFP-style), 4x4 blocks.

    rate = stored bits per value (total payload ~= rate/32 of float32).
    """

    rate: int = 16
    transform: bool = True
    name: str = "zfp"
    lossless: bool = False

    _MAGIC = b"ZFPR"

    def encode(self, arr: np.ndarray) -> bytes:
        orig_dtype = arr.dtype
        a = np.asarray(arr, dtype=np.float32)
        flat = a.ravel()
        n = flat.size
        pad = (-n) % 16
        if pad:
            flat = np.concatenate([flat, np.zeros(pad, np.float32)])
        blocks = flat.reshape(-1, 4, 4)                       # (B,4,4)

        # per-block common exponent (block floating point)
        absmax = np.abs(blocks).reshape(len(blocks), -1).max(axis=1)
        exp = np.zeros(len(blocks), np.int16)
        nz = absmax > 0
        exp[nz] = np.frexp(absmax[nz])[1].astype(np.int16)   # absmax < 2**exp

        # to fixed point: i = round(x * 2^(30-exp)) fits in int32 with headroom
        scale = np.ldexp(1.0, (30 - exp.astype(np.int64)))[:, None, None]
        q = np.rint(blocks.astype(np.float64) * scale).astype(np.int64)

        if self.transform:
            q = _fwd_lift(q, 1)
            q = _fwd_lift(q, 2)

        # bitplane truncation: keep top `rate` bits -> shift right by 32-rate+2
        # (transform grows magnitude by <=2 bits)
        shift = max(0, 32 - self.rate + 2)
        q >>= shift

        qmax = np.abs(q).max() if q.size else 0
        width = max(8, int(qmax).bit_length() + 1)
        width = 8 * ((width + 7) // 8)                       # byte-aligned width
        store_dtype = {8: np.int8, 16: np.int16, 24: np.int32, 32: np.int32,
                       40: np.int64, 48: np.int64, 56: np.int64, 64: np.int64}[
                           min(width, 64)]
        body = q.astype(store_dtype).tobytes()

        header = self._MAGIC + struct.pack(
            "<qqBBB", n, len(blocks), self.rate, int(self.transform),
            np.dtype(store_dtype).itemsize,
        ) + struct.pack("<B", len(arr.shape)) + struct.pack(
            f"<{len(arr.shape)}q", *arr.shape
        ) + orig_dtype.str.encode().ljust(8, b" ")
        return header + exp.tobytes() + body

    def decode(self, blob: bytes) -> np.ndarray:
        assert blob[:4] == self._MAGIC, "not a ZFPR stream"
        off = 4
        n, nblocks, rate, transform, itemsize = struct.unpack_from("<qqBBB", blob, off)
        off += struct.calcsize("<qqBBB")
        (ndim,) = struct.unpack_from("<B", blob, off); off += 1
        shape = struct.unpack_from(f"<{ndim}q", blob, off)
        off += 8 * ndim
        orig_dtype = np.dtype(blob[off:off + 8].decode().strip()); off += 8
        exp = np.frombuffer(blob, np.int16, nblocks, off).astype(np.int64)
        off += 2 * nblocks
        store_dtype = {1: np.int8, 2: np.int16, 4: np.int32, 8: np.int64}[itemsize]
        q = np.frombuffer(blob, store_dtype, nblocks * 16, off).astype(np.int64)
        q = q.reshape(nblocks, 4, 4)

        shift = max(0, 32 - rate + 2)
        q = q << shift
        if transform:
            q = _inv_lift(q, 2)
            q = _inv_lift(q, 1)
        scale = np.ldexp(1.0, -(30 - exp))[:, None, None]
        out = (q.astype(np.float64) * scale).astype(np.float32).ravel()[:n]
        return out.reshape(shape).astype(orig_dtype)

    def error_bound(self, absmax: float) -> float:
        """Worst-case absolute error for values with |x| <= absmax."""
        # one ulp at the truncated bitplane, inflated by the (non-orthogonal)
        # inverse lift's max row sum and the low bits the forward lift drops
        exp = np.frexp(absmax)[1] if absmax > 0 else 0
        shift = max(0, 32 - self.rate + 2)
        return float(np.ldexp(16.0 * (2 ** shift), int(exp) - 30))


# --------------------------------------------------------------------------
# LZ4 block format
# --------------------------------------------------------------------------


class Lz4Codec:
    """LZ4 *block* format (https://lz4.org), pure-python, byte-exact.

    Greedy match finder with a 4-byte hash table; emits
    [token][literal-len*][literals][offset(2B LE)][matchlen*] sequences.
    """

    name = "lz4"
    MIN_MATCH = 4

    def compress(self, data: bytes) -> bytes:
        n = len(data)
        out = bytearray()
        table: dict[bytes, int] = {}
        i = 0
        anchor = 0
        # last 5 bytes must be literals (spec: last match can't start there +
        # last 5 bytes always literal)
        limit = n - 5
        while i < limit:
            key = data[i:i + 4]
            cand = table.get(key, -1)
            table[key] = i
            if cand >= 0 and i - cand <= 0xFFFF and data[cand:cand + 4] == key:
                # extend match
                mlen = 4
                while i + mlen < n - 5 and data[cand + mlen] == data[i + mlen]:
                    mlen += 1
                lit = data[anchor:i]
                self._emit(out, lit, i - cand, mlen)
                i += mlen
                anchor = i
            else:
                i += 1
        # trailing literals
        lit = data[anchor:]
        token = min(len(lit), 15) << 4
        out.append(token)
        self._emit_len(out, len(lit) - 15)
        out += lit
        return bytes(out)

    @staticmethod
    def _emit_len(out: bytearray, rem: int) -> None:
        if rem < 0:
            return
        while rem >= 255:
            out.append(255)
            rem -= 255
        out.append(rem)

    def _emit(self, out: bytearray, lit: bytes, offset: int, mlen: int) -> None:
        lit_code = min(len(lit), 15)
        m_code = min(mlen - self.MIN_MATCH, 15)
        out.append((lit_code << 4) | m_code)
        if lit_code == 15:
            self._emit_len(out, len(lit) - 15)
        out += lit
        out += struct.pack("<H", offset)
        if m_code == 15:
            self._emit_len(out, mlen - self.MIN_MATCH - 15)

    def decompress(self, blob: bytes) -> bytes:
        out = bytearray()
        i, n = 0, len(blob)
        while i < n:
            token = blob[i]; i += 1
            lit_len = token >> 4
            if lit_len == 15:
                while True:
                    b = blob[i]; i += 1
                    lit_len += b
                    if b != 255:
                        break
            out += blob[i:i + lit_len]
            i += lit_len
            if i >= n:
                break  # final literal-only sequence
            (offset,) = struct.unpack_from("<H", blob, i); i += 2
            mlen = (token & 0xF)
            if mlen == 15:
                while True:
                    b = blob[i]; i += 1
                    mlen += b
                    if b != 255:
                        break
            mlen += self.MIN_MATCH
            pos = len(out) - offset
            for _ in range(mlen):          # may overlap; copy byte-wise
                out.append(out[pos])
                pos += 1
        return bytes(out)


# --------------------------------------------------------------------------
# Composition + timing (what the emulator charges as "overhead")
# --------------------------------------------------------------------------

SerName = Literal["json", "zfp"]
CompName = Literal["lz4", "none"]


@dataclasses.dataclass
class WireStats:
    raw_bytes: int
    wire_bytes: int
    encode_s: float
    decode_s: float

    @property
    def ratio(self) -> float:
        return self.wire_bytes / max(1, self.raw_bytes)


def make_serializer(name: SerName, zfp_rate: int = 16):
    return JsonCodec() if name == "json" else ZfpCodec(rate=zfp_rate)


def roundtrip(arr: np.ndarray, serializer: SerName = "zfp",
              compression: CompName = "none", zfp_rate: int = 16
              ) -> tuple[np.ndarray, WireStats]:
    """Serialize(+compress) then invert, with wall-clock timing."""
    ser = make_serializer(serializer, zfp_rate)
    lz4 = Lz4Codec()
    t0 = time.perf_counter()
    blob = ser.encode(arr)
    if compression == "lz4":
        blob = lz4.compress(blob)
    t1 = time.perf_counter()
    rt = lz4.decompress(blob) if compression == "lz4" else blob
    back = ser.decode(rt)
    t2 = time.perf_counter()
    stats = WireStats(arr.nbytes, len(blob), t1 - t0, t2 - t1)
    return back, stats
