"""Wire-format codecs for DEFER: JSON, ZFP-like fixed-rate, LZ4, and Q8.

The paper serializes three payload types (architecture spec, weights,
inter-node activations) with {JSON, ZFP} x {LZ4, uncompressed} and measures
energy / overhead / payload for each combination (Table I) plus the resulting
inference throughput (Table II).  These are *real* codecs, not models:

* :class:`JsonCodec`   — JSON of nested lists (the paper's NumPy-JSON path).
* :class:`ZfpCodec`    — fixed-rate blockwise float compressor in the spirit
  of ZFP (Lindstrom 2014): 4x4 blocks, per-block common exponent
  (block-floating-point), orthogonal decorrelating lift, bitplane truncation
  to ``rate`` bits/value.  Lossy with a fixed-rate error bound; round-trip
  accuracy is asserted in tests.  The lift runs in place on int64 views
  (``vectorized=True``, the default); ``vectorized=False`` keeps the
  original copy-per-axis reference, byte-identical output.
* :class:`Lz4Codec`    — LZ4 *block format* compressor/decompressor.  The
  default path vectorizes the hot loops with NumPy (bulk-skip of positions
  whose 4-gram occurs only once, slice-compare match extension, slice/RLE
  match copy on decode) and is byte-exact with the pure-Python greedy
  reference (``vectorized=False``), which is kept as the baseline for the
  codec microbenchmark.
* :class:`Q8Codec`     — shared-scale int8 tile quantization, the TPU-native
  ZFP analogue: backed by ``repro.kernels.block_quant`` (a Pallas kernel on
  TPU, interpret mode on CPU), with the same ``error_bound`` contract as
  :class:`ZfpCodec` so the serving runtime can ride int8 end-to-end.

``serialize``/``deserialize`` compose a serializer with an optional
compressor, returning (payload_bytes, timing) so the emulator can charge
overhead and energy exactly the way the paper does.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import time
from typing import Literal

import numpy as np

# --------------------------------------------------------------------------
# JSON serializer (paper: "JSON serialization of NumPy arrays")
# --------------------------------------------------------------------------


class JsonCodec:
    name = "json"
    lossless = True

    def encode(self, arr: np.ndarray) -> bytes:
        payload = {
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "data": arr.ravel().tolist(),
        }
        return json.dumps(payload).encode("utf-8")

    def decode(self, blob: bytes) -> np.ndarray:
        payload = json.loads(blob.decode("utf-8"))
        return np.asarray(payload["data"], dtype=np.dtype(payload["dtype"])).reshape(
            payload["shape"]
        )


# --------------------------------------------------------------------------
# ZFP-like fixed-rate codec
# --------------------------------------------------------------------------

# shared binary framing for the array codecs' headers: ndim + shape + dtype
def _pack_shape_dtype(shape: tuple, dtype: np.dtype) -> bytes:
    return struct.pack("<B", len(shape)) + struct.pack(
        f"<{len(shape)}q", *shape) + dtype.str.encode().ljust(8, b" ")


def _unpack_shape_dtype(blob: bytes, off: int) -> tuple[tuple, np.dtype, int]:
    (ndim,) = struct.unpack_from("<B", blob, off); off += 1
    shape = struct.unpack_from(f"<{ndim}q", blob, off); off += 8 * ndim
    dtype = np.dtype(blob[off:off + 8].decode().strip()); off += 8
    return shape, dtype, off


# ZFP's 1D integer lift on a block of 4 (canonical forward/inverse pair from
# the zfp reference implementation).  Applied along both axes of each 4x4
# block; exactly invertible on int64.
def _fwd_lift(arr: np.ndarray, axis: int) -> np.ndarray:
    v = np.moveaxis(arr, axis, 0).astype(np.int64)
    x, y, z, w = v[0].copy(), v[1].copy(), v[2].copy(), v[3].copy()
    x += w; x >>= 1; w -= x
    z += y; z >>= 1; y -= z
    x += z; x >>= 1; z -= x
    w += y; w >>= 1; y -= w
    w += y >> 1; y -= w >> 1
    out = np.stack([x, y, z, w])
    return np.moveaxis(out, 0, axis)


def _inv_lift(arr: np.ndarray, axis: int) -> np.ndarray:
    v = np.moveaxis(arr, axis, 0).astype(np.int64)
    x, y, z, w = v[0].copy(), v[1].copy(), v[2].copy(), v[3].copy()
    y += w >> 1; w -= y >> 1
    y += w; w <<= 1; w -= y
    z += x; x <<= 1; x -= z
    y += z; z <<= 1; z -= y
    w += x; x <<= 1; x -= w
    out = np.stack([x, y, z, w])
    return np.moveaxis(out, 0, axis)


# Batched variants over the full (B, 4, 4) stacked block tensor: one
# transpose to (4, 4, B) makes every lift operand a large contiguous slice
# (the per-axis views above have inner stride 4, which defeats SIMD), both
# axes run in place in that layout, then one transpose back.  Identical
# arithmetic to the per-axis reference — byte-exact output, ~2-4x faster.
def _fwd_lift_blocks(q: np.ndarray) -> np.ndarray:
    """Forward lift along axes 1 then 2 of (B, 4, 4) int64 blocks."""
    t = np.ascontiguousarray(q.transpose(1, 2, 0))          # (4, 4, B)
    for ax in (0, 1):                                       # == axes 1, 2
        v = t if ax == 0 else t.transpose(1, 0, 2)
        x, y, z, w = v[0], v[1], v[2], v[3]
        x += w; x >>= 1; w -= x
        z += y; z >>= 1; y -= z
        x += z; x >>= 1; z -= x
        w += y; w >>= 1; y -= w
        w += y >> 1; y -= w >> 1
    return np.ascontiguousarray(t.transpose(2, 0, 1))


def _inv_lift_blocks(q: np.ndarray) -> np.ndarray:
    """Inverse lift along axes 2 then 1 of (B, 4, 4) int64 blocks."""
    t = np.ascontiguousarray(q.transpose(1, 2, 0))
    for ax in (1, 0):                                       # == axes 2, 1
        v = t if ax == 0 else t.transpose(1, 0, 2)
        x, y, z, w = v[0], v[1], v[2], v[3]
        y += w >> 1; w -= y >> 1
        y += w; w <<= 1; w -= y
        z += x; x <<= 1; x -= z
        y += z; z <<= 1; z -= y
        w += x; x <<= 1; x -= w
    return np.ascontiguousarray(t.transpose(2, 0, 1))


@dataclasses.dataclass
class ZfpCodec:
    """Fixed-rate blockwise transform coder (ZFP-style), 4x4 blocks.

    rate = stored bits per value (total payload ~= rate/32 of float32).
    """

    rate: int = 16
    transform: bool = True
    name: str = "zfp"
    lossless: bool = False
    vectorized: bool = True        # in-place lift over the stacked tensor

    _MAGIC = b"ZFPR"

    def encode(self, arr: np.ndarray) -> bytes:
        orig_dtype = arr.dtype
        a = np.asarray(arr, dtype=np.float32)
        flat = a.ravel()
        n = flat.size
        pad = (-n) % 16
        if pad:
            flat = np.concatenate([flat, np.zeros(pad, np.float32)])
        blocks = flat.reshape(-1, 4, 4)                       # (B,4,4)

        # per-block common exponent (block floating point)
        absmax = np.abs(blocks).reshape(len(blocks), -1).max(axis=1)
        exp = np.zeros(len(blocks), np.int16)
        nz = absmax > 0
        exp[nz] = np.frexp(absmax[nz])[1].astype(np.int16)   # absmax < 2**exp

        # to fixed point: i = round(x * 2^(30-exp)) fits in int32 with headroom
        scale = np.ldexp(1.0, (30 - exp.astype(np.int64)))[:, None, None]
        if self.vectorized:
            # one f64 temp (fused upcast-multiply), rounded in place
            t = np.multiply(blocks, scale, dtype=np.float64)
            q = np.rint(t, out=t).astype(np.int64)
        else:
            q = np.rint(blocks.astype(np.float64) * scale).astype(np.int64)

        if self.transform:
            if self.vectorized:
                q = _fwd_lift_blocks(q)
            else:
                q = _fwd_lift(q, 1)
                q = _fwd_lift(q, 2)

        # bitplane truncation: keep top `rate` bits -> shift right by 32-rate+2
        # (transform grows magnitude by <=2 bits)
        shift = max(0, 32 - self.rate + 2)
        q >>= shift

        qmax = np.abs(q).max() if q.size else 0
        width = max(8, int(qmax).bit_length() + 1)
        width = 8 * ((width + 7) // 8)                       # byte-aligned width
        store_dtype = {8: np.int8, 16: np.int16, 24: np.int32, 32: np.int32,
                       40: np.int64, 48: np.int64, 56: np.int64, 64: np.int64}[
                           min(width, 64)]
        body = q.astype(store_dtype).tobytes()

        header = self._MAGIC + struct.pack(
            "<qqBBB", n, len(blocks), self.rate, int(self.transform),
            np.dtype(store_dtype).itemsize,
        ) + _pack_shape_dtype(arr.shape, orig_dtype)
        return header + exp.tobytes() + body

    def decode(self, blob: bytes) -> np.ndarray:
        assert blob[:4] == self._MAGIC, "not a ZFPR stream"
        off = 4
        n, nblocks, rate, transform, itemsize = struct.unpack_from("<qqBBB", blob, off)
        off += struct.calcsize("<qqBBB")
        shape, orig_dtype, off = _unpack_shape_dtype(blob, off)
        exp = np.frombuffer(blob, np.int16, nblocks, off).astype(np.int64)
        off += 2 * nblocks
        store_dtype = {1: np.int8, 2: np.int16, 4: np.int32, 8: np.int64}[itemsize]
        q = np.frombuffer(blob, store_dtype, nblocks * 16, off).astype(np.int64)
        q = q.reshape(nblocks, 4, 4)

        shift = max(0, 32 - rate + 2)
        if self.vectorized:
            q <<= shift                  # astype above made q owned
            if transform:
                q = _inv_lift_blocks(q)
        else:
            q = q << shift
            if transform:
                q = _inv_lift(q, 2)
                q = _inv_lift(q, 1)
        scale = np.ldexp(1.0, -(30 - exp))[:, None, None]
        out = (q.astype(np.float64) * scale).astype(np.float32).ravel()[:n]
        return out.reshape(shape).astype(orig_dtype)

    def error_bound(self, absmax: float) -> float:
        """Worst-case absolute error for values with |x| <= absmax."""
        # one ulp at the truncated bitplane, inflated by the (non-orthogonal)
        # inverse lift's max row sum and the low bits the forward lift drops
        exp = np.frexp(absmax)[1] if absmax > 0 else 0
        shift = max(0, 32 - self.rate + 2)
        return float(np.ldexp(16.0 * (2 ** shift), int(exp) - 30))


# --------------------------------------------------------------------------
# LZ4 block format
# --------------------------------------------------------------------------


class Lz4Codec:
    """LZ4 *block* format (https://lz4.org), byte-exact round trip.

    Greedy match finder with a 4-byte hash table; emits
    [token][literal-len*][literals][offset(2B LE)][matchlen*] sequences.

    The default (``vectorized=True``) path produces byte-identical streams
    to the pure-Python reference but vectorizes the three hot loops with
    NumPy:

    * the per-byte table scan bulk-skips every position whose 4-gram occurs
      only once in the input (its table entry could never serve a lookup),
      jumping between candidate positions with a precomputed sorted index;
    * match extension compares slices in growing chunks instead of one byte
      per Python iteration;
    * decompression copies literal runs and non-overlapping matches as
      slices and expands overlapping (RLE-style) matches by tiling.
    """

    name = "lz4"
    MIN_MATCH = 4

    def __init__(self, vectorized: bool = True):
        self.vectorized = vectorized

    def compress(self, data: bytes) -> bytes:
        if self.vectorized:
            return self._compress_vec(data)
        return self._compress_ref(data)

    def _compress_ref(self, data: bytes) -> bytes:
        """Reference greedy compressor (one Python iteration per byte)."""
        n = len(data)
        out = bytearray()
        table: dict[bytes, int] = {}
        i = 0
        anchor = 0
        # last 5 bytes must be literals (spec: last match can't start there +
        # last 5 bytes always literal)
        limit = n - 5
        while i < limit:
            key = data[i:i + 4]
            cand = table.get(key, -1)
            table[key] = i
            if cand >= 0 and i - cand <= 0xFFFF and data[cand:cand + 4] == key:
                # extend match
                mlen = 4
                while i + mlen < n - 5 and data[cand + mlen] == data[i + mlen]:
                    mlen += 1
                lit = data[anchor:i]
                self._emit(out, lit, i - cand, mlen)
                i += mlen
                anchor = i
            else:
                i += 1
        # trailing literals
        lit = data[anchor:]
        token = min(len(lit), 15) << 4
        out.append(token)
        self._emit_len(out, len(lit) - 15)
        out += lit
        return bytes(out)

    def _compress_vec(self, data: bytes) -> bytes:
        """Vectorized greedy compressor, byte-exact with :meth:`_compress_ref`.

        Exactness argument: the reference table entry at position ``p`` is
        only ever *read* by a later position with the same 4-gram, so
        skipping writes for 4-grams that occur once in ``[0, limit)`` cannot
        change any lookup.  Positions inside emitted matches are never
        visited by the reference either, so the jump-to-next-duplicate scan
        visits a superset of the positions whose table writes matter and
        exactly the positions whose lookups matter.
        """
        from bisect import bisect_left
        n = len(data)
        out = bytearray()
        anchor = 0
        limit = n - 5
        if limit > 0:
            u8 = np.frombuffer(data, dtype=np.uint8)
            v = (u8[:n - 3].astype(np.uint32)
                 | (u8[1:n - 2].astype(np.uint32) << 8)
                 | (u8[2:n - 1].astype(np.uint32) << 16)
                 | (u8[3:n].astype(np.uint32) << 24))[:limit]
            _, inverse, counts = np.unique(v, return_inverse=True,
                                           return_counts=True)
            dup_pos = np.nonzero(counts[inverse] > 1)[0]
            # python lists: per-candidate dict/index ops are ~5x cheaper
            # than numpy scalar extraction in this loop
            dups = dup_pos.tolist()
            keys = v[dup_pos].tolist()
            nd = len(dups)
            table: dict[int, int] = {}
            table_get = table.get
            out_append = out.append
            k = 0
            while k < nd:
                i = dups[k]
                key = keys[k]
                k += 1
                cand = table_get(key, -1)
                table[key] = i
                if cand >= 0 and i - cand <= 0xFFFF:
                    # chunked memcmp match extension: short mismatches stay
                    # in one tiny bytes compare, long matches grow the chunk
                    L = limit - (i + 4)
                    a0, b0 = cand + 4, i + 4
                    ext, chunk = 0, 16
                    while ext < L:
                        m = chunk if L - ext >= chunk else L - ext
                        a = data[a0 + ext:a0 + ext + m]
                        b = data[b0 + ext:b0 + ext + m]
                        if a == b:
                            ext += m
                            if chunk < (1 << 20):
                                chunk *= 4
                            continue
                        for j in range(m):          # mismatch inside chunk
                            if a[j] != b[j]:
                                break
                        ext += j
                        break
                    mlen = 4 + ext
                    llen = i - anchor
                    if llen < 15 and ext < 15:      # inlined common emit
                        out_append((llen << 4) | ext)
                        out += data[anchor:i]
                        off = i - cand
                        out_append(off & 0xFF)
                        out_append(off >> 8)
                    else:
                        self._emit(out, data[anchor:i], i - cand, mlen)
                    i += mlen
                    anchor = i
                    if i >= limit:
                        break
                    # skip candidate positions the match consumed: linear
                    # scan for short matches, bisect for long ones
                    stop = k + 8
                    while k < nd and dups[k] < i:
                        k += 1
                        if k >= stop:
                            k = bisect_left(dups, i, k)
                            break
        lit = data[anchor:]
        token = min(len(lit), 15) << 4
        out.append(token)
        self._emit_len(out, len(lit) - 15)
        out += lit
        return bytes(out)

    @staticmethod
    def _emit_len(out: bytearray, rem: int) -> None:
        if rem < 0:
            return
        while rem >= 255:
            out.append(255)
            rem -= 255
        out.append(rem)

    def _emit(self, out: bytearray, lit: bytes, offset: int, mlen: int) -> None:
        lit_code = min(len(lit), 15)
        m_code = min(mlen - self.MIN_MATCH, 15)
        out.append((lit_code << 4) | m_code)
        if lit_code == 15:
            self._emit_len(out, len(lit) - 15)
        out += lit
        out += struct.pack("<H", offset)
        if m_code == 15:
            self._emit_len(out, mlen - self.MIN_MATCH - 15)

    def decompress(self, blob: bytes) -> bytes:
        out = bytearray()
        i, n = 0, len(blob)
        vec = self.vectorized
        while i < n:
            token = blob[i]; i += 1
            lit_len = token >> 4
            if lit_len == 15:
                while True:
                    b = blob[i]; i += 1
                    lit_len += b
                    if b != 255:
                        break
            out += blob[i:i + lit_len]
            i += lit_len
            if i >= n:
                break  # final literal-only sequence
            (offset,) = struct.unpack_from("<H", blob, i); i += 2
            mlen = (token & 0xF)
            if mlen == 15:
                while True:
                    b = blob[i]; i += 1
                    mlen += b
                    if b != 255:
                        break
            mlen += self.MIN_MATCH
            pos = len(out) - offset
            if vec and offset >= mlen:
                out += out[pos:pos + mlen]         # disjoint: one slice copy
            elif vec:
                # overlapping match == periodic extension of the last
                # `offset` bytes; tile instead of copying byte-wise
                window = bytes(out[pos:])
                reps = -(-mlen // offset)
                out += (window * reps)[:mlen]
            else:
                for _ in range(mlen):              # reference byte-wise copy
                    out.append(out[pos])
                    pos += 1
        return bytes(out)


# --------------------------------------------------------------------------
# Q8: shared-scale int8 tile quantization (the TPU-native ZFP analogue)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Q8Codec:
    """Fixed-rate int8 wire serializer backed by ``kernels/block_quant``.

    Per-(8, 128)-VREG-tile shared-scale int8 quantization: a Pallas kernel
    on TPU, the same kernel in interpret mode on CPU, so the inter-node
    activation stream rides the int8 format end-to-end through the serving
    runtime.  Payload = int8 body + a 1/1024 float32 scale sidecar (~8.03
    bits/value), with the same ``error_bound`` contract as :class:`ZfpCodec`.
    """

    name: str = "q8"
    lossless: bool = False

    _MAGIC = b"Q8BQ"

    def encode(self, arr: np.ndarray) -> bytes:
        from repro.kernels import block_quant as bq
        a = np.asarray(arr)
        q, scales = bq.quantize_wire(a)
        header = self._MAGIC + struct.pack("<q", a.size) \
            + _pack_shape_dtype(a.shape, a.dtype) \
            + struct.pack("<q", scales.size)
        # trim the int8 body to the true element count: the pow2 tile
        # padding quantizes zeros, which decode re-synthesizes for free
        return header + scales.tobytes() + q[:a.size].tobytes()

    def decode(self, blob: bytes) -> np.ndarray:
        from repro.kernels import block_quant as bq
        assert blob[:4] == self._MAGIC, "not a Q8BQ stream"
        off = 4
        (n,) = struct.unpack_from("<q", blob, off); off += 8
        shape, dtype, off = _unpack_shape_dtype(blob, off)
        (ns,) = struct.unpack_from("<q", blob, off); off += 8
        scales = np.frombuffer(blob, np.float32, ns, off); off += 4 * ns
        q = np.frombuffer(blob, np.int8, -1, off)
        return bq.dequantize_wire(q, scales, n, shape, dtype)

    def error_bound(self, absmax: float) -> float:
        """Worst-case absolute error for values with |x| <= absmax.

        The true bound is half a quantization step, scale/2 <= absmax/254;
        we claim absmax/127 to cover float32 scale rounding with 2x margin.
        """
        return float(absmax) / 127.0 if absmax > 0 else 0.0


# --------------------------------------------------------------------------
# Composition + timing (what the emulator charges as "overhead")
# --------------------------------------------------------------------------

SerName = Literal["json", "zfp", "q8"]
CompName = Literal["lz4", "none"]


@dataclasses.dataclass
class WireStats:
    raw_bytes: int
    wire_bytes: int
    encode_s: float
    decode_s: float

    @property
    def ratio(self) -> float:
        return self.wire_bytes / max(1, self.raw_bytes)


def make_serializer(name: SerName, zfp_rate: int = 16):
    if name == "json":
        return JsonCodec()
    if name == "q8":
        return Q8Codec()
    return ZfpCodec(rate=zfp_rate)


def roundtrip(arr: np.ndarray, serializer: SerName = "zfp",
              compression: CompName = "none", zfp_rate: int = 16
              ) -> tuple[np.ndarray, WireStats]:
    """Serialize(+compress) then invert, with wall-clock timing."""
    ser = make_serializer(serializer, zfp_rate)
    lz4 = Lz4Codec()
    t0 = time.perf_counter()
    blob = ser.encode(arr)
    if compression == "lz4":
        blob = lz4.compress(blob)
    t1 = time.perf_counter()
    rt = lz4.decompress(blob) if compression == "lz4" else blob
    back = ser.decode(rt)
    t2 = time.perf_counter()
    stats = WireStats(arr.nbytes, len(blob), t1 - t0, t2 - t1)
    return back, stats
