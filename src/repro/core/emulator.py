"""CORE-network-emulator analogue: analytic emulation of the DEFER chain.

The paper runs dispatcher + k compute nodes as separate network namespaces
under CORE with emulated Ethernet links, then measures steady-state inference
throughput, per-node energy, serialization overhead and network payload.

We reproduce that measurement harness analytically + with *measured* codec
timings: the layer graph gives exact per-stage FLOPs and exact inter-stage
activation shapes; the codecs are real (repro.core.codecs), so serialization
overhead and wire payload are measured on real arrays of exactly the tensor
shapes that cross each cut.  Compute/transfer times come from the
:class:`HardwareProfile` / :class:`LinkModel` constants (the emulated part —
CORE emulates links the same way).

Steady-state FIFO pipeline throughput = 1 / max_i service_i, where
service_i = deserialize_i + compute_i + serialize_i + transfer_i
(each node is single-threaded per the paper's THREAD-1/THREAD-2 socket pair:
it relays sample t before computing sample t+1's result is available).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core import codecs
from repro.core.graph import LayerGraph, tree_bytes
from repro.core.metrics import (EDGE, HardwareProfile, compute_energy_j,
                                idle_energy_j, network_energy_j)
from repro.core.partitioner import LinkModel, Partition, partition

CHUNK_BYTES = 512 * 1024  # paper: 512 kB chunked transfer


@dataclasses.dataclass
class CodecConfig:
    serializer: codecs.SerName = "zfp"      # "json" | "zfp"
    compression: codecs.CompName = "none"   # "lz4"  | "none"
    zfp_rate: int = 16

    @property
    def label(self) -> str:
        comp = "LZ4" if self.compression == "lz4" else "Uncompressed"
        return f"{self.serializer.upper()}/{comp}"


@dataclasses.dataclass
class WireMeasurement:
    """Measured (not modeled) serialization cost for one tensor transfer."""

    raw_bytes: int
    wire_bytes: int
    encode_s: float
    decode_s: float
    chunks: int

    @property
    def overhead_s(self) -> float:
        return self.encode_s + self.decode_s


def measure_wire(shape: Sequence[int], cfg: CodecConfig, seed: int = 0,
                 sample_limit: int = 1 << 21, repeats: int = 3
                 ) -> WireMeasurement:
    """Encode/decode a real array of `shape`; subsample huge tensors.

    Pure-python LZ4 runs ~1-5 MB/s, so tensors beyond ``sample_limit`` bytes
    are measured on a slice and scaled linearly (documented in EXPERIMENTS.md;
    ratio and per-byte timing are byte-local for both codecs).  Timings are
    min-of-``repeats`` (least OS/scheduler contention on a 1-core host).
    """
    n = int(np.prod(shape))
    nbytes = n * 4
    scale = 1.0
    if nbytes > sample_limit:
        scale = nbytes / sample_limit
        n = sample_limit // 4
    rng = np.random.default_rng(seed)
    # activation-like data: correlated + sparse-ish (post-ReLU), compressible
    arr = rng.normal(size=n).astype(np.float32)
    arr = np.maximum(arr + 0.3 * np.roll(arr, 1), 0.0)
    best_enc = best_dec = float("inf")
    stats = None
    for _ in range(max(1, repeats)):
        _, stats = codecs.roundtrip(arr, cfg.serializer, cfg.compression,
                                    cfg.zfp_rate)
        best_enc = min(best_enc, stats.encode_s)
        best_dec = min(best_dec, stats.decode_s)
    wire = stats.wire_bytes * scale
    return WireMeasurement(
        raw_bytes=int(nbytes),
        wire_bytes=int(wire),
        encode_s=best_enc * scale,
        decode_s=best_dec * scale,
        chunks=int(np.ceil(wire / CHUNK_BYTES)),
    )


@dataclasses.dataclass
class StageReport:
    node: int
    compute_s: float
    serialize_s: float
    deserialize_s: float
    transfer_s: float
    payload_bytes: int
    energy_j: float                  # active (work) energy, whole stage
    replicas: int = 1                # identical nodes serving this stage
    idle_energy_j: float = 0.0       # baseline burn of the stage's nodes
    #                                  while waiting on the bottleneck

    @property
    def service_s(self) -> float:
        """Per-request service latency (replication never shortens one
        request's own path)."""
        return self.compute_s + self.serialize_s + self.deserialize_s + self.transfer_s

    @property
    def rate_service_s(self) -> float:
        """The stage's contribution to the pipeline bottleneck: replicas
        split the request stream, so the *rate* amortizes by 1/replicas."""
        return self.service_s / max(1, self.replicas)


@dataclasses.dataclass
class EmulationReport:
    model: str
    num_nodes: int                   # total nodes incl. replicas
    codec: str
    throughput_cps: float            # inference cycles / second
    single_device_cps: float
    per_node_energy_j: float         # avg energy per node per inference cycle
    single_device_energy_j: float
    total_payload_mb: float          # per inference cycle
    overhead_s: float                # total serialization time per cycle
    stages: list[StageReport]
    replicas: tuple = ()             # per-stage replica counts ((), pre-
    #                                  replica shape, when not requested)

    @property
    def speedup(self) -> float:
        return self.throughput_cps / self.single_device_cps

    @property
    def energy_ratio(self) -> float:
        return self.per_node_energy_j / self.single_device_energy_j


@dataclasses.dataclass
class ConfigStepReport:
    """The configuration step: dispatcher ships architecture + weights."""

    kind: str                       # "architecture" | "weights" | "data"
    codec: str
    energy_j: float
    overhead_s: float
    payload_mb: float


def emulate(graph: LayerGraph, num_nodes: int,
            cfg: CodecConfig | None = None,
            hw: HardwareProfile = EDGE,
            link: LinkModel | None = None,
            strategy: str = "equal_layers",
            seed: int = 0,
            replicas: Sequence[int] | None = None) -> EmulationReport:
    """Emulate DEFER steady state for ``graph`` on ``num_nodes`` compute
    stages.

    ``replicas`` (per-stage counts, SEIFER-style replicated partitions)
    adds the replica dimension: the pipeline bottleneck amortizes each
    stage's service time by its replica count (rate, never a request's
    own latency), ``num_nodes`` becomes the total node count, and energy
    gains the idle term the paper's per-node measurement implies — every
    replica of a non-bottleneck stage sits idle part of each cycle, and a
    powered-on idle node still draws ``hw.idle_w``.  ``replicas=None``
    (default) reproduces the pre-replica report exactly.
    """
    cfg = cfg or CodecConfig()
    link = link or LinkModel(bandwidth_bytes_per_s=hw.link_bw,
                             energy_per_bit_j=hw.energy_per_bit_j)
    from repro.core.partitioner import ComputeModel
    comp = ComputeModel(flops_per_s=hw.peak_flops, tdp_w=hw.tdp_w)
    reps = list(replicas) if replicas is not None else None
    if reps is not None and len(reps) != num_nodes:
        raise ValueError(f"{len(reps)} replica counts for "
                         f"{num_nodes} stages")
    part = partition(graph, num_nodes, strategy=strategy, link=link,
                     compute=comp, replicas=reps)

    stages: list[StageReport] = []
    outbound: list[WireMeasurement] = []
    for si, st in enumerate(part.stages):
        compute_s = st.flops / hw.peak_flops
        # measure real codec cost on the outbound activation of this stage
        out_elems = max(1, st.out_bytes // 4)
        wm = measure_wire((out_elems,), cfg, seed=seed + si)
        transfer_s = link.latency_s * wm.chunks + wm.wire_bytes / link.bandwidth_bytes_per_s
        # inbound deserialization (previous stage's payload)
        if si == 0:
            in_elems = max(1, tree_bytes(graph.input_spec) // 4)
            wm_in = measure_wire((in_elems,), cfg, seed=seed + 101 + si)
        else:
            wm_in = outbound[-1]
        energy = (
            compute_energy_j(compute_s + wm.encode_s + wm_in.decode_s, hw)
            + network_energy_j(wm.wire_bytes, hw)
        )
        stages.append(StageReport(
            node=si,
            compute_s=compute_s,
            serialize_s=wm.encode_s,
            deserialize_s=wm_in.decode_s,
            transfer_s=transfer_s,
            payload_bytes=wm.wire_bytes,
            energy_j=energy,
            replicas=reps[si] if reps is not None else 1,
        ))
        outbound.append(wm)

    # steady-state cycle time: the slowest stage RATE (service amortized
    # by replicas; with replicas=None this is exactly max service_s)
    bottleneck = max(s.rate_service_s for s in stages)
    throughput = 1.0 / bottleneck

    total_nodes = sum(reps) if reps is not None else num_nodes
    if reps is not None:
        # idle burn per cycle: each replica of stage i works
        # (compute+codec)/replicas seconds of a cycle and idles the rest —
        # the paper's per-node baseline that over-provisioning pays for
        for s in stages:
            active_per_replica = (s.compute_s + s.serialize_s
                                  + s.deserialize_s) / s.replicas
            s.idle_energy_j = s.replicas * idle_energy_j(
                bottleneck - active_per_replica, hw)

    # single-device baseline: whole graph on one node, no wire codecs
    single_compute_s = graph.total_flops / hw.peak_flops
    single_cps = 1.0 / single_compute_s
    single_energy = compute_energy_j(single_compute_s, hw)

    return EmulationReport(
        model=graph.name,
        num_nodes=total_nodes,
        codec=cfg.label,
        throughput_cps=throughput,
        single_device_cps=single_cps,
        per_node_energy_j=sum(s.energy_j + s.idle_energy_j
                              for s in stages) / total_nodes,
        single_device_energy_j=single_energy,
        total_payload_mb=sum(s.payload_bytes for s in stages) / 1e6,
        overhead_s=sum(s.serialize_s + s.deserialize_s for s in stages),
        stages=stages,
        replicas=tuple(reps) if reps is not None else (),
    )


def emulate_config_step(graph: LayerGraph, num_nodes: int, cfg: CodecConfig,
                        hw: HardwareProfile = EDGE, seed: int = 0
                        ) -> dict[str, ConfigStepReport]:
    """Configuration-step costs: architecture JSON + weights arrays (Table I)."""
    import json as _json

    # architecture spec: layer names/shapes/edges, like a Keras config JSON
    arch_spec = [
        {"name": n.name, "inputs": list(n.inputs),
         "out_shape": list(n.out_spec.shape), "flops": n.flops}
        for n in graph.nodes
    ]
    blob = _json.dumps(arch_spec).encode()
    t0 = time.perf_counter()
    if cfg.compression == "lz4":
        wire = codecs.Lz4Codec().compress(blob)
    else:
        wire = blob
    t1 = time.perf_counter()
    arch = ConfigStepReport(
        kind="architecture", codec=cfg.label,
        energy_j=compute_energy_j(t1 - t0, hw) + network_energy_j(len(wire), hw),
        overhead_s=t1 - t0,
        payload_mb=len(wire) / 1e6,
    )

    # weights: measured on real arrays, scaled to total param bytes
    pbytes = graph.total_param_bytes
    wm = measure_wire((max(1, pbytes // 4),), cfg, seed=seed)
    weights = ConfigStepReport(
        kind="weights", codec=cfg.label,
        energy_j=compute_energy_j(wm.overhead_s, hw) + network_energy_j(wm.wire_bytes, hw),
        overhead_s=wm.overhead_s,
        payload_mb=wm.wire_bytes / 1e6,
    )

    # inference data: sum of inter-stage activations for one cycle
    part = partition(graph, num_nodes, strategy="equal_layers")
    data_bytes = sum(st.out_bytes for st in part.stages)
    wm_d = measure_wire((max(1, data_bytes // 4),), cfg, seed=seed + 1)
    data = ConfigStepReport(
        kind="data", codec=cfg.label,
        energy_j=compute_energy_j(wm_d.overhead_s, hw) + network_energy_j(wm_d.wire_bytes, hw),
        overhead_s=wm_d.overhead_s,
        payload_mb=wm_d.wire_bytes / 1e6,
    )
    return {"architecture": arch, "weights": weights, "data": data}
