"""A decode-capable transformer as a partitionable :class:`LayerGraph`.

This is the bridge between the model zoo's attention/MLP primitives and the
serving runtime's autoregressive session path: every attention block carries
a :class:`~repro.core.graph.LayerDecode` (prefill builds the fixed-capacity
KV cache, step consumes one token against it), every other block is
stateless token-wise compute whose ``fn`` already works at ``S=1``.  The
graph is a pure chain, so any contiguous partition has exactly one boundary
activation — a decode step ships ``[1, 1, d_model]`` per hop instead of the
full sequence.

Greedy decode through the distributed chain is bit-identical to
:func:`pipeline_decode_reference` below because both run the very same
``prefill_fn``/``step_fn`` per layer; batching sessions along axis 0 does
not change per-row arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import LayerDecode, LayerGraph
from repro.models.attention import (AttnSpec, attention, attention_decode,
                                    attn_flops)
from repro.models.layers import apply_rope, linear, mlp, mlp_flops, rmsnorm


def _attn_nodes(spec: AttnSpec, cache_len: int, use_kernel: bool):
    """(fn, prefill, step) closures for one attention block."""

    def fn(p, x):
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        return attention(p, spec, x, positions)

    def prefill(p, x):
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        y = attention(p, spec, x, positions)
        # cache the prompt's K/V at slots [0, S) of the fixed-capacity
        # buffer (prompts longer than cache_len are rejected at session
        # open); kpos = -1 marks empty slots for the decode mask
        h = rmsnorm(p["ln"], x)
        k = linear(p["wk"], h).reshape(B, S, spec.kv_heads, spec.head_dim)
        v = linear(p["wv"], h).reshape(B, S, spec.kv_heads, spec.head_dim)
        k = apply_rope(k, positions, spec.rope_theta)
        shape = (B, cache_len, spec.kv_heads, spec.head_dim)
        ck = jnp.zeros(shape, x.dtype).at[:, :S].set(k)
        cv = jnp.zeros(shape, x.dtype).at[:, :S].set(v)
        kpos = jnp.full((B, cache_len), -1, jnp.int32).at[:, :S].set(
            jnp.arange(S, dtype=jnp.int32)[None, :])
        return y, {"k": ck, "v": cv, "kpos": kpos}

    def step(p, cache, x, pos):
        out, kv, kpos = attention_decode(
            p, spec, x, pos, {"k": cache["k"], "v": cache["v"]},
            cache["kpos"], use_kernel=use_kernel)
        return out, {"k": kv["k"], "v": kv["v"], "kpos": kpos}

    return fn, prefill, step


def decode_lm_graph(vocab: int = 64, d_model: int = 32, n_layers: int = 2,
                    num_heads: int = 2, kv_heads: int = 2, head_dim: int = 16,
                    d_ff: int = 64, cache_len: int = 64, seq_hint: int = 8,
                    use_kernel: bool = False, dtype=np.float32) -> LayerGraph:
    """Build a small decoder-only transformer LayerGraph.

    ``cache_len`` is the per-session KV capacity every attention block
    allocates at prefill — a graph-level constant so per-session caches
    (leading axis 1) stack into one decode batch with a single jit
    specialization per batch size.  ``seq_hint`` only sizes the nominal
    out_specs the partitioner costs cuts with.
    """
    spec = AttnSpec(d_model=d_model, num_heads=num_heads, kv_heads=kv_heads,
                    head_dim=head_dim)
    f32 = dtype
    g = LayerGraph(f"lm-{n_layers}x{d_model}",
                   jax.ShapeDtypeStruct((1, seq_hint), np.int32))
    act_spec = jax.ShapeDtypeStruct((1, seq_hint, d_model), f32)

    g.layer("embed", lambda p, x: p["table"][x],
            {"table": jax.ShapeDtypeStruct((vocab, d_model), f32)},
            ("",), act_spec, flops=0.0, pad_safe=True)
    prev = "embed"
    for i in range(n_layers):
        fn, prefill, step = _attn_nodes(spec, cache_len, use_kernel)
        g.layer(f"blk{i}_attn", fn,
                {"ln": {"scale": jax.ShapeDtypeStruct((d_model,), f32)},
                 "wq": {"w": jax.ShapeDtypeStruct(
                     (d_model, num_heads * head_dim), f32)},
                 "wk": {"w": jax.ShapeDtypeStruct(
                     (d_model, kv_heads * head_dim), f32)},
                 "wv": {"w": jax.ShapeDtypeStruct(
                     (d_model, kv_heads * head_dim), f32)},
                 "wo": {"w": jax.ShapeDtypeStruct(
                     (num_heads * head_dim, d_model), f32)}},
                (prev,), act_spec,
                flops=attn_flops(spec, seq_hint, seq_hint),
                pad_safe=False,
                decode=LayerDecode(prefill_fn=prefill, step_fn=step))
        g.layer(f"blk{i}_mlp", lambda p, x: mlp(p, x),
                {"ln": {"scale": jax.ShapeDtypeStruct((d_model,), f32)},
                 "up": {"w": jax.ShapeDtypeStruct((d_model, d_ff), f32)},
                 "down": {"w": jax.ShapeDtypeStruct((d_ff, d_model), f32)}},
                (f"blk{i}_attn",), act_spec,
                flops=mlp_flops(d_model, d_ff, False, seq_hint),
                pad_safe=True)
        prev = f"blk{i}_mlp"
    g.layer("head", lambda p, x: linear(p["out"], rmsnorm(p["ln"], x)),
            {"ln": {"scale": jax.ShapeDtypeStruct((d_model,), f32)},
             "out": {"w": jax.ShapeDtypeStruct((d_model, vocab), f32)}},
            (prev,), jax.ShapeDtypeStruct((1, seq_hint, vocab), f32),
            flops=2.0 * seq_hint * d_model * vocab, pad_safe=True)
    # per-session KV capacity; the session layer enforces
    # len(prompt) + max_new_tokens <= decode_cache_len at open
    g.decode_cache_len = cache_len
    return g


def pipeline_decode_reference(graph: LayerGraph, params, prompt,
                              max_new_tokens: int) -> list[int]:
    """Single-device greedy decode through a decode-capable LayerGraph —
    the reference the distributed session path must match bit-for-bit.
    Runs the same per-layer ``prefill_fn``/``step_fn`` the compute nodes
    jit, just without partitioning, batching, or a wire."""
    acts = jnp.asarray(np.asarray(prompt, np.int32).reshape(1, -1))
    pos = acts.shape[1]
    caches: dict[str, object] = {}
    for node in graph.nodes:
        p = params[node.name]
        if node.decode is not None:
            acts, caches[node.name] = node.decode.prefill_fn(p, acts)
        else:
            acts = node.fn(p, acts)
    toks: list[int] = []
    while True:
        toks.append(int(np.argmax(np.asarray(acts[0, -1]))))
        if len(toks) >= max_new_tokens:
            return toks
        acts = jnp.asarray([[toks[-1]]], jnp.int32)
        pv = jnp.asarray([pos], jnp.int32)
        for node in graph.nodes:
            p = params[node.name]
            if node.decode is not None:
                acts, caches[node.name] = node.decode.step_fn(
                    p, caches[node.name], acts, pv)
            else:
                acts = node.fn(p, acts)
        pos += 1
