"""Mamba2 block via SSD (state-space duality), chunk-parallel form.

Recurrence per head (state S in R^{P x N}):
    S_t = exp(dt_t * A) * S_{t-1} + dt_t * x_t B_t^T,    y_t = S_t C_t + D x_t

Train/prefill uses the SSD chunked algorithm (arXiv:2405.21060): quadratic
attention-like term inside chunks of length Q, linear recurrence across
chunks via ``lax.scan`` — matmul-heavy (MXU-friendly), O(S*Q) not O(S^2).
Decode is the O(1) single-step recurrence.  ``repro.kernels.ssd_scan`` is the
Pallas TPU kernel for the chunk body; this module is also its oracle
(``ssd_chunked`` with small shapes).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig
from repro.models.layers import he_init, init_rmsnorm, rmsnorm


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    ssm: SSMConfig

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.ssm.state_dim   # x + B + C (G=1)


def init_mamba(key, s: MambaSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    di, N, H = s.d_inner, s.ssm.state_dim, s.n_heads
    return {
        "ln": init_rmsnorm(s.d_model, dtype),
        "in_proj": he_init(ks[0], (s.d_model, 2 * di + 2 * N + H), dtype),
        "conv_w": he_init(ks[1], (s.ssm.conv_width, s.conv_channels), dtype,
                          fan_in=s.ssm.conv_width),
        "conv_b": jnp.zeros((s.conv_channels,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) ~ -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": he_init(ks[2], (di, s.d_model), dtype),
    }


def mamba_param_count(s: MambaSpec) -> int:
    di, N, H, w = s.d_inner, s.ssm.state_dim, s.n_heads, s.ssm.conv_width
    return (s.d_model                              # ln
            + s.d_model * (2 * di + 2 * N + H)     # in_proj
            + w * s.conv_channels + s.conv_channels
            + 3 * H                                # A_log, D, dt_bias
            + di                                   # gated norm
            + di * s.d_model)                      # out_proj


def _split_proj(s: MambaSpec, zxbcdt):
    di, N, H = s.d_inner, s.ssm.state_dim, s.n_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width w.  xBC [B,S,ch]; conv_state [B,w-1,ch]."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(xBC[:, : w - 1])
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(w))
    new_state = xp[:, -(w - 1):]
    return jax.nn.silu(out + conv_b), new_state


def ssd_chunked(x, dt, A, B_mat, C_mat, chunk: int, init_state=None,
                use_kernel: bool = False):
    """SSD scan.  x [B,S,H,P]; dt [B,S,H] (>0); A [H] (<0);
    B_mat/C_mat [B,S,N] (single group, broadcast over heads).
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    Bb, S, H, P = x.shape
    N = B_mat.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:                       # pad with dt=0 steps (state-neutral)
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = B_mat.reshape(Bb, nc, Q, N)
    Cc = C_mat.reshape(Bb, nc, Q, N)

    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)

    if use_kernel:
        from repro.kernels import ops as kops
        y, final = kops.ssd_scan(xc, dtc, A, Bc, Cc, init_state)
        return y[:, :S_orig], final

    def body(state, inp):
        xq, dtq, Bq, Cq = inp          # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        l = dtq.astype(jnp.float32) * A                     # [B,Q,H] (<=0)
        cum = jnp.cumsum(l, axis=1)                         # [B,Q,H]
        # intra-chunk quadratic term
        Lmat = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # [B,Q,Q,H]
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(causal[None, :, :, None], Lmat, 0.0)
        CB = jnp.einsum("bqn,bsn->bqs", Cc_f(Cq), Cc_f(Bq))       # [B,Q,Q]
        scores = CB[:, :, :, None] * Lmat * dtq[:, None, :, :]    # [B,Q,Q,H]
        y = jnp.einsum("bqsh,bshp->bqhp", scores, xq.astype(jnp.float32))
        # inter-chunk: contribution of incoming state
        y += jnp.einsum("bqn,bhpn->bqhp", Cc_f(Cq), state) \
            * jnp.exp(cum)[:, :, :, None]
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)              # [B,Q,H]
        dx = xq.astype(jnp.float32) * (dtq * decay_to_end)[..., None]
        new_state = state * jnp.exp(cum[:, -1])[:, :, None, None] \
            + jnp.einsum("bqhp,bqn->bhpn", dx, Cc_f(Bq))
        return new_state, y.astype(x.dtype)

    Cc_f = lambda t: t.astype(jnp.float32)
    final, ys = jax.lax.scan(
        body, init_state,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)[:, :S_orig]
    return y, final


def mamba_block(p: dict, s: MambaSpec, x: jax.Array, eps: float = 1e-5,
                use_kernel: bool = False) -> jax.Array:
    """Full Mamba2 block (train/prefill).  x [B,S,d] -> [B,S,d]."""
    B, S, _ = x.shape
    di, N, H, P = s.d_inner, s.ssm.state_dim, s.n_heads, s.ssm.head_dim
    h = rmsnorm(p["ln"], x, eps)
    z, xBC, dt_raw = _split_proj(s, h @ p["in_proj"])
    xBC, _ = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, s.ssm.chunk, use_kernel=use_kernel)
    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), eps)
    return x + y @ p["out_proj"]


# -- decode -------------------------------------------------------------------

def init_mamba_cache(s: MambaSpec, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, s.ssm.conv_width - 1, s.conv_channels), dtype),
        "ssd": jnp.zeros((batch, s.n_heads, s.ssm.head_dim, s.ssm.state_dim),
                         jnp.float32),
    }


def mamba_decode(p: dict, s: MambaSpec, x: jax.Array, cache: dict,
                 eps: float = 1e-5):
    """One token.  x [B,1,d] -> ([B,1,d], new_cache).  O(1) in history."""
    B = x.shape[0]
    di, N, H, P = s.d_inner, s.ssm.state_dim, s.n_heads, s.ssm.head_dim
    h = rmsnorm(p["ln"], x, eps)
    z, xBC, dt_raw = _split_proj(s, h @ p["in_proj"])
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], cache["conv"])
    xs = xBC[:, 0, :di].reshape(B, H, P)
    Bm = xBC[:, 0, di:di + N].astype(jnp.float32)
    Cm = xBC[:, 0, di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                            # [B,H]
    S_new = cache["ssd"] * a[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xs.astype(jnp.float32) * dt[..., None], Bm)
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cm)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), eps)
    return x + y @ p["out_proj"], {"conv": new_conv, "ssd": S_new}


def mamba_flops(s: MambaSpec, tokens: int) -> float:
    di, N, H, P, Q = (s.d_inner, s.ssm.state_dim, s.n_heads, s.ssm.head_dim,
                      s.ssm.chunk)
    proj = 2.0 * tokens * s.d_model * (2 * di + 2 * N + H) \
        + 2.0 * tokens * di * s.d_model
    intra = 2.0 * tokens * Q * (N + H * P)       # CB^T + scores@x
    inter = 4.0 * tokens * H * P * N             # state in/out
    return proj + intra + inter
