"""Composable transformer LM covering every assigned family.

Families map to a repeating *unit* of layers that is scanned over
(``lax.scan``) for compile-time O(1) HLO size:

* dense / vlm    unit = ``len(window_pattern)`` (attn + MLP) layers
                 (gemma3: 5 sliding-window + 1 global per unit)
* moe            unit = 1 (attn + MoE) layer
* ssm            unit = 1 Mamba2 layer
* hybrid         unit = ``hybrid_unit`` Mamba2 layers + the SHARED
                 (weight-tied) attention+MLP block (zamba2)
* encdec / audio separate encoder and decoder unit stacks; decoder units
                 add cross-attention over the encoder output

``num_layers % unit`` remainder layers are stored in a small unrolled stack.

Three entry points (used by launch/ for train and serve):
  ``forward``      train/prefill logits (+ router aux loss)
  ``prefill``      forward + KV/SSM caches for subsequent decode
  ``decode_step``  one token through all layers with caches (serve_step)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnSpec
from repro.models.moe import MoESpec
from repro.models.ssm import MambaSpec


# -- specs ---------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, window: int | None, causal: bool = True) -> AttnSpec:
    return AttnSpec(cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.head_dim,
                    cfg.rope_theta, window, causal)


def moe_spec(cfg: ModelConfig) -> MoESpec:
    return MoESpec(cfg.d_model, cfg.d_ff, cfg.gated_mlp, cfg.moe)


def mamba_spec(cfg: ModelConfig) -> MambaSpec:
    return MambaSpec(cfg.d_model, cfg.ssm)


def _window_at(cfg: ModelConfig, i: int) -> int | None:
    return cfg.window_pattern[i % len(cfg.window_pattern)]


def _unit_count(cfg: ModelConfig) -> tuple[int, int]:
    u = cfg.unit_layers
    return cfg.num_layers // u, cfg.num_layers % u


# -- init -------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, pos_in_unit: int, dtype,
                encoder: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    if cfg.family in ("ssm", "hybrid") and not encoder:
        return {"mamba": ssm_mod.init_mamba(ks[0], mamba_spec(cfg), dtype)}
    out: dict[str, Any] = {
        "attn": attn_mod.init_attn(
            ks[0], attn_spec(cfg, _window_at(cfg, pos_in_unit),
                             causal=not encoder), dtype)
    }
    if cfg.moe and not encoder:
        out["moe"] = moe_mod.init_moe(ks[1], moe_spec(cfg), dtype)
    else:
        out["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    if cfg.encoder_layers and not encoder:
        out["cross"] = attn_mod.init_cross_attn(
            ks[2], attn_spec(cfg, None, causal=False), dtype)
    return out


def _init_unit(key, cfg: ModelConfig, dtype, encoder: bool = False) -> dict:
    u = 1 if encoder else cfg.unit_layers
    ks = jax.random.split(key, u)
    return {f"pos{i}": _init_layer(ks[i], cfg, i, dtype, encoder)
            for i in range(u)}


def _pad_rows(table: jax.Array, rows: int) -> jax.Array:
    """Zero-pad dim 0 to ``rows``.  Pad rows MUST be zero (not random):
    tied-embedding logits are x @ table.T, so a nonzero pad row would bleed
    into real-id logits' gradient and break unpadded-model equivalence; the
    real rows are drawn from the SAME rng stream as the unpadded init."""
    if table.shape[0] == rows:
        return table
    pad = jnp.zeros((rows - table.shape[0],) + table.shape[1:], table.dtype)
    return jnp.concatenate([table, pad], axis=0)


def init_lm(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    n_units, rem = _unit_count(cfg)
    embed = L.init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype)
    embed["table"] = _pad_rows(embed["table"], cfg.padded_vocab)
    params: dict[str, Any] = {
        "embed": embed,
        "units": jax.vmap(lambda k: _init_unit(k, cfg, dtype))(
            jax.random.split(ks[1], n_units)),
        "final_ln": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if rem:
        # remainder layers: stacked single-layer units (window is an apply-time
        # property, so all share pos-0 param shapes)
        params["rem"] = jax.vmap(
            lambda k: {"pos0": _init_layer(k, cfg, 0, dtype)})(
            jax.random.split(ks[2], rem))
    if cfg.family == "hybrid":
        params["shared"] = {
            "attn": attn_mod.init_attn(ks[3], attn_spec(cfg, None), dtype),
            "mlp": L.init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
        }
    if cfg.encoder_layers:
        params["enc_units"] = jax.vmap(
            lambda k: _init_unit(k, cfg, dtype, encoder=True))(
            jax.random.split(ks[5], cfg.encoder_layers))
    if not cfg.tie_embeddings:
        unembed = L.init_linear(ks[6], cfg.d_model, cfg.vocab, dtype)
        unembed["w"] = _pad_rows(unembed["w"].T, cfg.padded_vocab).T
        params["unembed"] = unembed
    return params


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree without allocating (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_lm(cfg, k, dtype), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )


def param_count(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))


# -- apply -------------------------------------------------------------------------

def _tree_at(tree, i: int):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _scan_units(body, carry, units, remat: bool = False, unroll: bool = False,
                remat_policy: str = "full"):
    """scan `body` over the stacked-unit axis.

    ``unroll=True`` emits a python loop instead of ``lax.scan`` — identical
    math, but the lowered HLO contains every unit explicitly, so the
    dry-run's ``cost_analysis()`` / collective-byte parse see true totals
    (XLA cost analysis counts a while-loop body once).  Launch paths use it;
    runtime paths keep the scan for O(1) HLO size.
    """
    if remat:
        policy = (jax.checkpoint_policies.dots_saveable
                  if remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)
    if not unroll:
        return jax.lax.scan(body, carry, units)
    n = jax.tree_util.tree_leaves(units)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, _tree_at(units, i))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ys)
    else:
        ys = None
    return carry, ys


def _apply_layer(lp: dict, cfg: ModelConfig, x, positions, aux, window,
                 enc_out=None, use_kernel=False, encoder=False):
    if "mamba" in lp:
        x = ssm_mod.mamba_block(lp["mamba"], mamba_spec(cfg), x,
                                cfg.norm_eps, use_kernel)
        return x, aux
    s = attn_spec(cfg, window, causal=not encoder)
    x = attn_mod.attention(lp["attn"], s, x, positions, cfg.norm_eps)
    if "cross" in lp and enc_out is not None:
        x = attn_mod.cross_attention(lp["cross"], attn_spec(cfg, None, False),
                                     x, enc_out, eps=cfg.norm_eps)
    if "moe" in lp:
        x, a = moe_mod.moe_block(lp["moe"], moe_spec(cfg), x, cfg.norm_eps)
        aux = aux + a
    else:
        x = L.mlp(lp["mlp"], x, cfg.norm_eps)
    return x, aux


def _apply_unit(up: dict, cfg: ModelConfig, x, positions, aux, shared=None,
                enc_out=None, use_kernel=False, encoder=False, n_pos=None):
    n_pos = n_pos or (1 if encoder else cfg.unit_layers)
    for i in range(n_pos):
        x, aux = _apply_layer(up[f"pos{i}"], cfg, x, positions, aux,
                              _window_at(cfg, i), enc_out, use_kernel, encoder)
    if shared is not None:
        x = attn_mod.attention(shared["attn"], attn_spec(cfg, None), x,
                               positions, cfg.norm_eps)
        x = L.mlp(shared["mlp"], x, cfg.norm_eps)
    return x, aux


def _fuse_prefix(cfg: ModelConfig, x, prefix_embeds):
    if prefix_embeds is None or cfg.num_prefix_embeds == 0:
        return x
    n = prefix_embeds.shape[1]
    return jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, n:]], axis=1)


def _encode(params, cfg: ModelConfig, encoder_embeds, use_kernel=False,
            unroll=False):
    x = encoder_embeds
    B, Se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    aux = jnp.zeros((), jnp.float32)

    def body(carry, up):
        h, a = carry
        h, a = _apply_unit(up, cfg, h, positions, a, use_kernel=use_kernel,
                           encoder=True)
        return (h, a), None

    (x, aux), _ = _scan_units(body, (x, aux), params["enc_units"],
                              remat=cfg.remat, unroll=unroll,
                              remat_policy=cfg.remat_policy)
    return x, aux


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeds=None, encoder_embeds=None, use_kernel=False,
            unroll=False):
    """tokens [B,S] -> logits [B,S,V]; returns (logits, aux_loss)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = _fuse_prefix(cfg, x, prefix_embeds)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux = jnp.zeros((), jnp.float32)

    enc_out = None
    if cfg.encoder_layers:
        assert encoder_embeds is not None, "enc-dec model needs encoder_embeds"
        enc_out, enc_aux = _encode(params, cfg, encoder_embeds, use_kernel,
                                   unroll)
        aux = aux + enc_aux

    shared = params.get("shared")

    def body(carry, up):
        h, a = carry
        h, a = _apply_unit(up, cfg, h, positions, a, shared=shared,
                           enc_out=enc_out, use_kernel=use_kernel)
        return (h, a), None

    (x, aux), _ = _scan_units(body, (x, aux), params["units"],
                              remat=cfg.remat, unroll=unroll,
                              remat_policy=cfg.remat_policy)

    _, rem = _unit_count(cfg)
    if rem:
        for i in range(rem):
            up = _tree_at(params["rem"], i)
            x, aux = _apply_layer(up["pos0"], cfg, x, positions, aux,
                                  _window_at(cfg, i), enc_out, use_kernel)

    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.linear(params["unembed"], x)
    return _mask_pad_vocab(cfg, logits), aux


def _mask_pad_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """Padded-vocab ids get -inf so softmax/argmax semantics are exact."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    ids = jnp.arange(cfg.padded_vocab)
    return jnp.where(ids < cfg.vocab, logits, -1e30)


def loss_fn(params, cfg: ModelConfig, batch: dict, use_kernel=False,
            unroll=False):
    """Next-token cross entropy (+ router aux)."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("prefix_embeds"),
                          batch.get("encoder_embeds"), use_kernel, unroll)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    return loss + w * aux, {"nll": loss, "aux": aux}


# -- caches / decode -------------------------------------------------------------

def _init_layer_cache(cfg: ModelConfig, pos_in_unit: int, batch: int,
                      max_len: int, dtype, lp_kind: str):
    if lp_kind == "mamba":
        return ssm_mod.init_mamba_cache(mamba_spec(cfg), batch, dtype)
    s = attn_spec(cfg, _window_at(cfg, pos_in_unit))
    c = attn_mod.init_cache(s, batch, max_len, dtype,
                            quant=cfg.kv_cache_quant)
    c["kpos"] = jnp.full((batch, c["k"].shape[1]), -1, jnp.int32)
    return c


def _layer_kind(cfg: ModelConfig) -> str:
    return "mamba" if cfg.family in ("ssm", "hybrid") else "attn"


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    n_units, rem = _unit_count(cfg)
    kind = _layer_kind(cfg)

    def unit_cache(_):
        c = {f"pos{i}": _init_layer_cache(cfg, i, batch, max_len, dtype, kind)
             for i in range(cfg.unit_layers)}
        if cfg.family == "hybrid":
            sc = attn_mod.init_cache(attn_spec(cfg, None), batch, max_len, dtype)
            sc["kpos"] = jnp.full((batch, max_len), -1, jnp.int32)
            c["shared"] = sc
        return c

    caches: dict[str, Any] = {
        "units": jax.vmap(unit_cache)(jnp.arange(n_units)),
    }
    if rem:
        caches["rem"] = jax.vmap(
            lambda i: {"pos0": _init_layer_cache(cfg, 0, batch, max_len, dtype,
                                                 kind)})(jnp.arange(rem))
        # NB: rem layer i uses window _window_at(cfg, i); cache sized per pos0.
        # For gemma3 the remainder layers are all sliding-window => same size.
    if cfg.encoder_layers:
        caches["enc_out"] = jnp.zeros(
            (batch, cfg.num_prefix_embeds, cfg.d_model), dtype)
    return caches


def _decode_layer(lp, cfg, x, pos, cache, window, enc_out, use_kernel):
    if "mamba" in lp:
        x, nc = ssm_mod.mamba_decode(lp["mamba"], mamba_spec(cfg), x,
                                     cache, cfg.norm_eps)
        return x, nc
    s = attn_spec(cfg, window)
    x, nkv, nkpos = attn_mod.attention_decode(
        lp["attn"], s, x, pos, cache, cache["kpos"], cfg.norm_eps, use_kernel)
    nc = {**nkv, "kpos": nkpos}
    if "cross" in lp and enc_out is not None:
        x = attn_mod.cross_attention(lp["cross"], attn_spec(cfg, None, False),
                                     x, enc_out, eps=cfg.norm_eps)
    if "moe" in lp:
        x, _ = moe_mod.moe_block(lp["moe"], moe_spec(cfg), x, cfg.norm_eps)
    else:
        x = L.mlp(lp["mlp"], x, cfg.norm_eps)
    return x, nc


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                pos: jax.Array, caches: dict, use_kernel=False, unroll=False):
    """One serve step: token [B,1] (ids), pos [B] -> (logits [B,1,V], caches)."""
    B = token.shape[0]
    x = L.embed(params["embed"], token)
    enc_out = caches.get("enc_out")
    shared = params.get("shared")

    def body(carry, xs):
        h = carry
        up, uc = xs
        ncs = {}
        for i in range(cfg.unit_layers):
            h, nc = _decode_layer(up[f"pos{i}"], cfg, h, pos, uc[f"pos{i}"],
                                  _window_at(cfg, i), enc_out, use_kernel)
            ncs[f"pos{i}"] = nc
        if shared is not None:
            s = attn_spec(cfg, None)
            sc = uc["shared"]
            hs = h
            h, nkv, nkpos = attn_mod.attention_decode(
                shared["attn"], s, hs, pos, sc, sc["kpos"], cfg.norm_eps,
                use_kernel)
            h = L.mlp(shared["mlp"], h, cfg.norm_eps)
            ncs["shared"] = {**nkv, "kpos": nkpos}
        return h, ncs

    x, new_unit_caches = _scan_units(body, x,
                                     (params["units"], caches["units"]),
                                     unroll=unroll)
    new_caches = dict(caches)
    new_caches["units"] = new_unit_caches

    _, rem = _unit_count(cfg)
    if rem:
        ncs = []
        for i in range(rem):
            up = _tree_at(params["rem"], i)
            uc = _tree_at(caches["rem"], i)
            x, nc = _decode_layer(up["pos0"], cfg, x, pos, uc["pos0"],
                                  _window_at(cfg, i), enc_out, use_kernel)
            ncs.append({"pos0": nc})
        new_caches["rem"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *ncs)

    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = (L.unembed(params["embed"], x) if cfg.tie_embeddings
              else L.linear(params["unembed"], x))
    return _mask_pad_vocab(cfg, logits), new_caches


# -- prefill ----------------------------------------------------------------------

def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            prefix_embeds=None, encoder_embeds=None, max_len: int | None = None,
            use_kernel=False, unroll=False):
    """Run the full prompt, returning (last_logits, caches) for decode.

    Implemented as forward + cache construction per layer; attention layers
    re-project K/V once more for cache filling (2 extra GEMMs per layer —
    negligible vs attention itself, keeps the fast path allocation-free).
    For simplicity and exactness we instead run layer-by-layer collecting
    caches, mirroring forward()'s structure.
    """
    B, S = tokens.shape
    max_len = max_len or S
    x = L.embed(params["embed"], tokens)
    x = _fuse_prefix(cfg, x, prefix_embeds)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux = jnp.zeros((), jnp.float32)

    enc_out = None
    if cfg.encoder_layers:
        enc_out, _ = _encode(params, cfg, encoder_embeds, use_kernel, unroll)

    dtype = x.dtype
    shared = params.get("shared")

    def prefill_layer(lp, h, window, pos_in_unit):
        """returns (new_h, cache)"""
        if "mamba" in lp:
            ms = mamba_spec(cfg)
            hh = L.rmsnorm(lp["mamba"]["ln"], h, cfg.norm_eps)
            z, xBC, dt_raw = ssm_mod._split_proj(ms, hh @ lp["mamba"]["in_proj"])
            xBC_c, conv_state = ssm_mod._causal_conv(
                xBC, lp["mamba"]["conv_w"], lp["mamba"]["conv_b"])
            di = ms.d_inner
            N = ms.ssm.state_dim
            xs = xBC_c[..., :di].reshape(B, S, ms.n_heads, ms.ssm.head_dim)
            Bm = xBC_c[..., di:di + N]
            Cm = xBC_c[..., di + N:]
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                                 + lp["mamba"]["dt_bias"])
            A = -jnp.exp(lp["mamba"]["A_log"])
            y, state = ssm_mod.ssd_chunked(xs, dt, A, Bm, Cm, ms.ssm.chunk,
                                           use_kernel=use_kernel)
            y = y + xs * lp["mamba"]["D"].astype(h.dtype)[None, None, :, None]
            y = y.reshape(B, S, di)
            y = L.rmsnorm(lp["mamba"]["norm"], y * jax.nn.silu(z), cfg.norm_eps)
            out = h + y @ lp["mamba"]["out_proj"]
            return out, {"conv": conv_state, "ssd": state}
        # attention layer: compute forward and fill cache
        s = attn_spec(cfg, window)
        out = attn_mod.attention(lp["attn"], s, h, positions, cfg.norm_eps)
        hh = L.rmsnorm(lp["attn"]["ln"], h, cfg.norm_eps)
        _, k, v = attn_mod._project_qkv(lp["attn"], s, hh, positions)
        C = min(max_len, s.window) if s.window else max_len
        ck = jnp.zeros((B, C, s.kv_heads, s.head_dim), dtype)
        cv = jnp.zeros_like(ck)
        kpos = jnp.full((B, C), -1, jnp.int32)
        take = min(S, C)
        src_pos = jnp.arange(S - take, S)
        slots = src_pos % C
        ck = ck.at[:, slots].set(k[:, S - take:])
        cv = cv.at[:, slots].set(v[:, S - take:])
        kpos = kpos.at[:, slots].set(jnp.broadcast_to(src_pos[None], (B, take)))
        if cfg.kv_cache_quant:                     # §Perf HC5
            ckq, ks = attn_mod.quant_rows(ck)
            cvq, vs = attn_mod.quant_rows(cv)
            return out, {"k": ckq, "v": cvq, "kscale": ks, "vscale": vs,
                         "kpos": kpos}
        return out, {"k": ck, "v": cv, "kpos": kpos}

    def unit_body(carry, up):
        h, a = carry
        caches = {}
        for i in range(cfg.unit_layers):
            lp = up[f"pos{i}"]
            if "mamba" in lp:
                h, c = prefill_layer(lp, h, None, i)
            else:
                h, c = prefill_layer(lp, h, _window_at(cfg, i), i)
                if "cross" in lp and enc_out is not None:
                    h = attn_mod.cross_attention(
                        lp["cross"], attn_spec(cfg, None, False), h, enc_out,
                        eps=cfg.norm_eps)
                if "moe" in lp:
                    h, aa = moe_mod.moe_block(lp["moe"], moe_spec(cfg), h,
                                              cfg.norm_eps)
                    a = a + aa
                else:
                    h = L.mlp(lp["mlp"], h, cfg.norm_eps)
            caches[f"pos{i}"] = c
        if shared is not None:
            h2, c = prefill_layer({"attn": shared["attn"]}, h, None, 0)
            h = L.mlp(shared["mlp"], h2, cfg.norm_eps)
            caches["shared"] = c
        return (h, a), caches

    (x, aux), unit_caches = _scan_units(unit_body, (x, aux), params["units"],
                                        unroll=unroll)

    caches: dict[str, Any] = {"units": unit_caches}
    _, rem = _unit_count(cfg)
    if rem:
        rem_caches = []
        for i in range(rem):
            up = _tree_at(params["rem"], i)
            lp = up["pos0"]
            x, c = prefill_layer(lp, x, _window_at(cfg, i), i)
            if "mamba" not in lp:
                if "moe" in lp:
                    x, aa = moe_mod.moe_block(lp["moe"], moe_spec(cfg), x,
                                              cfg.norm_eps)
                    aux = aux + aa
                else:
                    x = L.mlp(lp["mlp"], x, cfg.norm_eps)
            rem_caches.append({"pos0": c})
        caches["rem"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *rem_caches)
    if enc_out is not None:
        caches["enc_out"] = enc_out

    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    last = x[:, -1:]
    logits = (L.unembed(params["embed"], last) if cfg.tie_embeddings
              else L.linear(params["unembed"], last))
    return _mask_pad_vocab(cfg, logits), caches


# -- hybrid attention layer bug guard: mamba layers ignore window ----------------


def flops_estimate(cfg: ModelConfig, batch: int, seq: int,
                   kind: str = "train") -> float:
    """Analytic model FLOPs (fwd; x3 for train fwd+bwd) for the roofline's
    MODEL_FLOPS / HLO_FLOPS utilization ratio."""
    tokens = batch * seq
    total = 0.0
    for i in range(cfg.num_layers):
        if cfg.family in ("ssm", "hybrid"):
            total += ssm_mod.mamba_flops(mamba_spec(cfg), tokens)
        else:
            s = attn_spec(cfg, _window_at(cfg, i))
            kv_len = seq if kind != "decode" else seq
            total += attn_mod.attn_flops(s, tokens, kv_len)
            if cfg.moe:
                total += moe_mod.moe_flops(moe_spec(cfg), tokens)
            else:
                total += L.mlp_flops(cfg.d_model, cfg.d_ff, cfg.gated_mlp, tokens)
    if cfg.family == "hybrid":
        n_units = cfg.num_layers // cfg.hybrid_unit
        s = attn_spec(cfg, None)
        total += n_units * (attn_mod.attn_flops(s, tokens, seq)
                            + L.mlp_flops(cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                                          tokens))
    if cfg.encoder_layers:
        etok = batch * cfg.num_prefix_embeds
        s = attn_spec(cfg, None)
        total += cfg.encoder_layers * (
            attn_mod.attn_flops(s, etok, cfg.num_prefix_embeds)
            + L.mlp_flops(cfg.d_model, cfg.d_ff, cfg.gated_mlp, etok))
        total += cfg.num_layers * attn_mod.attn_flops(s, tokens,
                                                      cfg.num_prefix_embeds)
    total += 2.0 * tokens * cfg.d_model * cfg.vocab   # unembed
    if kind == "train":
        total *= 3.0
    return total
