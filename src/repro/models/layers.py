"""Primitive layers: linear / norm / embedding / RoPE / MLP.

Params are plain dict pytrees; every layer is an ``init_*`` returning params
and a pure ``apply`` function.  Initializers take explicit keys so model init
is fully deterministic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * np.sqrt(2.0 / fan_in)).astype(dtype)


# -- linear -----------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype) -> dict:
    return {"w": he_init(key, (d_in, d_out), dtype)}


def linear(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["w"]


# -- norms --------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(dt)


# -- embedding ----------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
                      ).astype(dtype)}


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["table"].T


# -- RoPE ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                           # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    angles = angles[..., None, :]                           # [..., S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- MLP -------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"ln": init_rmsnorm(d, dtype),
         "up": init_linear(ks[0], d, f, dtype),
         "down": init_linear(ks[1], f, d, dtype)}
    if gated:
        p["gate"] = init_linear(ks[2], d, f, dtype)
    return p


def mlp(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    h = rmsnorm(p["ln"], x, eps)
    up = linear(p["up"], h)
    if "gate" in p:
        up = jax.nn.silu(linear(p["gate"], h)) * up
    else:
        up = jax.nn.gelu(up)
    return x + linear(p["down"], up)


def mlp_flops(d: int, f: int, gated: bool, tokens: int) -> float:
    mats = 3 if gated else 2
    return 2.0 * mats * d * f * tokens
