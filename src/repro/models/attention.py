"""Attention: GQA with RoPE, chunked (memory-bounded) causal attention,
banded sliding-window attention, cross-attention, and cached decode.

Shapes: x [B, S, d]; K/V heads ``kv``; query heads ``H = g * kv``.
Caches: K,V as [B, C, kv, hd] where C = full seq for global layers or the
window size (ring buffer) for sliding-window layers.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, init_linear, init_rmsnorm, linear, rmsnorm

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    num_heads: int
    kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    window: int | None = None        # sliding window (tokens), None = global
    causal: bool = True
    q_chunk: int = 1024              # chunking for memory-bounded attention


def init_attn(key, s: AttnSpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "ln": init_rmsnorm(s.d_model, dtype),
        "wq": init_linear(ks[0], s.d_model, s.num_heads * s.head_dim, dtype),
        "wk": init_linear(ks[1], s.d_model, s.kv_heads * s.head_dim, dtype),
        "wv": init_linear(ks[2], s.d_model, s.kv_heads * s.head_dim, dtype),
        "wo": init_linear(ks[3], s.num_heads * s.head_dim, s.d_model, dtype),
    }


def _project_qkv(p, s: AttnSpec, x, positions):
    B, S, _ = x.shape
    q = linear(p["wq"], x).reshape(B, S, s.num_heads, s.head_dim)
    k = linear(p["wk"], x).reshape(B, S, s.kv_heads, s.head_dim)
    v = linear(p["wv"], x).reshape(B, S, s.kv_heads, s.head_dim)
    q = apply_rope(q, positions, s.rope_theta)
    k = apply_rope(k, positions, s.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q [B,Cq,H,hd], k/v [B,Ck,kv,hd] (GQA broadcast), mask [B?,Cq,Ck]."""
    B, Cq, H, hd = q.shape
    kv = k.shape[2]
    g = H // kv
    qg = q.reshape(B, Cq, kv, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Cq, H, hd)


def attention(p: dict, s: AttnSpec, x: jax.Array, positions: jax.Array,
              eps: float = 1e-5, kv_override=None) -> jax.Array:
    """Full-sequence attention (train / prefill), memory-bounded.

    Chunks queries with ``lax.scan`` so live logits are [B,H,Cq,S] not
    [B,H,S,S]; sliding-window layers use a banded gather so their FLOPs and
    memory scale with S * window, not S^2.
    """
    B, S, _ = x.shape
    h = rmsnorm(p["ln"], x, eps)
    q, k, v = _project_qkv(p, s, h, positions)
    scale = 1.0 / np.sqrt(s.head_dim)

    C = min(s.q_chunk, S)
    if S % C != 0:  # small/smoke shapes: single chunk
        C = S
    nq = S // C
    qs = q.reshape(B, nq, C, s.num_heads, s.head_dim)
    pos_q = positions.reshape(B, nq, C) if positions.ndim == 2 else \
        jnp.broadcast_to(positions.reshape(nq, C)[None], (B, nq, C))

    if s.window is not None and s.window < S:
        out = _banded_attention(qs, k, v, pos_q, positions, s, scale, C)
    else:
        out = _chunked_attention(qs, k, v, pos_q, positions, s, scale, C)
    out = out.reshape(B, S, s.num_heads * s.head_dim)
    return x + linear(p["wo"], out)


def _chunked_attention(qs, k, v, pos_q, pos_k, s, scale, C):
    """scan over query chunks; each sees the full K (causal-masked)."""
    B = qs.shape[0]
    if pos_k.ndim == 1:
        pos_k = jnp.broadcast_to(pos_k[None], (B, pos_k.shape[0]))

    def body(_, inp):
        qc, pq = inp                       # [B,C,H,hd], [B,C]
        mask = jnp.ones((B, C, pos_k.shape[1]), bool)
        if s.causal:
            mask = pq[:, :, None] >= pos_k[:, None, :]
        return None, _sdpa(qc, k, v, mask, scale)

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(pos_q, 1, 0)))
    return jnp.moveaxis(outs, 0, 1)        # [B,nq,C,H,hd]


def _banded_attention(qs, k, v, pos_q, pos_k, s, scale, C):
    """Sliding window: q chunk i attends only to k chunks [i-nb+1 .. i].

    nb = ceil(window/C) + 1 chunks; FLOPs ~ S * (nb*C) instead of S^2.
    """
    B, nq, _, H, hd = qs.shape
    S = k.shape[1]
    nb = int(np.ceil(s.window / C)) + 1
    kc = k.reshape(B, nq, C, s.kv_heads, hd)
    vc = v.reshape(B, nq, C, s.kv_heads, hd)
    pos_kc = (pos_k if pos_k.ndim == 2 else jnp.broadcast_to(pos_k[None], (B, S))
              ).reshape(B, nq, C)

    idx = jnp.arange(nq)[:, None] - jnp.arange(nb - 1, -1, -1)[None, :]  # [nq,nb]
    valid_chunk = idx >= 0
    idx = jnp.clip(idx, 0, nq - 1)

    def body(_, inp):
        qc, pq, band_idx, bvalid = inp
        kb = kc[:, band_idx].reshape(B, nb * C, s.kv_heads, hd)
        vb = vc[:, band_idx].reshape(B, nb * C, s.kv_heads, hd)
        pb = pos_kc[:, band_idx].reshape(B, nb * C)
        delta = pq[:, :, None] - pb[:, None, :]
        mask = (delta >= 0) & (delta < s.window)
        mask &= jnp.repeat(bvalid, C)[None, None, :]
        return None, _sdpa(qc, kb, vb, mask, scale)

    _, outs = jax.lax.scan(
        body, None,
        (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(pos_q, 1, 0), idx, valid_chunk),
    )
    return jnp.moveaxis(outs, 0, 1)


# -- cross attention (enc-dec) --------------------------------------------------

def init_cross_attn(key, s: AttnSpec, dtype) -> dict:
    return init_attn(key, s, dtype)


def cross_attention(p: dict, s: AttnSpec, x: jax.Array, enc: jax.Array,
                    enc_mask: jax.Array | None = None, eps: float = 1e-5):
    B, S, _ = x.shape
    Se = enc.shape[1]
    h = rmsnorm(p["ln"], x, eps)
    q = linear(p["wq"], h).reshape(B, S, s.num_heads, s.head_dim)
    k = linear(p["wk"], enc).reshape(B, Se, s.kv_heads, s.head_dim)
    v = linear(p["wv"], enc).reshape(B, Se, s.kv_heads, s.head_dim)
    mask = jnp.ones((B, S, Se), bool) if enc_mask is None else \
        jnp.broadcast_to(enc_mask[:, None, :], (B, S, Se))
    out = _sdpa(q, k, v, mask, 1.0 / np.sqrt(s.head_dim))
    return x + linear(p["wo"], out.reshape(B, S, -1))


# -- cached decode ----------------------------------------------------------------

def init_cache(s: AttnSpec, batch: int, max_len: int, dtype,
               quant: bool = False) -> dict:
    """KV cache.  ``quant=True`` stores int8 values with one f32 scale per
    (position, kv head) row — §Perf HC5: halves cache residency and HBM
    reads per decoded token (the ZFP fixed-rate idea applied to the cache).
    """
    C = min(max_len, s.window) if s.window else max_len
    if quant:
        return {
            "k": jnp.zeros((batch, C, s.kv_heads, s.head_dim), jnp.int8),
            "v": jnp.zeros((batch, C, s.kv_heads, s.head_dim), jnp.int8),
            "kscale": jnp.zeros((batch, C, s.kv_heads), jnp.float32),
            "vscale": jnp.zeros((batch, C, s.kv_heads), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, C, s.kv_heads, s.head_dim), dtype),
        "v": jnp.zeros((batch, C, s.kv_heads, s.head_dim), dtype),
    }


def quant_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [..., hd] -> (int8 [..., hd], scale [...]) with per-row absmax."""
    absmax = jnp.abs(x.astype(jnp.float32)).max(axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequant_rows(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def decode_attention_ref(q, cache_k, cache_v, kpos, pos, window, scale):
    """Single-token attention over a cache. q [B,1,H,hd]; cache [B,C,kv,hd];
    kpos [B,C] absolute positions stored in each cache slot (-1 = empty)."""
    delta = pos[:, None] - kpos                         # [B,C]
    valid = (kpos >= 0) & (delta >= 0)
    if window is not None:
        valid &= delta < window
    return _sdpa(q, cache_k, cache_v, valid[:, None, :], scale)


def attention_decode(p: dict, s: AttnSpec, x: jax.Array, pos: jax.Array,
                     cache: dict, kpos: jax.Array, eps: float = 1e-5,
                     use_kernel: bool = False):
    """One decode step.  x [B,1,d]; pos [B] absolute position; kpos [B,C].

    Returns (out, new_cache, new_kpos).  Sliding-window caches are ring
    buffers indexed by pos % window.
    """
    B = x.shape[0]
    h = rmsnorm(p["ln"], x, eps)
    q = linear(p["wq"], h).reshape(B, 1, s.num_heads, s.head_dim)
    k = linear(p["wk"], h).reshape(B, 1, s.kv_heads, s.head_dim)
    v = linear(p["wv"], h).reshape(B, 1, s.kv_heads, s.head_dim)
    q = apply_rope(q, pos[:, None], s.rope_theta)
    k = apply_rope(k, pos[:, None], s.rope_theta)

    C = cache["k"].shape[1]
    slot = (pos % C).astype(jnp.int32)                 # ring for window layers
    bidx = jnp.arange(B)
    nkpos = kpos.at[bidx, slot].set(pos)
    quant = cache["k"].dtype == jnp.int8
    new_cache: dict
    if quant:
        kq, ks = quant_rows(k[:, 0])
        vq, vs = quant_rows(v[:, 0])
        ck = cache["k"].at[bidx, slot].set(kq)
        cv = cache["v"].at[bidx, slot].set(vq)
        kss = cache["kscale"].at[bidx, slot].set(ks)
        vss = cache["vscale"].at[bidx, slot].set(vs)
        new_cache = {"k": ck, "v": cv, "kscale": kss, "vscale": vss}
        ck_f = dequant_rows(ck, kss, x.dtype)
        cv_f = dequant_rows(cv, vss, x.dtype)
    else:
        ck_f = ck = cache["k"].at[bidx, slot].set(k[:, 0])
        cv_f = cv = cache["v"].at[bidx, slot].set(v[:, 0])
        new_cache = {"k": ck, "v": cv}

    scale = 1.0 / np.sqrt(s.head_dim)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, ck_f, cv_f, nkpos, pos, s.window, scale)
    else:
        out = decode_attention_ref(q, ck_f, cv_f, nkpos, pos, s.window, scale)
    out = x + linear(p["wo"], out.reshape(B, 1, -1))
    return out, new_cache, nkpos


def attn_flops(s: AttnSpec, tokens: int, kv_len: int) -> float:
    proj = 2.0 * tokens * s.d_model * (s.num_heads + 2 * s.kv_heads + s.num_heads) \
        * s.head_dim
    eff_kv = min(kv_len, s.window) if s.window else kv_len
    attn = 4.0 * tokens * eff_kv * s.num_heads * s.head_dim
    return proj + attn
