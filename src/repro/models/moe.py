"""Mixture-of-Experts block: top-k router + capacity-based dispatch.

Two interchangeable dispatch implementations (numerically identical where
no tokens are dropped; tested):

* ``moe_block`` (default) — global-view scatter/gather dispatch in plain jnp;
  GSPMD infers the collectives from the expert-sharded weights.  This is the
  *baseline* path used in the 40-pair dry-run.
* ``moe_block_a2a`` — explicit per-device dispatch with ``jax.lax.all_to_all``
  under ``shard_map`` (GShard-style).  The optimized path for the hillclimb;
  see repro/distributed.py for the wrapper that binds it to a mesh.

Routing: softmax router, top-k, gates renormalized over the chosen k,
GShard dropping at capacity C = ceil(T * k / E * capacity_factor), and the
standard load-balance auxiliary loss  aux = E * sum_e f_e * p_e.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import he_init, init_rmsnorm, rmsnorm


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int
    gated: bool
    moe: MoEConfig


def init_moe(key, s: MoESpec, dtype) -> dict:
    ks = jax.random.split(key, 4)
    E, d, f = s.moe.num_experts, s.d_model, s.d_ff
    p = {
        "ln": init_rmsnorm(d, dtype),
        "router": he_init(ks[0], (d, E), jnp.float32),
        "up": he_init(ks[1], (E, d, f), dtype, fan_in=d),
        "down": he_init(ks[2], (E, f, d), dtype, fan_in=f),
    }
    if s.gated:
        p["gate"] = he_init(ks[3], (E, d, f), dtype, fan_in=d)
    return p


def moe_param_count(s: MoESpec) -> int:
    E, d, f = s.moe.num_experts, s.d_model, s.d_ff
    return d + d * E + (3 if s.gated else 2) * E * d * f


def _route(p, s: MoESpec, h_flat):
    """h_flat [T, d] -> (expert_idx [T,k], gates [T,k], aux_loss scalar)."""
    logits = (h_flat.astype(jnp.float32) @ p["router"])           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, s.moe.top_k)                # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch/GShard): E * sum_e mean(frac routed) * mean(prob)
    E = s.moe.num_experts
    onehot = jax.nn.one_hot(idx[:, 0], E)                          # top-1 frac
    aux = E * jnp.mean(onehot.mean(0) * probs.mean(0)) * E
    return idx, gates.astype(h_flat.dtype), aux


def _capacity(T: int, s: MoESpec) -> int:
    c = int(np.ceil(T * s.moe.top_k / s.moe.num_experts * s.moe.capacity_factor))
    return max(4, ((c + 3) // 4) * 4)


def dispatch_indices(idx, E: int, C: int):
    """Slot positions via per-expert running count.  idx [T, k] ->
    (flat_expert [T*k], pos [T*k], keep [T*k])."""
    T, k = idx.shape
    flat = idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)              # [T*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                      # [T*k, E]
    pos = jnp.take_along_axis(pos_in_e, flat[:, None], axis=1)[:, 0]
    keep = pos < C
    return flat, pos, keep


def _expert_ffn(p, s: MoESpec, buf):
    """buf [E, C, d] -> [E, C, d], dense per-expert einsums (MXU-friendly)."""
    up = jnp.einsum("ecd,edf->ecf", buf, p["up"])
    if s.gated:
        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * up
    else:
        up = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", up, p["down"])


def moe_block(p: dict, s: MoESpec, x: jax.Array, eps: float = 1e-5):
    """x [B,S,d] -> ([B,S,d], aux_loss).

    Dispatch is global-view by default; with ``moe.token_shards = D`` the
    capacity buffers are built per data shard (§Perf HC2) so the scatter is
    shard-local and the cross-device exchange is an all-to-all of routed
    tokens, not an all-reduce of the whole expert buffer.
    """
    if s.moe.token_shards > 1:
        return _moe_block_sharded(p, s, x, eps, s.moe.token_shards)
    B, S, d = x.shape
    T = B * S
    h = rmsnorm(p["ln"], x, eps).reshape(T, d)
    idx, gates, aux = _route(p, s, h)
    E, k = s.moe.num_experts, s.moe.top_k
    C = _capacity(T, s)

    flat, pos, keep = dispatch_indices(idx, E, C)
    pos = jnp.where(keep, pos, C - 1)
    src = jnp.repeat(h, k, axis=0) * keep[:, None].astype(h.dtype)  # [T*k, d]
    buf = jnp.zeros((E, C, d), h.dtype).at[flat, pos].add(src)

    out_buf = _expert_ffn(p, s, buf)                                # [E, C, d]

    slots = out_buf[flat, pos] * keep[:, None].astype(h.dtype)      # [T*k, d]
    y = (slots.reshape(T, k, d) * gates[:, :, None]).sum(axis=1)
    return x + y.reshape(B, S, d), aux


def _shard_hint(t: jax.Array, spec):
    """Best-effort sharding constraint (no-op without a mesh context)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(t, P(*spec))
    except Exception:
        return t


def _moe_block_sharded(p: dict, s: MoESpec, x: jax.Array, eps: float,
                       D: int):
    """Per-data-shard dispatch: buf [D, E, C/D, d], scatter local to each
    shard's tokens; the (data x model) exchange of routed tokens is left to
    GSPMD as an all-to-all.  Numerically == global dispatch when no shard
    overflows its local capacity (C_l = C/D x the same capacity factor)."""
    B, S, d = x.shape
    T = B * S
    E, k = s.moe.num_experts, s.moe.top_k
    h = rmsnorm(p["ln"], x, eps).reshape(T, d)
    idx, gates, aux = _route(p, s, h)
    T_l = T // D
    C_l = _capacity(T_l, s)

    idx_s = idx.reshape(D, T_l, k)
    flat, pos, keep = jax.vmap(lambda ix: dispatch_indices(ix, E, C_l))(idx_s)
    pos = jnp.where(keep, pos, C_l - 1)
    src = jnp.repeat(h.reshape(D, T_l, d), k, axis=1) \
        * keep[..., None].astype(h.dtype)                # [D, T_l*k, d]
    buf = jnp.zeros((D, E, C_l, d), h.dtype)
    buf = _shard_hint(buf, ("data", "model", None, None))
    didx = jnp.arange(D)[:, None]
    buf = buf.at[didx, flat, pos].add(src)               # local scatter
    buf = _shard_hint(buf, ("data", "model", None, None))

    up = jnp.einsum("xecd,edf->xecf", buf, p["up"])
    if s.gated:
        up = jax.nn.silu(jnp.einsum("xecd,edf->xecf", buf, p["gate"])) * up
    else:
        up = jax.nn.gelu(up)
    out_buf = jnp.einsum("xecf,efd->xecd", up, p["down"])
    out_buf = _shard_hint(out_buf, ("data", "model", None, None))

    slots = out_buf[didx, flat, pos] * keep[..., None].astype(h.dtype)
    y = (slots.reshape(D, T_l, k, d)
         * gates.reshape(D, T_l, k)[..., None]).sum(axis=2)
    return x + y.reshape(B, S, d), aux


# -- explicit all-to-all variant (optimized path; used under shard_map) --------

def moe_block_local(p: dict, s: MoESpec, x_l: jax.Array, axis_name: str,
                    eps: float = 1e-5):
    """Per-device body for shard_map: x_l [B_l, S_l, d]; experts sharded on
    ``axis_name`` (p['up'] etc. have leading dim E_l = E / axis_size).

    dispatch locally -> all_to_all tokens to expert owners -> dense expert
    FFN on local experts -> all_to_all back -> combine.
    """
    from repro.sharding import axis_size
    ax = axis_size(axis_name)
    B_l, S_l, d = x_l.shape
    T_l = B_l * S_l
    E = s.moe.num_experts
    E_l = E // ax
    h = rmsnorm(p["ln"], x_l, eps).reshape(T_l, d)
    # router weights are replicated across the expert axis
    idx, gates, aux = _route(p, s, h)
    C = _capacity(T_l, s)

    flat, pos, keep = dispatch_indices(idx, E, C)
    pos = jnp.where(keep, pos, C - 1)
    src = jnp.repeat(h, s.moe.top_k, axis=0) * keep[:, None].astype(h.dtype)
    buf = jnp.zeros((E, C, d), h.dtype).at[flat, pos].add(src)      # [E, C, d]

    # exchange: every device sends its [E_l-slice, C] block to the owner
    buf = buf.reshape(ax, E_l, C, d)
    recv = jax.lax.all_to_all(buf, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)                          # [ax, E_l, C, d]
    recv = jnp.moveaxis(recv, 0, 1).reshape(E_l, ax * C, d)

    out = _expert_ffn(p, s, recv)                                   # [E_l, ax*C, d]

    out = jnp.moveaxis(out.reshape(E_l, ax, C, d), 1, 0)            # [ax, E_l, C, d]
    back = jax.lax.all_to_all(out, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    back = back.reshape(E, C, d)

    slots = back[flat, pos] * keep[:, None].astype(h.dtype)
    y = (slots.reshape(T_l, s.moe.top_k, d) * gates[:, :, None]).sum(axis=1)
    return x_l + y.reshape(B_l, S_l, d), aux


def moe_flops(s: MoESpec, tokens: int) -> float:
    mats = 3 if s.gated else 2
    active = 2.0 * mats * s.d_model * s.d_ff * s.moe.top_k
    router = 2.0 * s.d_model * s.moe.num_experts
    return tokens * (active * s.moe.capacity_factor + router)
