"""The paper's own evaluation models — VGG16 / VGG19 / ResNet50 — as
:class:`LayerGraph`s (NHWC, inference mode, BN folded to scale/bias form).

These are the models DEFER partitions in Figs 2-3 / Tables I-II; building
them as layer graphs gives the partitioner exactly what the Keras DAG gave
the original: per-layer params, output shapes (=> inter-node payloads) and
FLOPs.  ResNet50 keeps its residual branches as explicit ``add`` nodes, so
cuts inside a bottleneck transfer BOTH crossing activations — the same wire
cost the paper's chunked-socket transfer would pay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import LayerGraph

F32 = jnp.float32


def _sds(shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


# -- layer apply fns (params, *inputs) -> output ---------------------------------

def conv_apply(p, x, *, stride, padding):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def conv_bn_relu_apply(p, x, *, stride, padding, relu=True):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y * p["scale"] + p["bias"]            # folded inference BN
    return jax.nn.relu(y) if relu else y


def relu_apply(p, x):
    return jax.nn.relu(x)


def add_relu_apply(p, a, b):
    return jax.nn.relu(a + b)


def maxpool_apply(p, x, *, size, stride):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, stride, stride, 1),
        "SAME" if size == 3 else "VALID")


def gap_apply(p, x):
    return x.mean(axis=(1, 2))


def flatten_apply(p, x):
    return x.reshape(x.shape[0], -1)


def fc_apply(p, x, *, relu):
    y = x @ p["w"] + p["b"]
    return jax.nn.relu(y) if relu else y


# -- cost helpers ------------------------------------------------------------------

def conv_flops(out_shape, k, cin):
    n = int(np.prod(out_shape))
    return 2.0 * n * k * k * cin


def fc_flops(batch, din, dout):
    return 2.0 * batch * din * dout


# -- VGG ---------------------------------------------------------------------------

_VGG16_PLAN = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
               512, 512, 512, "M", 512, 512, 512, "M"]
_VGG19_PLAN = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
               512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]


def _build_vgg(name: str, plan, batch: int = 1, image: int = 224,
               num_classes: int = 1000) -> LayerGraph:
    g = LayerGraph(name, _sds((batch, image, image, 3)))
    h, w, cin = image, image, 3
    prev = ""
    ci = 0
    for item in plan:
        if item == "M":
            h //= 2
            w //= 2
            nname = f"pool{ci}"
            g.layer(nname, functools.partial(maxpool_apply, size=2, stride=2),
                    {}, (prev,), _sds((batch, h, w, cin)), flops=0.0)
        else:
            cout = item
            nname = f"conv{ci}"
            spec = {"w": _sds((3, 3, cin, cout)), "b": _sds((cout,))}
            g.layer(nname, functools.partial(conv_apply, stride=1, padding="SAME"),
                    spec, (prev,), _sds((batch, h, w, cout)),
                    flops=conv_flops((batch, h, w, cout), 3, cin))
            # relu fused into a separate cheap node keeps layer-wise cuts
            g.layer(f"relu{ci}", relu_apply, {}, (nname,),
                    _sds((batch, h, w, cout)), flops=0.0)
            nname = f"relu{ci}"
            cin = cout
        prev = nname
        ci += 1
    g.layer("flatten", flatten_apply, {}, (prev,),
            _sds((batch, h * w * cin)), flops=0.0)
    dims = [h * w * cin, 4096, 4096, num_classes]
    prev = "flatten"
    for i in range(3):
        spec = {"w": _sds((dims[i], dims[i + 1])), "b": _sds((dims[i + 1],))}
        g.layer(f"fc{i}", functools.partial(fc_apply, relu=i < 2), spec, (prev,),
                _sds((batch, dims[i + 1])), flops=fc_flops(batch, dims[i], dims[i + 1]))
        prev = f"fc{i}"
    return g


def vgg16(batch: int = 1) -> LayerGraph:
    return _build_vgg("vgg16", _VGG16_PLAN, batch)


def vgg19(batch: int = 1) -> LayerGraph:
    return _build_vgg("vgg19", _VGG19_PLAN, batch)


# -- ResNet50 ------------------------------------------------------------------------

_R50_STAGES = [(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2),
               (3, 512, 2048, 2)]


def resnet50(batch: int = 1, image: int = 224, num_classes: int = 1000
             ) -> LayerGraph:
    g = LayerGraph("resnet50", _sds((batch, image, image, 3)))
    h = w = image // 2
    # stem
    spec = {"w": _sds((7, 7, 3, 64)), "scale": _sds((64,)), "bias": _sds((64,))}
    g.layer("stem", functools.partial(conv_bn_relu_apply, stride=2, padding="SAME"),
            spec, ("",), _sds((batch, h, w, 64)),
            flops=conv_flops((batch, h, w, 64), 7, 3))
    h //= 2
    w //= 2
    g.layer("stem_pool", functools.partial(maxpool_apply, size=3, stride=2),
            {}, ("stem",), _sds((batch, h, w, 64)), flops=0.0)
    prev, cin = "stem_pool", 64

    def bn_conv(name, inp, k, cout, stride, relu, hh, ww, ci):
        spec = {"w": _sds((k, k, ci, cout)), "scale": _sds((cout,)),
                "bias": _sds((cout,))}
        g.layer(name,
                functools.partial(conv_bn_relu_apply, stride=stride,
                                  padding="SAME", relu=relu),
                spec, (inp,), _sds((batch, hh, ww, cout)),
                flops=conv_flops((batch, hh, ww, cout), k, ci))
        return name

    for si, (blocks, cmid, cout, stride0) in enumerate(_R50_STAGES):
        for bi in range(blocks):
            stride = stride0 if bi == 0 else 1
            hh, ww = h // stride, w // stride
            base = f"s{si}b{bi}"
            a = bn_conv(f"{base}_c1", prev, 1, cmid, 1, True, h, w, cin)
            b = bn_conv(f"{base}_c2", a, 3, cmid, stride, True, hh, ww, cmid)
            c = bn_conv(f"{base}_c3", b, 1, cout, 1, False, hh, ww, cmid)
            if bi == 0:
                sc = bn_conv(f"{base}_sc", prev, 1, cout, stride, False, hh, ww, cin)
            else:
                sc = prev
            g.layer(f"{base}_add", add_relu_apply, {}, (c, sc),
                    _sds((batch, hh, ww, cout)), flops=0.0)
            prev, cin, h, w = f"{base}_add", cout, hh, ww
    g.layer("gap", gap_apply, {}, (prev,), _sds((batch, cin)), flops=0.0)
    g.layer("fc", functools.partial(fc_apply, relu=False),
            {"w": _sds((cin, num_classes)), "b": _sds((num_classes,))},
            ("gap",), _sds((batch, num_classes)),
            flops=fc_flops(batch, cin, num_classes))
    return g


BUILDERS = {"resnet50": resnet50, "vgg16": vgg16, "vgg19": vgg19}
