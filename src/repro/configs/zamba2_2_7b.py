"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.

[arXiv:2411.15242].  54 Mamba2 layers in 9 units of 6; after each unit the
single SHARED (weight-tied) attention+MLP block runs.  ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig, reduced

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    gated_mlp=True,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
    hybrid_unit=6,
    tie_embeddings=True,
    source="arXiv:2411.15242",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
