"""Model / run configuration system.

``ModelConfig`` is the single source of truth a model builder consumes.  One
file per assigned architecture lives next to this module; each exports
``CONFIG`` (the exact assigned full-size config, citation in ``source``) and
``smoke_config()`` (a reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # §Perf HC2: >1 = build per-data-shard capacity buffers so the dispatch
    # scatter stays shard-local (all-to-all of routed tokens instead of an
    # all-reduce of the full expert buffer over the data axis).
    token_shards: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int            # N (d_state)
    head_dim: int = 64        # P
    expand: int = 2           # d_inner = expand * d_model
    chunk: int = 256          # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int            # query heads (0 for attention-free)
    kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""

    head_dim: int | None = None          # default d_model // num_heads
    gated_mlp: bool = True               # SwiGLU (3 mats) vs classic (2 mats)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # sliding-window pattern: window size per layer-position within the
    # repeating unit; None = full attention.  gemma3: (1024,)*5 + (None,)
    window_pattern: tuple[int | None, ...] = (None,)

    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None

    # hybrid (zamba2-style): within a repeating unit of `unit` layers, the
    # last one is followed by the SHARED attention block
    hybrid_unit: int = 0                 # 0 = not hybrid

    # encoder-decoder (seamless-style)
    encoder_layers: int = 0              # 0 = decoder-only

    # multimodal stub frontends (per assignment: embeddings provided)
    num_prefix_embeds: int = 0           # image patches / audio frames per sample

    # training
    tie_embeddings: bool = True
    remat: bool = True
    remat_policy: str = "full"        # "full" | "dots" (save matmul outputs)

    # §Perf: pad the vocab so embedding/unembedding shard over the tensor
    # axis (a non-divisible vocab forces REPLICATED f32 logits — seamless'
    # 256206 cost 67 GB/device of logits alone).  0 = no padding.
    vocab_pad_multiple: int = 0

    # §Perf HC5: store KV caches as int8 + per-row f32 scale (the ZFP
    # fixed-rate idea applied to cache residency): ~2x less HBM held and
    # read per decoded token, bounded dequantization error.
    kv_cache_quant: bool = False

    def __post_init__(self):
        if self.num_heads:
            object.__setattr__(
                self, "head_dim", self.head_dim or self.d_model // self.num_heads
            )

    # ---- derived sizes -----------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        if not self.vocab_pad_multiple:
            return self.vocab
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def unit_layers(self) -> int:
        """Length of the repeating (scannable) layer unit."""
        if self.hybrid_unit:
            return self.hybrid_unit
        return len(self.window_pattern) if len(self.window_pattern) > 1 else 1

    @property
    def attn_q_dim(self) -> int:
        return self.num_heads * (self.head_dim or 0)

    @property
    def attn_kv_dim(self) -> int:
        return self.kv_heads * (self.head_dim or 0)

    def param_count(self) -> int:
        """Analytic parameter count (matches the built model; tested)."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        total = self.padded_vocab * d                # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        dec_layers = L
        enc_layers = self.encoder_layers
        per_attn = d * self.attn_q_dim + 2 * d * self.attn_kv_dim \
            + self.attn_q_dim * d + d              # q,k,v,o + ln
        per_mlp = (3 if self.gated_mlp else 2) * d * f + d
        if self.moe:
            per_mlp = self.moe.num_experts * (3 if self.gated_mlp else 2) * d * f \
                + d * self.moe.num_experts + d       # experts + router + ln
        if self.family in ("ssm",):
            per_layer = self._mamba_params() + d
            total += dec_layers * per_layer
        elif self.family == "hybrid":
            n_units = dec_layers // self.hybrid_unit
            total += dec_layers * (self._mamba_params() + d)
            total += per_attn + per_mlp              # one SHARED attn block
            del n_units
        else:
            total += dec_layers * (per_attn + per_mlp)
            total += enc_layers * (per_attn + per_mlp)
            if enc_layers:                           # cross-attention in decoder
                total += dec_layers * per_attn
        total += d                                   # final norm
        return total

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        d = self.d_model
        s = self.ssm
        d_inner = s.expand * d
        n_heads = d_inner // s.head_dim
        n_groups = 1
        in_proj = d * (2 * d_inner + 2 * n_groups * s.state_dim + n_heads)
        ch = d_inner + 2 * n_groups * s.state_dim
        conv = s.conv_width * ch + ch                # depthwise weight + bias
        out_proj = d_inner * d
        extras = 3 * n_heads + d_inner               # A_log, dt_bias, D + norm
        return in_proj + conv + out_proj + extras

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.gated_mlp else 2) * d * f
        inactive = self.num_layers * (self.moe.num_experts - self.moe.top_k) * per_expert
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, same family."""
    small: dict = dict(
        num_layers=2,
        d_model=256,
        num_heads=4,
        kv_heads=min(cfg.kv_heads, 4) if cfg.kv_heads else 0,
        d_ff=512 if cfg.d_ff else 0,
        vocab=512,
        head_dim=64 if cfg.num_heads else None,
    )
    if cfg.moe:
        small["moe"] = MoEConfig(num_experts=4, top_k=min(cfg.moe.top_k, 2),
                                 capacity_factor=2.0)
    if cfg.ssm:
        small["ssm"] = SSMConfig(state_dim=16, head_dim=32, expand=2, chunk=32)
    if cfg.hybrid_unit:
        small["hybrid_unit"] = 2
        small["num_layers"] = 4
    if cfg.encoder_layers:
        small["encoder_layers"] = 2
    if cfg.num_prefix_embeds:
        small["num_prefix_embeds"] = 8
    if len(cfg.window_pattern) > 1:
        small["window_pattern"] = (32, None)
        small["num_layers"] = 2
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
