"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

[arXiv:2308.11596].  Per assignment the conv/mel frontend is a stub:
``input_specs`` provides precomputed frame embeddings (B, frames, d_model)
as the encoder input; this config is the 24+24 enc-dec transformer backbone.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,                 # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    gated_mlp=False,
    num_prefix_embeds=1024,        # audio frames fed to the encoder
    tie_embeddings=False,
    # §Perf HC1: 256206 % 16 != 0 replicates the f32 logits over the tensor
    # axis (269 GB/device temp).  Padding to a multiple of 128 shards them.
    vocab_pad_multiple=128,
    source="arXiv:2308.11596",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
