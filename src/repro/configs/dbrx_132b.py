"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig, MoEConfig, reduced

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    gated_mlp=True,
    rope_theta=5e5,
    moe=MoEConfig(num_experts=16, top_k=4, capacity_factor=1.25),
    tie_embeddings=False,
    source="hf:databricks/dbrx-base",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
