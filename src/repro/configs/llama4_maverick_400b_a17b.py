"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E].  Assigned as the text MoE backbone.
"""
from repro.configs.base import ModelConfig, MoEConfig, reduced

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    gated_mlp=True,
    rope_theta=5e5,
    moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25),
    tie_embeddings=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
