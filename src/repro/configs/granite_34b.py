"""granite-34b [dense] — llama-arch code model, 88 layers, MQA (kv=1).

[arXiv:2405.04324].  GPT-BigCode-style classic (non-gated) MLP.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    gated_mlp=False,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2405.04324",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
