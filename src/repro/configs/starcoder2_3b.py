"""starcoder2-3b [dense] — GQA kv=2, RoPE, classic MLP. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab=49152,
    gated_mlp=False,
    rope_theta=1e5,
    tie_embeddings=True,
    source="arXiv:2402.19173",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
