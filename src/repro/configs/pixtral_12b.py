"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone.

[hf:mistralai/Pixtral-12B-2409].  Per assignment, the ViT/projector frontend
is a stub: ``input_specs`` provides precomputed patch embeddings of shape
(B, num_prefix_embeds, d_model); this config is the language decoder that
consumes them (early fusion — embeds replace the leading token positions).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    gated_mlp=True,
    rope_theta=1e6,
    num_prefix_embeds=256,          # one 1024px image -> 256 patch embeddings
    tie_embeddings=False,
    source="hf:mistralai/Pixtral-12B-2409",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
