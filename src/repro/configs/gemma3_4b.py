"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k.

[hf:google/gemma-3-1b-pt].  Repeating unit: 5 sliding-window (1024) layers,
then 1 global layer; 34 layers = 5 full units + 4 local remainder.
The sliding-window layers make long_500k decode sub-quadratic in cache size
(local layers cache only the window; global layers are single-token matvec).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    gated_mlp=True,
    rope_theta=1e6,
    window_pattern=(1024, 1024, 1024, 1024, 1024, None),
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
