"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    gated_mlp=True,
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2404.14219",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
