"""Architecture registry: ``--arch <id>`` -> config module.

Every assigned architecture (plus the paper's own CNNs, which live in
``repro.models.cnn`` as layer graphs) is selectable by its public id.
"""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

ARCHS: dict[str, str] = {
    "pixtral-12b": "pixtral_12b",
    "dbrx-132b": "dbrx_132b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "starcoder2-3b": "starcoder2_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "gemma3-4b": "gemma3_4b",
    "granite-34b": "granite_34b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-2.7b": "mamba2_2_7b",
}

# long_500k runs only for sub-quadratic decode (DESIGN.md §Arch-applicability)
LONG_CONTEXT_OK = {"mamba2-2.7b", "zamba2-2.7b", "gemma3-4b"}


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}").CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}").smoke_config()


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def pair_supported(arch: str, shape: str) -> tuple[bool, str]:
    """Is (arch, shape) runnable?  Returns (ok, reason-if-skip)."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, ("SKIP(design): full-attention decode over 524k KV "
                       "(no assigned sub-quadratic variant)"
                       if arch != "seamless-m4t-large-v2"
                       else "SKIP(design): enc-dec, source-bounded decode")
    return True, ""


def all_pairs() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in INPUT_SHAPES]
