"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060].  64 Mamba2 layers, d_state=128, O(1) decode state,
so long_500k decode is exact and sub-quadratic.
"""
from repro.configs.base import ModelConfig, SSMConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
    # §Perf HC1 spillover: 50280 % 16 != 0 -> same replicated-logit tax
    vocab_pad_multiple=128,
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
