"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) every wrapper runs the kernel in ``interpret=True``
mode — the kernel body executes in Python for bit-faithful validation; on a
real TPU backend the same calls lower to Mosaic.  Padding/reshaping to tile
multiples lives here so kernel bodies stay shape-exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import block_quant as _bq
from repro.kernels import decode_attention as _da
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# -- block quantization (wire compression for the DEFER pipeline) ---------------

def quantize_blocks(x: jax.Array):
    """Any-rank x -> (q int8 [R,C], scales, meta) with padding to (8,128)."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    R, C = flat.shape
    padr, padc = (-R) % _bq.TILE_R, (-C) % _bq.TILE_C
    if padr or padc:
        flat = jnp.pad(flat, ((0, padr), (0, padc)))
    q, s = _bq.quantize_blocks(flat, interpret=_interpret())
    return q, s, (shape, R, C)


def dequantize_blocks(q: jax.Array, scales: jax.Array, meta, dtype=jnp.float32):
    shape, R, C = meta
    x = _bq.dequantize_blocks(q, scales, dtype=dtype, interpret=_interpret())
    return x[:R, :C].reshape(shape)


def quant_bytes(shape, dtype=jnp.bfloat16) -> tuple[int, int]:
    """(raw_bytes, wire_bytes) for a tensor sent through the quant codec."""
    n = int(np.prod(shape))
    raw = n * jnp.dtype(dtype).itemsize
    wire = n * 1 + (n // (_bq.TILE_R * _bq.TILE_C)) * 4   # int8 + f32 scales
    return raw, wire


# -- decode attention ------------------------------------------------------------

def decode_attention(q, k, v, kpos, pos, window, scale):
    """q [B,1,H,hd]; k/v [B,C,kv,hd]; kpos [B,C]; pos [B] -> [B,1,H,hd]."""
    C = k.shape[1]
    block = min(_da.BLOCK_C, C)
    pad = (-C) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
    return _da.decode_attention(q, k, v, kpos, pos, window, scale,
                                block_c=block, interpret=_interpret())


# -- SSD scan ----------------------------------------------------------------------

def ssd_scan(xc, dtc, A, Bc, Cc, init_state):
    """Chunked inputs -> (y [B, nc*Q, H, P], final_state [B,H,P,N]).

    Matches the return convention of ``ssm.ssd_chunked``'s scan path: callers
    trim padding rows themselves (they know S_orig).
    """
    B, nc, Q, H, P = xc.shape
    y, fin = _ssd.ssd_scan(xc, dtc, A, Bc, Cc, init_state,
                           interpret=_interpret())
    return y.reshape(B, nc * Q, H, P), fin
