"""Pallas TPU kernel: single-token GQA decode attention over a long KV cache.

The decode-shape hot spot (``decode_32k``, ``long_500k``): one query token
attends over a KV cache of up to 524k positions.  The cache never fits VMEM,
so the kernel streams KV blocks HBM->VMEM along the innermost grid dimension
and maintains a running (flash-style) softmax in VMEM scratch:

    grid = (B, KV_heads, C // BLOCK_C)          # last dim sequential on TPU

Per (b, kv) instance the G = H/KV query rows of that group are resident; each
KV block contributes a partial max / denominator / weighted-value sum.  The
position-validity mask (ring-buffer slots, window) is computed from the
``kpos`` sidecar, so sliding-window ring caches need no host-side compaction.

Block shape: (BLOCK_C, head_dim) with BLOCK_C=512 — 512x256 bf16 = 256 kB per
K and V block, double-buffered well inside VMEM; the G x BLOCK_C logits tile
is MXU-shaped for G in {1..32} padded to 8 sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_C = 512


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, scale, window, blocks):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)                 # [BC, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)                 # [BC, hd]
    kpos = kpos_ref[0]                                     # [BC] int32
    pos = pos_ref[0]                                       # scalar int32

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # [G, BC]
    delta = pos - kpos
    valid = (kpos >= 0) & (delta >= 0)
    if window is not None:
        valid &= delta < window
    logits = jnp.where(valid[None, :], logits, NEG_INF)

    m_prev = m_ref[...]                                    # [G, 1]
    m_cur = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(logits - m_cur)                            # [G, BC]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(c == blocks - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "block_c", "interpret"))
def decode_attention(q, k, v, kpos, pos, window, scale,
                     block_c: int = BLOCK_C, interpret: bool = False):
    """q [B,1,H,hd]; k/v [B,C,kv,hd]; kpos [B,C]; pos [B] -> [B,1,H,hd].

    C must be a multiple of ``block_c`` (callers pad the cache; padded slots
    carry kpos = -1 and are masked out).
    """
    B, _, H, hd = q.shape
    C, kv = k.shape[1], k.shape[2]
    g = H // kv
    block_c = min(block_c, C)
    assert C % block_c == 0, f"cache len {C} % block {block_c} != 0"
    blocks = C // block_c
    qg = q.reshape(B, kv, g, hd)

    kernel = functools.partial(_decode_attn_kernel, scale=scale,
                               window=window, blocks=blocks)
    out = pl.pallas_call(
        kernel,
        grid=(B, kv, blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, c: (b,)),                    # pos
            pl.BlockSpec((1, 1, g, hd), lambda b, h, c: (b, h, 0, 0)),   # q
            pl.BlockSpec((1, block_c, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, block_c, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, block_c), lambda b, h, c: (b, c)),          # kpos
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max
            pltpu.VMEM((g, 1), jnp.float32),     # running denom
            pltpu.VMEM((g, hd), jnp.float32),    # weighted-value acc
        ],
        interpret=interpret,
    )(pos, qg, k, v, kpos)
    return out.reshape(B, 1, H, hd)
