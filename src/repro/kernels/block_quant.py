"""Pallas TPU kernel: fixed-rate shared-scale int8 block quantization.

This is the TPU-native adaptation of DEFER's ZFP wire codec (DESIGN.md §4):
ZFP's core idea — *fixed-rate blockwise compression of floats* — maps onto
per-(8,128)-VREG-tile shared-scale int8 quantization executed in VMEM.  The
pipeline runtime quantizes an inter-stage activation before ``ppermute`` and
dequantizes after, cutting ICI bytes 2x (bf16) / 4x (f32) plus a 1/1024
scale sidecar, with a fixed (rate-determined) error envelope exactly like ZFP.

Tiling: the (8, 128) tile is the native VREG shape (8 sublanes x 128 lanes),
so absmax-reduction and the scale broadcast stay register-local; blocks of
``BLOCK_R x BLOCK_C`` tiles are staged through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TILE_R, TILE_C = 8, 128

# VMEM block: (BLOCK_R*8) x (BLOCK_C*128) values.  64x4 => 512x512 f32 = 1 MB
# in + 0.25 MB out + scales — comfortably inside ~16 MB VMEM with double
# buffering; rows-major grid keeps lanes contiguous.
BLOCK_R = 64
BLOCK_C = 4


def _quant_kernel(x_ref, q_ref, s_ref):
    """x block [BR*8, BC*128] -> int8 block + scales [BR, BC]."""
    br = x_ref.shape[0] // TILE_R
    bc = x_ref.shape[1] // TILE_C
    x = x_ref[...].astype(jnp.float32)
    xt = x.reshape(br, TILE_R, bc, TILE_C)
    absmax = jnp.abs(xt).max(axis=3).max(axis=1)                # [br, bc]
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = xt / scale[:, None, :, None]
    q = jnp.clip(jnp.round(q), -127.0, 127.0)
    q_ref[...] = q.reshape(x_ref.shape).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    br = q_ref.shape[0] // TILE_R
    bc = q_ref.shape[1] // TILE_C
    qt = q_ref[...].astype(jnp.float32).reshape(br, TILE_R, bc, TILE_C)
    x = qt * s_ref[...][:, None, :, None]
    x_ref[...] = x.reshape(q_ref.shape).astype(x_ref.dtype)


def _grid(R, C, block_r, block_c):
    return (R // (block_r * TILE_R), C // (block_c * TILE_C))


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def quantize_blocks(x: jax.Array, block_r: int = BLOCK_R, block_c: int = BLOCK_C,
                    interpret: bool = False):
    """x [R, C] (R % 8 == 0, C % 128 == 0) -> (q int8 [R,C], scales [R/8, C/128])."""
    R, C = x.shape
    block_r = min(block_r, R // TILE_R)
    block_c = min(block_c, C // TILE_C)
    grid = _grid(R, C, block_r, block_c)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r * TILE_R, block_c * TILE_C),
                               lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_r * TILE_R, block_c * TILE_C), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((R // TILE_R, C // TILE_C), jnp.float32),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("dtype", "block_r", "block_c",
                                             "interpret"))
def dequantize_blocks(q: jax.Array, scales: jax.Array, dtype=jnp.float32,
                      block_r: int = BLOCK_R, block_c: int = BLOCK_C,
                      interpret: bool = False):
    R, C = q.shape
    block_r = min(block_r, R // TILE_R)
    block_c = min(block_c, C // TILE_C)
    grid = _grid(R, C, block_r, block_c)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r * TILE_R, block_c * TILE_C), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_r * TILE_R, block_c * TILE_C),
                               lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(q, scales)


# -- host-facing wire entry points (the serving runtime's "q8" serializer) ---
#
# The kernels above want an aligned 2D [R, C] grid; the wire sees arbitrary
# activation pytree leaves.  These wrappers flatten, zero-pad to a whole
# number of (8, 128) tiles, and run the kernel natively on TPU or in
# interpret mode everywhere else (same numerics, still one jitted call).

WIRE_C = TILE_C


def _wire_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pow2_tiles(n: int) -> int:
    """Whole (8, 128) tiles covering n values, rounded up to a power of two
    so the jit cache sees a bounded set of [R, 128] shapes regardless of
    ragged batch sizes (one specialization per doubling, not per size)."""
    tiles = -(-n // (TILE_R * WIRE_C))
    p = 1
    while p < tiles:
        p *= 2
    return p


def quantize_wire(arr: np.ndarray,
                  interpret: bool | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Arbitrary-shape array -> (int8 payload [Np], float32 scales [Np/1024]).

    ``Np`` is ``arr.size`` zero-padded up to a power-of-two count of
    (8, 128) tiles; the caller records the true element count and may trim
    the int8 payload to it (zero input quantizes to zero, so the padding is
    reconstructible on decode).
    """
    a = np.ascontiguousarray(arr, dtype=np.float32).ravel()
    n = a.size
    if n == 0:
        return np.zeros(0, np.int8), np.zeros(0, np.float32)
    np_full = _pow2_tiles(n) * TILE_R * WIRE_C
    if np_full > n:
        a = np.concatenate([a, np.zeros(np_full - n, np.float32)])
    x = a.reshape(-1, WIRE_C)
    q, s = quantize_blocks(jnp.asarray(x),
                           interpret=_wire_interpret(interpret))
    return np.asarray(q).ravel(), np.asarray(s, np.float32).ravel()


def dequantize_wire(q: np.ndarray, scales: np.ndarray, n: int,
                    shape: tuple[int, ...], dtype,
                    interpret: bool | None = None) -> np.ndarray:
    """Invert :func:`quantize_wire` back to ``shape``/``dtype``.  Accepts an
    int8 payload trimmed to ``n`` — the tail tiles quantized from zero
    padding are re-synthesized as zeros."""
    if n == 0:
        return np.zeros(shape, dtype)
    np_full = scales.size * TILE_R * TILE_C    # one scale per (8, 128) tile
    qf = np.zeros(np_full, np.int8)
    qf[:q.size] = q
    q2 = qf.reshape(-1, WIRE_C)
    s2 = np.ascontiguousarray(scales, dtype=np.float32).reshape(
        -1, WIRE_C // TILE_C)
    out = dequantize_blocks(jnp.asarray(q2), jnp.asarray(s2),
                            interpret=_wire_interpret(interpret))
    return np.asarray(out).ravel()[:n].reshape(shape).astype(dtype, copy=False)
