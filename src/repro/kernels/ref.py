"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the numerical ground truth the kernels are validated
against (``tests/test_kernels.py`` sweeps shapes/dtypes and asserts
allclose).  They are deliberately written in the most obvious way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TILE_R, TILE_C = 8, 128  # TPU VREG tile: 8 sublanes x 128 lanes


# -- block quantization (the ZFP fixed-rate adaptation) -----------------------

def quantize_blocks_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fixed-rate shared-scale int8 quantization per (8,128) tile.

    x [R, C] (R % 8 == 0, C % 128 == 0) -> (q int8 [R, C],
    scales f32 [R/8, C/128]).  scale = absmax/127 per tile; q = round(x/scale).
    """
    R, C = x.shape
    tr, tc = R // TILE_R, C // TILE_C
    xt = x.astype(jnp.float32).reshape(tr, TILE_R, tc, TILE_C)
    absmax = jnp.abs(xt).max(axis=(1, 3))                       # [tr, tc]
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xt / scale[:, None, :, None]), -127, 127)
    return q.astype(jnp.int8).reshape(R, C), scale


def dequantize_blocks_ref(q: jax.Array, scale: jax.Array,
                          dtype=jnp.float32) -> jax.Array:
    R, C = q.shape
    tr, tc = R // TILE_R, C // TILE_C
    qt = q.astype(jnp.float32).reshape(tr, TILE_R, tc, TILE_C)
    return (qt * scale[:, None, :, None]).reshape(R, C).astype(dtype)


# -- single-token decode attention ---------------------------------------------

def decode_attention_ref(q, k, v, kpos, pos, window, scale):
    """q [B,1,H,hd]; k/v [B,C,kv,hd]; kpos [B,C] absolute position per cache
    slot (-1 = empty); pos [B] current position.  GQA broadcast; returns
    [B,1,H,hd] in f32."""
    B, _, H, hd = q.shape
    C, kv = k.shape[1], k.shape[2]
    g = H // kv
    qg = q.astype(jnp.float32).reshape(B, kv, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, kf) * scale       # [B,kv,g,C]
    delta = pos[:, None] - kpos                                  # [B,C]
    valid = (kpos >= 0) & (delta >= 0)
    if window is not None:
        valid &= delta < window
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, vf)
    return out.reshape(B, 1, H, hd)


# -- SSD (Mamba2) chunked scan ---------------------------------------------------

def ssd_scan_ref(xc, dtc, A, Bc, Cc, init_state):
    """Chunked state-space-dual scan (arXiv:2405.21060), plain jnp.

    xc [B,nc,Q,H,P]; dtc [B,nc,Q,H] (>0); A [H] (<0); Bc/Cc [B,nc,Q,N]
    (single B/C group broadcast over heads); init_state [B,H,P,N] f32.
    Returns (y [B,nc,Q,H,P] in xc.dtype, final_state [B,H,P,N] f32).
    """
    Bb, nc, Q, H, P = xc.shape
    f32 = jnp.float32

    def body(state, inp):
        xq, dtq, Bq, Cq = inp
        l = dtq.astype(f32) * A                              # [B,Q,H]
        cum = jnp.cumsum(l, axis=1)
        Lmat = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B,Q,Q,H]
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(causal[None, :, :, None], Lmat, 0.0)
        CB = jnp.einsum("bqn,bsn->bqs", Cq.astype(f32), Bq.astype(f32))
        scores = CB[:, :, :, None] * Lmat * dtq.astype(f32)[:, None, :, :]
        y = jnp.einsum("bqsh,bshp->bqhp", scores, xq.astype(f32))
        y += jnp.einsum("bqn,bhpn->bqhp", Cq.astype(f32), state) \
            * jnp.exp(cum)[:, :, :, None]
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)
        dx = xq.astype(f32) * (dtq.astype(f32) * decay_to_end)[..., None]
        new_state = state * jnp.exp(cum[:, -1])[:, :, None, None] \
            + jnp.einsum("bqhp,bqn->bhpn", dx, Bq.astype(f32))
        return new_state, y.astype(xc.dtype)

    final, ys = jax.lax.scan(
        body, init_state.astype(f32),
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), final
