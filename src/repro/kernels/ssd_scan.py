"""Pallas TPU kernel: Mamba2 SSD (state-space dual) chunked scan.

The SSD algorithm (arXiv:2405.21060) reformulates the selective-state-space
recurrence as, per chunk of Q tokens, one quadratic *attention-like* term
(MXU matmuls) plus a rank-N running-state correction carried across chunks.
This kernel executes the per-(batch, head) scan with the chunk index as the
innermost (sequential) grid dimension and the running state held in VMEM
scratch — the HBM traffic is exactly one read of x/dt/B/C and one write of y
per token, with zero state spills:

    grid = (B, H, nc)                      # nc sequential, state persists

Per instance: xq [Q, P], dtq [Q], Bq/Cq [Q, N], state [P, N] f32 scratch.
All four matmuls ([Q,N]x[N,Q], [Q,Q]x[Q,P], [Q,N]x[N,P], [P,Q]x[Q,N]) are
MXU-shaped for Q in {128, 256}, N/P in {64, 128}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref,
                y_ref, fin_ref, state_ref, *, nc):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)

    xq = x_ref[0, 0, :, 0].astype(jnp.float32)             # [Q, P]
    dtq = dt_ref[0, 0, :, 0].astype(jnp.float32)           # [Q]
    A = a_ref[0]                                           # scalar (<0)
    Bq = b_ref[0, 0].astype(jnp.float32)                   # [Q, N]
    Cq = c_ref[0, 0].astype(jnp.float32)                   # [Q, N]
    Q = xq.shape[0]

    l = dtq * A                                            # [Q] <= 0
    cum = jnp.cumsum(l)                                    # [Q]
    # intra-chunk attention-like term
    Lmat = jnp.exp(cum[:, None] - cum[None, :])            # [Q, Q]
    causal = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    CB = jax.lax.dot_general(Cq, Bq, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = CB * Lmat * causal * dtq[None, :]             # [Q, Q]
    y = jax.lax.dot_general(scores, xq, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: incoming state contribution  C_t state^T * exp(cum_t)
    state = state_ref[...]                                 # [P, N]
    y += jax.lax.dot_general(Cq, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    # state update: decay + sum_t dt_t decay_t x_t B_t^T
    decay_to_end = jnp.exp(cum[-1] - cum)                  # [Q]
    dx = xq * (dtq * decay_to_end)[:, None]                # [Q, P]
    state_ref[...] = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        dx, Bq, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _done():
        fin_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(xc, dtc, A, Bc, Cc, init_state, interpret: bool = False):
    """xc [B,nc,Q,H,P]; dtc [B,nc,Q,H]; A [H]; Bc/Cc [B,nc,Q,N];
    init_state [B,H,P,N] f32 -> (y [B,nc,Q,H,P], final_state [B,H,P,N])."""
    B, nc, Q, H, P = xc.shape
    N = Bc.shape[-1]
    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), xc.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, A.astype(jnp.float32), Bc, Cc, init_state.astype(jnp.float32))
    return y, fin
