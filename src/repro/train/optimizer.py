"""AdamW + warmup-cosine schedule + global-norm clipping, on plain pytrees.

Self-contained (no optax in this container); moments shard like their
parameters (see ``sharding.opt_state_pspecs``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Any) -> dict:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(params: Any, grads: Any, opt_state: dict, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * g32 * g32
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (u + decay)
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
