"""Checkpointing: pytree -> sharded .npz files + JSON manifest, resumable.

Layout:  <dir>/step_<n>/manifest.json + shard_<i>.npz.  Leaves are stored by
their tree path; shards are capped at ``shard_bytes`` so very large models
split across files.  Restores into the exact original tree structure and
dtypes; ``latest_step`` enables resume.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def save(directory: str, step: int, tree: Any,
         shard_bytes: int = 512 * 1024 * 1024) -> str:
    out = os.path.join(directory, f"step_{step}")
    os.makedirs(out, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: dict[str, Any] = {"step": step, "leaves": [], "shards": 0}
    shard: dict[str, np.ndarray] = {}
    shard_size = 0
    si = 0

    def flush():
        nonlocal shard, shard_size, si
        if shard:
            np.savez(os.path.join(out, f"shard_{si}.npz"), **shard)
            si += 1
            shard, shard_size = {}, 0

    for path, leaf in flat:
        name = _path_str(path)
        arr = np.asarray(leaf)
        if shard_size + arr.nbytes > shard_bytes and shard:
            flush()
        key = f"a{len(shard)}"
        shard[key] = arr
        manifest["leaves"].append(
            {"path": name, "shard": si, "key": key, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})
        shard_size += arr.nbytes
    flush()
    manifest["shards"] = si
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return out


def restore(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs)."""
    src = os.path.join(directory, f"step_{step}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    shards = {i: np.load(os.path.join(src, f"shard_{i}.npz"))
              for i in range(manifest["shards"])}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        name = _path_str(path)
        entry = by_path[name]
        arr = shards[entry["shard"]][entry["key"]]
        assert list(arr.shape) == list(leaf.shape), \
            f"{name}: ckpt {arr.shape} vs model {leaf.shape}"
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None
