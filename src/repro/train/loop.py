"""Training loop: jitted train_step + host loop with metrics.

``make_train_step`` builds the canonical step used by launch/train.py, the
train-shape dry-runs, and the end-to-end example: loss (next-token CE +
router aux) -> grads -> AdamW.  Remat is applied over the unit scan inside
the model when ``cfg.remat`` (policy: nothing saved across units — the
standard memory/compute trade recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import OptConfig, apply_updates, init_opt_state


def make_loss_fn(cfg: ModelConfig, use_kernel: bool = False):
    def loss_fn(params, batch):
        return T.loss_fn(params, cfg, batch, use_kernel)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: OptConfig,
                    use_kernel: bool = False) -> Callable:
    loss_fn = make_loss_fn(cfg, use_kernel)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, stats = apply_updates(params, grads, opt_state, opt)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, opt: OptConfig, data_iter, num_steps: int,
          key: jax.Array | None = None, params=None, use_kernel: bool = False,
          log_every: int = 10, callback=None):
    """Single-host training loop (CPU smoke / examples).  Returns
    (params, opt_state, history)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if params is None:
        params = T.init_lm(cfg, key)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt, use_kernel))
    history = []
    t0 = time.perf_counter()
    for step in range(num_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(m)
    return params, opt_state, history
