"""Per-stage routing for replicated topologies.

A :class:`StageGroup` owns one topology stage: its replicas, its inbound
channel, and a router thread that spreads work across the replicas.  The
groups of consecutive stages chain through the stage input channels:

    pump -> [router 0] -> replica inboxes (stage 0)
                           each replica egress -> stage 1 input channel
         -> [router 1] -> replica inboxes (stage 1)
                           ...
         -> result channel -> collector

Routing policies: ``"rr"`` (round-robin) and ``"lqd"`` (least queue
depth — the default; ties break round-robin, so a homogeneous idle stage
degrades gracefully to rr).

**The fence barrier.** With one replica per stage the chain is a single
FIFO and a :class:`~repro.runtime.wire.ReconfigMarker` can never be
overtaken.  Replication breaks that: a fast replica may emit post-fence
envelopes while a slow sibling still drains pre-fence work.  Each router
therefore runs a counting barrier per epoch: it forwards the fence to its
own replicas only after receiving one copy from EVERY upstream replica,
and envelopes stamped ahead of its current epoch
(:attr:`BatchEnvelope.epoch`) are held until that barrier completes.
Pre-fence stragglers (stamped at or below the current epoch) keep flowing
during the barrier — holding them would deadlock the very backlog the
barrier waits for.

**Elastic membership.** ``Dispatcher.scale`` stages a pending membership
change (spawned replicas to add, draining replicas to retire) keyed by the
fence epoch; the router applies it exactly when the fence passes: spawned
replicas join the broadcast + routing set at the fence (so the downstream
barrier count includes them), draining replicas receive the fence (flushing
their in-flight work), are removed from the routing set, and get a
``_RETIRE`` token queued behind the fence — they finish everything already
routed to them and exit without signaling downstream.  Zero requests are
dropped, reordered (the collector's sequenced merge), or recomputed.

``fence_info`` is the cross-stage contract: before broadcasting epoch ``e``
the router records how many marker copies the downstream barrier must
expect and how many members will remain after — the downstream router (or
the tail collector) reads exactly that.  The same count bookkeeping makes
``_STOP`` exact: a shutdown broadcast reaches every live replica, each
forwards one stop, and the downstream barrier knows how many to await.

**Dead links.**  With a real socket transport a replica's inbox can die
mid-serve (connection reset, :class:`ChannelClosed`).  The router then (1)
fails exactly the affected batch's futures (the same per-batch isolation a
compute error gets), (2) removes the member from the routing set so later
traffic heals onto its siblings, and (3) keeps the member on a ``dead``
list whose control tokens it *proxies*: when a fence or stop broadcast
comes due, the router sends the dead member's copy directly into its
downstream channel — the replica's own egress will never do it (its
ingress self-retired on the closed channel) and the downstream barrier
counts would otherwise wait forever.  The chain keeps serving, and
shutdown still joins cleanly.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import TYPE_CHECKING

from repro.runtime.node import _RETIRE, _STOP, ComputeNode
from repro.runtime.transport import Channel, ChannelClosed
from repro.runtime.wire import (K_CLOSE, K_OPEN, K_STEP, BatchEnvelope,
                                ReconfigMarker)

if TYPE_CHECKING:
    from repro.runtime.topology import StageSpec


class FenceTally:
    """Counting state for the markers and stops one consumer receives from
    an upstream replica set — shared by every stage router and the tail
    collector, so the barrier/stop accounting exists exactly once.

    A drained replica forwards its fence copy but never a stop, and the
    fence lowers ``expected_stops`` when its barrier completes — possibly
    AFTER the last live replica's stop already arrived, so the consumer
    must re-check :attr:`stopped` after every completed barrier, not only
    on stop receipt (otherwise shutdown racing an in-flight drain fence
    deadlocks)."""

    def __init__(self, upstream_members: int):
        self.expected_stops = upstream_members
        self.stops = 0
        self._marks: dict[int, int] = {}
        self._barrier: dict[int, tuple[int, int]] = {}

    @property
    def stopped(self) -> bool:
        return self.stops >= self.expected_stops

    def on_stop(self) -> bool:
        """Record one _STOP; True once every upstream member stopped."""
        self.stops += 1
        return self.stopped

    def on_marker(self, epoch: int,
                  upstream: "StageGroup | None") -> bool:
        """Record one fence copy; True exactly when the barrier for
        ``epoch`` completes (at which point all pre-fence traffic from
        every upstream replica has been received, and ``expected_stops``
        reflects the post-fence membership)."""
        self._marks[epoch] = self._marks.get(epoch, 0) + 1
        if epoch not in self._barrier:
            # first copy of this fence: learn the barrier size (recorded
            # by the upstream router before it broadcast, so this read
            # can never race ahead of the write)
            self._barrier[epoch] = ((1, 1) if upstream is None
                                    else upstream.fence_info(epoch))
        need, after = self._barrier[epoch]
        if self._marks[epoch] < need:
            return False
        del self._marks[epoch], self._barrier[epoch]
        self.expected_stops = after
        return True


class StageGroup:
    """One stage of the topology: replicas + router + fence bookkeeping."""

    def __init__(self, index: int, spec: "StageSpec",
                 replicas: list[ComputeNode], input_channel: Channel,
                 upstream: "StageGroup | None",
                 fail_batch=None, note_displaced=None):
        self.index = index
        self.spec = spec
        self.replicas = replicas            # all live replicas (stats view)
        self.input = input_channel
        self.upstream = upstream            # None = fed by the pump
        self.routing = spec.routing
        # (extents, error=str) callback: a routing failure (a transport
        # send raising) fails exactly the affected requests' futures
        # instead of silently killing the router thread and hanging every
        # client — mirroring the per-batch isolation inside ComputeNode
        self.fail_batch = fail_batch
        # (sessions) callback: the replica these decode sessions were
        # pinned to left the routing set (drained at a fence, or its link
        # died), so their KV caches at this stage are gone — the
        # dispatcher flags them for session-layer re-prefill
        self.note_displaced = note_displaced
        # epoch -> (markers the DOWNSTREAM barrier must count, members
        # remaining after the fence).  Written before the broadcast, read
        # by the next router / the collector when its barrier trips.
        self._fence_info: dict[int, tuple[int, int]] = {}
        # epoch -> (replicas to add, replicas to retire) at that fence
        self._pending: dict[int, tuple[list[ComputeNode],
                                       list[ComputeNode]]] = {}
        self._info_lock = threading.Lock()
        self._thread: threading.Thread | None = None

    # -- cross-stage contract -------------------------------------------------
    def fence_info(self, epoch: int) -> tuple[int, int]:
        """(expected marker count, members after) for a fence this stage
        broadcast — consumed once by the downstream barrier."""
        with self._info_lock:
            return self._fence_info.pop(epoch)

    def stage_membership(self, epoch: int, adds: list[ComputeNode],
                         drops: list[ComputeNode]) -> None:
        """Queue a membership change to apply when fence ``epoch`` passes
        this stage's router."""
        with self._info_lock:
            self._pending[epoch] = (adds, drops)

    def upstream_members(self) -> int:
        return 1 if self.upstream is None else len(self.upstream.replicas)

    def live_replicas(self) -> list[ComputeNode]:
        """Current members for stats/pricing: prunes replicas retired by
        a drain once their threads exit.  An un-acked drain leaves a
        retiree in ``replicas`` while it flushes (its telemetry is still
        real); once dead it must go, or its frozen snapshot epoch makes
        the controller rebaseline forever and its ghost membership
        inflates capacity pricing."""
        with self._info_lock:
            for node in [r for r in self.replicas if r.retiring]:
                if not any(t.is_alive() for t in node._threads):
                    self.replicas.remove(node)
            return list(self.replicas)

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._thread = threading.Thread(target=self._route_loop, daemon=True)
        self._thread.start()

    def join(self) -> None:
        if self._thread is not None:
            self._thread.join()

    # -- the router thread ----------------------------------------------------
    # in-flight ledger floor per member: outstanding items on a channel
    # are bounded by its credit window (the stage queue_depth), so the
    # per-member depth is that capacity with headroom — this floor only
    # covers channels that do not expose a capacity
    _LEDGER_DEPTH = 64

    @classmethod
    def _ledger_depth(cls, m: ComputeNode) -> int:
        cap = getattr(m.inbox, "capacity", 0) or 0
        # process-backed members (lost_on_death) lose their CONSUMED
        # in-flight work too when they die, so the ledger must also cover
        # the member's internal pipeline: up to ~3 waves of max_batch
        # envelopes (ingress stash + compute + egress) beyond the channel
        mb = (getattr(m, "max_batch_cap", None)
              or getattr(m, "max_batch", 0) or 0)
        return max(cls._LEDGER_DEPTH, 2 * cap + 4 * mb)
    # how long to wait for a dead member's threads to finish flushing
    # before proxying its fence/stop downstream (normally milliseconds —
    # the self-retire is immediate once the channel raises)
    _FLUSH_JOIN_S = 5.0

    def _route_loop(self) -> None:
        members = list(self.replicas)       # the routing set (thread-local)
        dead: list[ComputeNode] = []        # members with a dead inbox link
        # per member: the last routed items' extents (None for control
        # tokens), FIFO-aligned with the channel, so when a link dies the
        # unconsumed tail (channel qsize, credit accounting) can be failed
        # instead of leaving those batches' futures hanging forever
        ledger: dict[int, deque] = {}
        # per member that can report what it forwarded
        # (``forwarded_tokens``, i.e. process-backed): [settled_prefix,
        # control tokens sent on its link, in order].  A member can die
        # AFTER a broadcast handed its fence/stop copy to the socket
        # (the send succeeds into a doomed buffer) but BEFORE its egress
        # forwarded the copy downstream — without settling that copy the
        # downstream barrier is short one count forever and a mid-fence
        # scale() wedges.  settle_tokens() proxies exactly the
        # sent-minus-forwarded tail on death.
        sent_tokens: dict[int, list] = {}
        rr = 0
        current_epoch = 0
        tally = FenceTally(self.upstream_members())
        held: list[BatchEnvelope] = []
        # decode-session stickiness: session id -> the member holding its
        # KV cache at this stage.  Router-thread-local like the routing
        # set itself; opens pin (policy pick), steps follow the pin,
        # closes unpin, and a member leaving the set displaces its
        # sessions (note_displaced).  Session envelopes carry exactly one
        # extent, so an envelope never needs splitting to route sticky.
        affinity: dict = {}

        def displace_sessions(m: ComputeNode) -> None:
            owned = [s for s, mm in affinity.items() if mm is m]
            for s in owned:
                del affinity[s]
            if owned and self.note_displaced is not None:
                self.note_displaced(owned)

        def fail_extents(extents, why: str,
                         retryable: bool = False) -> None:
            if self.fail_batch is not None:
                self.fail_batch(extents, error=why, retryable=retryable)

        def fail_stranded(m: ComputeNode) -> None:
            """Fail the batches stranded in a dead link's buffers: the
            unconsumed tail of its FIFO, counted by the channel's
            outstanding credits.  A batch the replica had in fact already
            consumed may be failed spuriously (its late result is then
            ignored by the collector) — at-most-once on a dying link,
            never a hang.  For a process-backed member
            (``lost_on_death``) the replica's own pipeline died with the
            link, so the CONSUMED-but-unfinished batches are gone too:
            the whole ledger fails, and entries whose results already
            reached the collector resolve to no-ops there."""
            dq = ledger.pop(id(m), None)
            if not dq:
                return
            if getattr(m, "lost_on_death", False):
                entries = list(dq)
            else:
                try:
                    k = m.inbox.qsize()
                except Exception:  # deferlint: swallow(depth probe on a dying link; 0 means nothing stranded)
                    k = 0
                if not k:
                    return
                entries = list(dq)[-k:]
            for entry in entries:
                if entry is not None:
                    # a dead link/replica is an infrastructure failure:
                    # the reliability layer may replay through the healed
                    # routing set (spurious failures resolve to no-ops at
                    # the collector's at-most-once merge)
                    fail_extents(
                        entry,
                        f"stage {self.index} replica {m.replica}: inbox "
                        "link died with this batch in flight "
                        "(undeliverable)",
                        retryable=True)

        def settle_tokens(m: ComputeNode) -> None:
            """Proxy the control tokens a dead member was SENT but never
            forwarded.  Joining the member's threads first makes the
            forwarded count final (and means everything it DID flush is
            already downstream, so the proxies cannot overtake it); only
            members exposing ``forwarded_tokens`` — process-backed, whose
            consumed-but-unforwarded copies die with the process — need
            this, and only they are tracked in ``sent_tokens``."""
            rec = sent_tokens.pop(id(m), None)
            if rec is None:
                return
            base, tokens = rec
            for t in m._threads:
                t.join(self._FLUSH_JOIN_S)
            owed = tokens[m.forwarded_tokens() - base:]
            try:
                if m.next_inbox is not None:
                    for item in owed:
                        m.next_inbox.send(item)
            except (ChannelClosed, OSError):
                pass            # downstream gone too: nothing owed

        def on_member_death(m: ComputeNode) -> None:
            """Heal the routing set; the dead member's fence/stop copies
            are proxied at the next broadcast."""
            if m in members:
                members.remove(m)
                dead.append(m)
            displace_sessions(m)
            fail_stranded(m)
            settle_tokens(m)

        def member_send(m: ComputeNode, item, data: bool = False) -> bool:
            """Send + ledger-record one item to a member.  A DEAD link
            (ChannelClosed/OSError) heals the routing set and fails the
            member's stranded batches — True/False tells the caller.  Any
            other send failure on a DATA envelope (e.g. a payload the
            framing refuses) propagates so the caller fails exactly that
            batch WITHOUT retiring a healthy replica; for control tokens
            (always frameable) any failure is link-shaped."""
            try:
                m.inbox.send(item)
            except (ChannelClosed, OSError):
                on_member_death(m)
                return False
            except Exception:
                if data:
                    raise
                on_member_death(m)
                return False
            ledger.setdefault(id(m), deque(maxlen=self._ledger_depth(m))) \
                .append(item.extents if isinstance(item, BatchEnvelope)
                        else None)
            if not isinstance(item, BatchEnvelope) \
                    and getattr(m, "forwarded_tokens", None) is not None:
                rec = sent_tokens.setdefault(id(m), [0, []])
                rec[1].append(item)
                if len(rec[1]) > 16:
                    # drop the confirmed-forwarded prefix (a stale read
                    # only under-prunes — the relay count is monotonic)
                    k = min(m.forwarded_tokens() - rec[0], len(rec[1]))
                    if k > 0:
                        del rec[1][:k]
                        rec[0] += k
            return True

        def probe_members() -> None:
            """Proactively heal members whose channel reports itself dead
            (the transport noticed the peer process vanish).  Waiting for
            a send to fail is not enough: under lqd a dead member whose
            frozen depth exceeds its siblings' is never picked again, so
            its stranded batches' futures would hang until shutdown."""
            for m in list(members):
                if getattr(m.inbox, "dead", False):
                    on_member_death(m)

        def route(env: BatchEnvelope) -> None:
            nonlocal rr
            probe_members()
            if not members:
                raise ChannelClosed(
                    f"stage {self.index}: no live replicas (all inbox "
                    "links dead)")
            ext = env.extents[0] if len(env.extents) == 1 else None
            sess = ext.session if ext is not None else None
            if sess is not None:
                pinned = affinity.get(sess)
                if ext.kind == K_CLOSE:
                    affinity.pop(sess, None)
                if pinned is not None:
                    if pinned in members:
                        if not member_send(pinned, env, data=True):
                            raise ChannelClosed("routed onto a dead link")
                        return
                    # pin points outside the routing set (member drained
                    # or died since): fall through to a policy pick — an
                    # open re-prefills there; a step meets SessionLost at
                    # a replica with no cache, which is the truth
                    affinity.pop(sess, None)
            if len(members) == 1:
                pick = 0
            elif self.routing == "lqd":
                depth = [m.inbox.qsize() for m in members]
                lo = min(depth)
                # ties (and the idle case) rotate round-robin
                pick = min((i for i, d in enumerate(depth) if d == lo),
                           key=lambda i: (i - rr) % len(members))
            else:
                pick = rr % len(members)
            rr = (pick + 1) % len(members)
            target = members[pick]
            if not member_send(target, env, data=True):
                raise ChannelClosed("routed onto a dead link")
            if sess is not None and ext.kind in (K_OPEN, K_STEP):
                affinity[sess] = target

        def broadcast(item) -> None:
            """One control token to every member.  A member whose link
            dies moves to ``dead``; every dead member's copy is proxied
            into its downstream channel so the next stage's barrier/stop
            counting stays exact (the dead replica's own egress will
            never forward it — its ingress self-retired).  Before
            proxying, the dead member's threads get a bounded join: once
            they have exited, everything it flushed is already in the
            downstream channel, so the proxied token cannot overtake its
            pre-fence work (if the join times out — a wedged replica —
            the proxy goes ahead rather than deadlocking the router)."""
            probe_members()     # a dead member's copy must be proxied, not
            for m in list(members):     # lost in its socket's doomed buffer
                member_send(m, item)
            for m in dead:
                for t in m._threads:
                    t.join(self._FLUSH_JOIN_S)
                try:
                    if m.next_inbox is not None:
                        m.next_inbox.send(item)
                except (ChannelClosed, OSError):
                    pass                # downstream gone too: nothing owed

        def fail(env: BatchEnvelope, exc: BaseException) -> None:
            import traceback
            # link-shaped routing failures are retryable (the set heals,
            # a respawn lands); anything else — e.g. a payload the framing
            # refuses — would fail identically on every attempt
            fail_extents(env.extents, traceback.format_exc(),
                         retryable=isinstance(exc, (ChannelClosed, OSError)))

        while True:
            try:
                item = self.input.recv()
            except ChannelClosed:
                # the stage's input link died: nothing will ever arrive
                # again — fail anything still held at a fence barrier (its
                # fence can no longer complete), then flush the replicas
                # out so shutdown can join them
                for env in held:
                    fail_extents(
                        env.extents,
                        f"stage {self.index}: input link died with this "
                        "batch held at an epoch fence (undeliverable)",
                        retryable=True)
                broadcast(_STOP)
                return
            if item is _STOP:
                if not tally.on_stop():
                    continue
                broadcast(_STOP)
                return
            if isinstance(item, ReconfigMarker):
                e = item.epoch
                if not tally.on_marker(e, self.upstream):
                    continue
                # barrier complete: every upstream replica flushed the
                # fence, so all pre-fence work has arrived here
                with self._info_lock:
                    adds, drops = self._pending.pop(e, ([], []))
                members.extend(adds)
                with self._info_lock:
                    # record BEFORE broadcasting — the downstream barrier
                    # reads this when the first forwarded copy lands.
                    # Dead members count on both sides: their marker/stop
                    # copies arrive downstream via the proxy.
                    self._fence_info[e] = (
                        len(members) + len(dead),
                        len(members) - len(drops) + len(dead))
                broadcast(item)
                for m in drops:
                    if m in members:
                        members.remove(m)
                        # a drained member's resident KV caches retire
                        # with it: flag its sessions for re-prefill
                        displace_sessions(m)
                        try:
                            m.retire()  # queued behind the fence: flush+exit
                        except Exception:
                            # link died since the broadcast: a dropped
                            # member owes downstream nothing, but its
                            # stranded batches must still fail (the
                            # ledger is popped only on a clean retire —
                            # fail_stranded needs it), and any fence copy
                            # it never forwarded must be settled
                            fail_stranded(m)
                            settle_tokens(m)
                        else:
                            ledger.pop(id(m), None)     # clean exit: it
                            sent_tokens.pop(id(m), None)    # flushes all
                    elif m in dead:
                        # a dead member can't flush; its fence copy was
                        # proxied and its threads already self-retired —
                        # dropping it just stops the stop-proxying
                        dead.remove(m)
                current_epoch = e
                if held:
                    ready = [env for env in held if env.epoch <= e]
                    held = [env for env in held if env.epoch > e]
                    for env in ready:
                        try:
                            route(env)
                        except Exception as exc:
                            fail(env, exc)
                if tally.stopped:
                    # shutdown raced an in-flight drain fence: the last
                    # live stop arrived BEFORE this barrier lowered the
                    # expectation (the drained replica never stops), so
                    # re-check here or nobody ever will
                    broadcast(_STOP)
                    return
                continue
            env = item
            if env.epoch > current_epoch:
                held.append(env)            # post-fence overtaker: hold at
                continue                    # the barrier
            try:
                route(env)
            except Exception as exc:
                # fail exactly this batch's futures and keep routing —
                # a dying router would silently hang every client
                fail(env, exc)
