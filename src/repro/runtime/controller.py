"""Serving-time feedback controller: close the loop between measurement
and planning.

DEFER's steady-state throughput is ``1 / max_i service_i`` — it is set by
the slowest stage.  The dispatcher plans the chain ONCE, offline, from
static :class:`~repro.core.partitioner.ComputeModel` /
:class:`~repro.core.partitioner.LinkModel` guesses; meanwhile every node
already *measures* its real per-stage decode / compute / encode time per
batch (:class:`~repro.runtime.node.BatchTrace` + per-stage busy counters).
This module feeds those measurements back into the plan while the chain is
serving:

1. **Calibrate** (:class:`CostCalibrator`): EWMA per-*layer* compute
   seconds (each node's measured per-request apply time, spread over its
   layer range by static FLOPs share) and per-*byte* codec rates (encode
   at the sender, decode at the receiver, amortized over real batches, so
   batching efficiency is priced in).  Together these price ANY candidate
   cut, not just the ones currently in use.

2. **Re-plan** (:func:`decide_repartition`): periodically re-run the
   ``balanced_latency`` DP on the calibrated costs — warm-started in a
   window around the live cuts, which bounds both the search and the
   weight bytes a migration would ship — and compare the predicted
   bottleneck against the current plan priced with the SAME costs (the
   partitioner's cost-delta API).  Only when the predicted improvement
   clears a hysteresis threshold does the controller commit; noise in the
   telemetry therefore cannot thrash the chain.

3. **Migrate** (:meth:`Dispatcher.reconfigure`): commit by shipping only
   the shifted layers' weights to the affected neighbors and fencing the
   switch with a :class:`~repro.runtime.wire.ReconfigMarker` epoch marker
   on the wire — zero in-flight requests are dropped or recomputed.

4. **Adapt knobs** (:func:`suggest_knobs`): retune each stage's
   ``max_batch`` and ingress ``coalesce_s`` window (uniformly across its
   replicas) from its measured codec/compute stage-time ratio instead of
   the static 8 / 5 ms defaults: a codec-bound stage grows its coalescing
   window (bigger waves = fewer codec passes, and compute is idle anyway),
   a compute-bound stage shrinks it back toward zero to cut queueing
   latency.

5. **Scale replicas** (:func:`decide_scale`): when the calibrated DP says
   the bottleneck stage CANNOT be fixed by moving cuts (the repartition
   arm holds), the controller prices the topology with the replica-aware
   ruler (stage rate = per-request service / replicas) and recommends —
   or, behind ``execute_scaling``, commits via ``Dispatcher.scale`` — a
   replica change on the bottleneck stage; over-replicated stages shed a
   replica symmetrically.  This is the SEIFER insight: past some point
   the throughput win comes from replicating partitions, not re-cutting
   them.

The controller is deliberately conservative: it acts only on windows with
enough requests, respects a cooldown between migrations, and every
decision (including "hold") is recorded in :attr:`Controller.actions` so
benchmarks and tests can audit the loop.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core.graph import LayerGraph, tree_bytes
from repro.core.partitioner import (CalibratedCosts, ComputeModel, LinkModel,
                                    bounds_bottleneck, calibrated_partition)

if TYPE_CHECKING:                      # import cycle: dispatcher is runtime
    from repro.runtime.dispatcher import Dispatcher


@dataclasses.dataclass
class ControllerConfig:
    """Knobs of the feedback loop itself (not of the nodes it tunes)."""

    interval_s: float = 0.5            # control period
    ewma_alpha: float = 0.4            # calibration smoothing (1 = no memory)
    hysteresis: float = 0.15           # min predicted bottleneck improvement
    min_requests: int = 32             # min per-node window requests before
                                       # a re-plan may commit
    cooldown_s: float = 2.0            # min time between live migrations
    window: int | None = None          # warm-start DP: cut shift cap (layers)
    repartition: bool = True           # enable the live-migration arm
    adapt_knobs: bool = True           # enable the max_batch/coalesce_s arm
    knob_min_requests: int = 4         # per-node interval gate for knob moves
    coalesce_bounds: tuple = (0.0005, 0.04)   # [s] adaptive window clamp
    precompile_after_swap: bool = True # trace new shapes off the hot path
    model_wire: bool = False           # include modeled link time in costs
                                       # (False: in-process wire is free)
    # the replica dimension: when cuts can't fix the bottleneck, recommend
    # (replica_scaling) or commit (execute_scaling) a replica change
    replica_scaling: bool = False      # enable the scale arm
    execute_scaling: bool = False      # actually call Dispatcher.scale
    max_replicas: int = 4              # per-stage replica ceiling
    scale_up_ratio: float = 1.5        # bottleneck rate >= ratio * runner-up
    scale_down_ratio: float = 2.0      # shed only when r-1 stays this far
                                       # under the bottleneck


@dataclasses.dataclass
class ControllerAction:
    """One control decision, kept for audit (tests, benchmarks, reports)."""

    t: float                           # perf_counter at decision time
    kind: str                          # "repartition" | "knobs" | "hold"
    detail: dict


class CostCalibrator:
    """Online EWMA calibration of the partitioner's cost inputs.

    Seeds from the static models (so the first ``costs()`` is exactly the
    offline planner's view) and refines toward measured reality with every
    telemetry window: per-layer compute seconds from each node's measured
    per-request apply time, per-byte encode/decode rates from the codec
    stages.  ``ready`` flips once at least one real window with traffic on
    every node has been folded in — before that, re-planning would just
    echo the static plan's own assumptions back at it.
    """

    def __init__(self, graph: LayerGraph, alpha: float = 0.4,
                 compute: ComputeModel | None = None,
                 link: LinkModel | None = None,
                 model_wire: bool = False):
        self.graph = graph
        self.alpha = alpha
        self.link = link or LinkModel()
        self.model_wire = model_wire
        n = len(graph.nodes)
        compute = compute or ComputeModel()
        self.flops = np.array([nd.flops for nd in graph.nodes], np.float64)
        # static seed: the offline planner's per-layer guess
        self.layer_s = self.flops / compute.flops_per_s
        self.cut_bytes = np.array(
            [graph.cut_cost(i) for i in range(n - 1)]
            + [graph.nodes[-1].out_bytes], np.float64)
        self.head_in_bytes = float(tree_bytes(graph.input_spec))
        self.tail_out_bytes = float(graph.nodes[-1].out_bytes)
        self.encode_s_per_byte = 0.0
        self.decode_s_per_byte = 0.0
        self._nodes_seen: set[int] = set()
        self._num_nodes: int | None = None
        self.updates = 0

    @property
    def ready(self) -> bool:
        return (self._num_nodes is not None
                and len(self._nodes_seen) >= self._num_nodes)

    def _ewma(self, old: float, sample: float) -> float:
        return (1.0 - self.alpha) * old + self.alpha * sample

    def update(self, snapshots: Sequence[dict],
               ranges: Sequence[tuple]) -> None:
        """Fold one telemetry window (``ComputeNode.snapshot()`` per node,
        plus the node's current layer range) into the calibration."""
        self._num_nodes = len(snapshots)
        for snap, (lo, hi) in zip(snapshots, ranges):
            n = snap["n"]
            if n <= 0:
                continue
            self._nodes_seen.add(snap["node"])
            # per-request compute, spread over the range by FLOPs share
            # (zero-FLOP ranges spread uniformly)
            per_req = snap["compute_s"] / n
            shares = self.flops[lo:hi]
            total = shares.sum()
            shares = (shares / total if total > 0
                      else np.full(hi - lo, 1.0 / (hi - lo)))
            for j, share in zip(range(lo, hi), shares):
                self.layer_s[j] = self._ewma(self.layer_s[j],
                                             per_req * share)
            # per-byte codec rates at this node's live cuts; amortization
            # from batching is embedded because serialize/deserialize_s
            # are window totals over n requests
            out_b = (self.tail_out_bytes if hi == len(self.layer_s)
                     else self.cut_bytes[hi - 1])
            if out_b > 0 and snap["serialize_s"] > 0:
                self.encode_s_per_byte = self._ewma(
                    self.encode_s_per_byte, snap["serialize_s"] / n / out_b)
            in_b = (self.head_in_bytes if lo == 0
                    else self.cut_bytes[lo - 1])
            if in_b > 0 and snap["deserialize_s"] > 0:
                self.decode_s_per_byte = self._ewma(
                    self.decode_s_per_byte,
                    snap["deserialize_s"] / n / in_b)
        self.updates += 1

    def costs(self) -> CalibratedCosts:
        return CalibratedCosts(
            layer_s=self.layer_s.copy(),
            cut_bytes=self.cut_bytes,
            encode_s_per_byte=self.encode_s_per_byte,
            decode_s_per_byte=self.decode_s_per_byte,
            wire_s_per_byte=(1.0 / self.link.bandwidth_bytes_per_s
                            if self.model_wire else 0.0),
            head_in_bytes=self.head_in_bytes,
            tail_out_bytes=self.tail_out_bytes,
        )


def decide_repartition(costs: CalibratedCosts, cur_bounds: Sequence[int],
                       num_stages: int, staged: bool = True,
                       hysteresis: float = 0.15,
                       window: int | None = None,
                       replicas: Sequence[int] | None = None) -> dict | None:
    """Pure decision: is a migration worth it under the calibrated costs?

    Prices the CURRENT cuts and the DP's best candidate with the same
    calibrated ruler (the cost-delta API) and returns a decision record
    only when the predicted bottleneck improves by more than
    ``hysteresis`` — the deadband that keeps telemetry noise from
    thrashing the chain with migrations.  ``replicas`` prices both plans
    for the live replicated topology (a 2-replica stage runs at half its
    per-request time, so cuts should lean layers INTO it).
    """
    cur_pred = bounds_bottleneck(costs, cur_bounds, staged, replicas)
    new_bounds, new_pred = calibrated_partition(
        costs, num_stages, staged=staged, prev_bounds=cur_bounds,
        window=window, replicas=replicas)
    if tuple(new_bounds) == tuple(cur_bounds):
        return None
    if new_pred >= cur_pred * (1.0 - hysteresis):
        return None
    return {
        "bounds": new_bounds,
        "cuts": tuple(new_bounds[1:-1]),
        "predicted_current_s": cur_pred,
        "predicted_new_s": new_pred,
        "predicted_gain": cur_pred / new_pred if new_pred > 0 else float("inf"),
    }


def decide_scale(costs: CalibratedCosts, bounds: Sequence[int],
                 replicas: Sequence[int], staged: bool = True,
                 max_replicas: int = 4, up_ratio: float = 1.5,
                 down_ratio: float = 2.0) -> dict | None:
    """Pure decision: should a stage's replica count change?

    Called only after :func:`decide_repartition` held — cuts alone cannot
    fix the bottleneck.  Prices every stage's effective service RATE
    (per-request time / replicas) under the calibrated costs:

    * **up**: the bottleneck stage's rate is at least ``up_ratio`` x the
      runner-up's — moving cuts already couldn't close that gap, so one
      more replica on the bottleneck is the remaining lever (capped at
      ``max_replicas``);
    * **down**: a multi-replica stage that would STILL sit ``down_ratio``
      x under the bottleneck with one replica fewer is over-provisioned —
      shed one (throughput is set by the bottleneck; idle replicas only
      burn energy, the paper's per-node metric).
    """
    ranges = list(zip(bounds, bounds[1:]))
    eff = [costs.stage_service_s(lo, hi, staged, r)
           for (lo, hi), r in zip(ranges, replicas)]
    order = sorted(range(len(eff)), key=lambda i: eff[i], reverse=True)
    b = order[0]
    runner_up = eff[order[1]] if len(order) > 1 else 0.0
    # no runner-up (single stage, or a ~free second stage) means no
    # measured imbalance to justify a spawn — an unconditional up would
    # grow an idle single-stage engine to max_replicas on pure cost noise
    if (runner_up > 0.0 and replicas[b] < max_replicas
            and eff[b] >= up_ratio * runner_up):
        return {"stage": b, "replicas": replicas[b] + 1,
                "direction": "up",
                "predicted_stage_s": eff[b],
                "predicted_after_s": eff[b] * replicas[b]
                / (replicas[b] + 1),
                "runner_up_s": runner_up}
    for s in order[::-1]:                     # coldest stages first
        r = replicas[s]
        if s == b or r <= 1:
            continue
        shed = eff[s] * r / (r - 1)           # rate at r-1 replicas
        if shed * down_ratio <= eff[b]:
            return {"stage": s, "replicas": r - 1,
                    "direction": "down",
                    "predicted_stage_s": eff[s],
                    "predicted_after_s": shed,
                    "bottleneck_s": eff[b]}
    return None


def suggest_knobs(snap: dict, cap: int,
                  coalesce_bounds: tuple = (0.0005, 0.04)) -> tuple[int, float]:
    """Adaptive batching law: retune (max_batch, coalesce_s) from the
    measured codec/compute stage-time ratio.

    * codec-bound (decode+encode busy > compute busy) WITH a real backlog
      (queued arrivals, batches not already full): growing the ingress
      coalescing window merges more requests per wave, so the expensive
      codec runs once per wave instead of once per trickle.  The window is
      additionally capped by the node's measured per-wave service time —
      coalescing longer than one wave takes to process would starve the
      downstream stages instead of hiding behind them.  A backlogged node
      with full batches also raises max_batch toward the cap.
    * compute-bound (ratio < 1/2), or no backlog to merge: shrink the
      window back toward zero — waves can't amortize anything worth the
      queueing latency they add.

    Multiplicative 1.5x steps per control period give smooth convergence;
    the clamps keep the knobs inside sane serving ranges.
    """
    mb, co = snap["max_batch"], snap["coalesce_s"]
    cmp_busy = snap["busy_compute_s"]
    codec_busy = snap["busy_decode_s"] + snap["busy_encode_s"]
    if cmp_busy + codec_busy <= 0:
        return mb, co
    lo, hi = coalesce_bounds
    ratio = codec_busy / max(cmp_busy, 1e-9)
    backlog = snap["queue_depth_mean"]
    waves = max(1.0, snap["n"] / max(snap["batch_mean"], 1e-9))
    wave_service_s = (cmp_busy + codec_busy) / waves
    if ratio > 1.0 and backlog > 1.5:
        if snap["batch_mean"] < 0.75 * mb:
            # waves aren't filling: a longer window merges more per wave
            co = min(hi, max(co, lo) * 1.5, wave_service_s)
        if backlog > 0.5 * mb and snap["batch_mean"] > 0.5 * mb:
            # waves ARE filling and work keeps queueing: the batch size
            # itself is the binding constraint, raise it toward the cap
            # (independent of the coalesce branch — a saturated node with
            # batch_mean == mb must still be able to grow)
            mb = min(cap, mb * 2)
    elif ratio < 0.5 or backlog <= 1.0:
        co = max(lo, co / 1.5)
        if (ratio < 0.5 and snap["batch_mean"] < 0.25 * mb
                and backlog <= 1.0):
            mb = max(1, mb // 2)
    return mb, co


class Controller:
    """The feedback thread tying calibration, planning, and actuation
    together over a live :class:`~repro.runtime.dispatcher.Dispatcher`.

    ``step()`` is one full control period and is callable directly (no
    thread) — that is how tests drive deterministic scenarios and how a
    benchmark can force convergence checks.
    """

    def __init__(self, dispatcher: "Dispatcher",
                 config: ControllerConfig | None = None):
        self.dispatcher = dispatcher
        self.cfg = config or ControllerConfig()
        self.calibrator = CostCalibrator(
            dispatcher.graph, alpha=self.cfg.ewma_alpha,
            link=dispatcher.link, model_wire=self.cfg.model_wire)
        self.actions: list[ControllerAction] = []
        self.migrations = 0
        self._last_migration_t = float("-inf")
        # per-interval windowing: node stats are cumulative (the engine's
        # report window owns their reset), so each step diffs against the
        # previous snapshot and calibrates on the interval's delta only
        self._prev: list[dict] | None = None
        self._accum_n = 0              # evidence since the last migration
        self._skip_update = False      # the interval spanning a migration
                                       # mixes two partitions' telemetry
        self._stop_evt = threading.Event()
        self._thread: threading.Thread | None = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _loop(self) -> None:
        while not self._stop_evt.wait(self.cfg.interval_s):
            try:
                self.step()
            except Exception:            # a sick controller must not kill
                import traceback         # the serving chain it watches
                self.actions.append(ControllerAction(
                    time.perf_counter(), "error",
                    {"traceback": traceback.format_exc()}))

    # -- one control period ---------------------------------------------------
    _COUNTERS = ("n", "compute_s", "serialize_s", "deserialize_s",
                 "payload_bytes", "encodes", "busy_decode_s",
                 "busy_compute_s", "busy_encode_s", "waves", "depth_sum",
                 "depth_count")

    def _stage_snapshot(self, group) -> dict:
        """One telemetry view per STAGE: replica counters summed (time
        totals and request counts are additive across the replicas that
        split the stream), knobs read from replica 0 (set uniformly), and
        the epoch as the MIN over replicas — the stage has fully adopted a
        fence only when its slowest replica has.  live_replicas() prunes
        dead retirees, whose frozen epochs would otherwise read as a
        permanently lagging fence."""
        snaps = [r.snapshot() for r in group.live_replicas()]
        agg = {k: sum(s[k] for s in snaps) for k in self._COUNTERS}
        agg["node"] = group.index
        agg["replicas"] = len(snaps)
        agg["epoch"] = min(s["epoch"] for s in snaps)
        agg["max_batch"] = snaps[0]["max_batch"]
        agg["coalesce_s"] = snaps[0]["coalesce_s"]
        agg["batch_mean"] = (agg["n"] / agg["waves"] if agg["waves"]
                             else 0.0)
        agg["queue_depth_mean"] = (agg["depth_sum"] / agg["depth_count"]
                                   if agg["depth_count"] else 0.0)
        return agg

    @classmethod
    def _delta(cls, prev: dict | None, cur: dict) -> dict:
        """This interval's telemetry: cumulative counters diffed against
        the previous snapshot, with the derived means (batch occupancy,
        queue depth) rebuilt from the interval's own sums so every signal
        shares one time base.

        A counter that went DOWN means the baseline is gone — the engine
        reset its report window, or a drained replica left the stage's
        aggregate (a manual ``scale()`` is not guarded by the fence-lag
        rebaseline if it cleared between control periods).  Either way
        the current cumulative values are NOT one interval's telemetry,
        so the interval is zeroed (skipped) rather than fed to the
        calibrator as a giant fake window; the next tick diffs cleanly
        against the new baseline."""
        if prev is None:
            out = dict(cur)
        else:
            out = dict(cur)
            deltas = {k: cur[k] - prev[k] for k in cls._COUNTERS}
            if any(v < 0 for v in deltas.values()):
                deltas = {k: 0 for k in cls._COUNTERS}
            out.update(deltas)
        out["batch_mean"] = (out["n"] / out["waves"] if out["waves"]
                             else 0.0)
        out["queue_depth_mean"] = (out["depth_sum"] / out["depth_count"]
                                   if out["depth_count"] else 0.0)
        return out

    def step(self) -> ControllerAction:
        d = self.dispatcher
        cfg = self.cfg
        now = time.perf_counter()
        raw = [self._stage_snapshot(g) for g in d.stages]
        prev = self._prev or [None] * len(raw)
        snaps = [self._delta(p, r) for p, r in zip(prev, raw)]
        self._prev = raw
        # an epoch fence can take several intervals to clear a backlogged
        # chain: while any replica still runs the old partition /
        # membership — and for one interval after the last one catches up
        # (that interval's telemetry straddles both) — rebaseline only
        lagging = any(s["epoch"] < d.epoch for s in raw)
        if lagging or self._skip_update:
            self._skip_update = lagging
            action = ControllerAction(now, "rebaseline",
                                      {"epoch": d.epoch,
                                       "fence_in_flight": lagging})
            self.actions.append(action)
            return action
        ranges = d.partition.ranges()
        self.calibrator.update(snaps, ranges)
        # every request traverses every stage, so the interval's size is
        # the MIN per-stage count (summing would count each request k
        # times); evidence accumulates across intervals until a decision
        window_n = min((s["n"] for s in snaps), default=0)
        self._accum_n += window_n

        knob_moves = []
        if cfg.adapt_knobs:
            for i, snap in enumerate(snaps):
                if snap["n"] < cfg.knob_min_requests:
                    continue
                cap = d.stages[i].replicas[0].max_batch_cap
                mb, co = suggest_knobs(snap, cap, cfg.coalesce_bounds)
                if mb != snap["max_batch"] or co != snap["coalesce_s"]:
                    d.set_stage_knobs(i, max_batch=mb, coalesce_s=co)
                    knob_moves.append({"stage": i, "max_batch": mb,
                                       "coalesce_s": co})

        staged = d.stages[0].replicas[0].staged
        reps = list(d.replicas)
        bounds = [0, *d.partition.cuts, len(d.graph.nodes)]
        gate_ok = (self.calibrator.ready
                   and self._accum_n >= cfg.min_requests
                   and now - self._last_migration_t >= cfg.cooldown_s)
        decision = None
        if cfg.repartition and gate_ok:
            decision = decide_repartition(
                self.calibrator.costs(), bounds, len(d.stages),
                staged=staged, hysteresis=cfg.hysteresis,
                window=cfg.window, replicas=reps)
        scale_rec = None
        if decision is None and cfg.replica_scaling and gate_ok:
            # cuts can't fix the bottleneck (the DP held): the replica
            # dimension is the remaining lever
            scale_rec = decide_scale(
                self.calibrator.costs(), bounds, reps, staged=staged,
                max_replicas=cfg.max_replicas,
                up_ratio=cfg.scale_up_ratio,
                down_ratio=cfg.scale_down_ratio)
        if decision is not None:
            record = d.reconfigure(decision["cuts"])
            self._last_migration_t = time.perf_counter()
            self.migrations += 1
            self._accum_n = 0
            self._skip_update = True
            if cfg.precompile_after_swap and record.get("acknowledged"):
                # trace the swapped stages' new batch shapes from the
                # controller thread: concurrent with serving (jit compiles
                # are thread-safe), so the hot path never stalls on XLA
                for i in record["nodes_touched"]:
                    for node in d.stages[i].replicas:
                        node.precompile()
            action = ControllerAction(now, "repartition",
                                      {**decision, **record,
                                       "knobs": knob_moves})
        elif scale_rec is not None and cfg.execute_scaling:
            record = d.scale(scale_rec["stage"], scale_rec["replicas"],
                             precompile=cfg.precompile_after_swap)
            self._last_migration_t = time.perf_counter()
            self.migrations += 1
            self._accum_n = 0
            self._skip_update = True
            action = ControllerAction(now, "scale",
                                      {**scale_rec, **record,
                                       "knobs": knob_moves})
        elif scale_rec is not None:
            # recommendation only: surfaced (and paced by the cooldown)
            # for an operator or an external autoscaler to act on
            self._last_migration_t = time.perf_counter()
            action = ControllerAction(now, "scale_recommend",
                                      {**scale_rec, "knobs": knob_moves})
        elif knob_moves:
            action = ControllerAction(now, "knobs", {"knobs": knob_moves})
        else:
            action = ControllerAction(now, "hold", {"requests": window_n})
        self.actions.append(action)
        return action
