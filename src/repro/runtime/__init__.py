from repro.runtime.engine import InferenceEngine  # noqa: F401
