from repro.runtime.dispatcher import (AdmissionFull,  # noqa: F401
                                      Dispatcher, DispatcherCodecs)
from repro.runtime.engine import EngineReport, InferenceEngine  # noqa: F401
from repro.runtime.wire import Envelope, WireCodec, WireRecord  # noqa: F401
