from repro.runtime.dispatcher import (AdmissionFull,  # noqa: F401
                                      Dispatcher, DispatcherCodecs, NodeError)
from repro.runtime.engine import EngineReport, InferenceEngine  # noqa: F401
from repro.runtime.wire import (BatchEnvelope, Envelope,  # noqa: F401
                                RowExtent, WireCodec, WireRecord)
