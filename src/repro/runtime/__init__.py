from repro.runtime.controller import (Controller,  # noqa: F401
                                      ControllerConfig, CostCalibrator,
                                      decide_repartition, decide_scale,
                                      suggest_knobs)
from repro.runtime.dispatcher import (AdmissionFull,  # noqa: F401
                                      DeadlineExceeded, Dispatcher,
                                      DispatcherCodecs, NodeError,
                                      ReplayStats, RetryPolicy)
from repro.runtime.engine import EngineReport, InferenceEngine  # noqa: F401
from repro.runtime.supervisor import (Supervisor,  # noqa: F401
                                      SupervisorConfig, supervised_engine)
from repro.runtime.topology import StageSpec, TopologySpec  # noqa: F401
from repro.runtime.transport import (Channel, ChannelClosed,  # noqa: F401
                                     InprocTransport, LinkTransport,
                                     TcpTransport, Transport, get_transport,
                                     register_transport,
                                     register_transport_scheme)
from repro.runtime.wire import (BatchEnvelope, Envelope,  # noqa: F401
                                NodePlan, ReconfigMarker, RowExtent,
                                WireCodec, WireFormatError, WireRecord,
                                frame, unframe, unframe_compat)
