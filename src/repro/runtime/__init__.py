from repro.runtime.controller import (Controller,  # noqa: F401
                                      ControllerConfig, CostCalibrator,
                                      decide_repartition, decide_scale,
                                      suggest_knobs)
from repro.runtime.dispatcher import (AdmissionFull,  # noqa: F401
                                      Dispatcher, DispatcherCodecs, NodeError)
from repro.runtime.engine import EngineReport, InferenceEngine  # noqa: F401
from repro.runtime.topology import StageSpec, TopologySpec  # noqa: F401
from repro.runtime.transport import (Channel, InprocTransport,  # noqa: F401
                                     Transport, get_transport,
                                     register_transport)
from repro.runtime.wire import (BatchEnvelope, Envelope,  # noqa: F401
                                NodePlan, ReconfigMarker, RowExtent,
                                WireCodec, WireRecord)
