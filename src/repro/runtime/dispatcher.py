"""The DEFER dispatcher (paper Algorithm 1), in-process, async.

Partitions the model, ships architecture + weights to each compute node
(configuration step), then serves a *multi-client* inference stream: a
bounded admission queue applies backpressure at the front door, a pump
thread feeds the head of the chain, compute nodes continuously batch (and
relay whole batches as single :class:`BatchEnvelope` payloads), and a
collector thread decodes each tail envelope ONCE, slices per-request rows
back out, and resolves the per-request futures — FIFO per client (the
batching chain may legally reorder across clients).  A batch that failed
inside a node arrives as an ``error`` envelope; the collector fails exactly
those futures with :class:`NodeError` while the chain keeps serving.
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
import traceback
from collections import defaultdict, deque
from concurrent.futures import Future
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.partitioner import LinkModel, Partition, partition
from repro.runtime.node import _STOP, ComputeNode
from repro.runtime.wire import (BatchEnvelope, NodePlan, ReconfigMarker,
                                RowExtent, WireCodec, WireRecord, slice_parts)


class AdmissionFull(Exception):
    """The bounded admission queue is full, or the submitting client hit its
    in-flight quota (backpressure reached the client)."""


class NodeError(RuntimeError):
    """A request's batch failed inside a compute node; carries the remote
    traceback.  The node survives and keeps serving other requests."""


@dataclasses.dataclass
class DispatcherCodecs:
    """Per-payload-type codec choice (the paper's three socket configs)."""

    architecture: WireCodec = WireCodec("raw", "none")   # JSON spec, tiny
    weights: WireCodec = WireCodec("raw", "none")
    data: WireCodec = WireCodec("zfp", "none", zfp_rate=16)


class _WeightedAdmissionQueue:
    """Bounded admission queue with weighted-fair dequeue across priority
    bands.

    ``put`` files an item under its priority band (higher = more urgent)
    and applies the same bounded-capacity backpressure as a plain FIFO.
    ``get`` runs smooth weighted round-robin over the non-empty bands with
    weight ``priority + 1``: a priority-1 client is dequeued ~2x as often
    as a priority-0 client *when both are backlogged*, but low bands keep
    accumulating credit, so nothing starves.  Within a band, FIFO.

    ``put(_STOP)`` latches a stop flag instead of enqueueing, and ``get``
    surfaces _STOP only once every band is drained — the stop token can
    never overtake an admitted request (shutdown(drain=False) still
    completes in-flight work, exactly like the old FIFO)."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._bands: dict[int, deque] = {}
        self._credit: dict[int, float] = {}
        self._size = 0
        self._stopped = False
        self._mutex = threading.Lock()
        self._not_empty = threading.Condition(self._mutex)
        self._not_full = threading.Condition(self._mutex)

    def qsize(self) -> int:
        with self._mutex:
            return self._size

    def put(self, item: Any, block: bool = True,
            timeout: float | None = None, priority: int = 0) -> None:
        with self._not_full:
            if item is _STOP:
                self._stopped = True
                self._not_empty.notify_all()
                return
            if self._size >= self.maxsize:
                if not block or not self._not_full.wait_for(
                        lambda: self._size < self.maxsize, timeout=timeout):
                    raise queue.Full
            band = self._bands.setdefault(priority, deque())
            self._credit.setdefault(priority, 0.0)
            band.append(item)
            self._size += 1
            self._not_empty.notify()

    def get(self) -> Any:
        with self._not_empty:
            self._not_empty.wait_for(
                lambda: self._size > 0 or self._stopped)
            if self._size == 0:          # stopped AND fully drained
                return _STOP
            # smooth weighted round-robin: every backlogged band earns its
            # weight, the richest band is served and pays the round total
            total = 0.0
            for p, dq in self._bands.items():
                if dq:
                    w = max(1.0, p + 1.0)    # sub-zero priorities still run
                    self._credit[p] += w
                    total += w
            pick = max((p for p, dq in self._bands.items() if dq),
                       key=lambda p: (self._credit[p], p))
            self._credit[pick] -= total
            item = self._bands[pick].popleft()
            self._size -= 1
            self._not_full.notify()
            return item


class Dispatcher:
    """Owns the chain: planning, configuration, and the admission stream."""

    def __init__(self, graph: LayerGraph, num_nodes: int,
                 codecs: DispatcherCodecs | None = None,
                 strategy: str = "equal_layers",
                 link: LinkModel | None = None,
                 max_batch: int = 8,
                 admission_depth: int = 64,
                 queue_depth: int = 8,
                 staged: bool = True,
                 cuts: Sequence[int] | None = None,
                 client_quota: int | None = None,
                 shape_buckets: str = "exact",
                 max_batch_cap: int | None = None):
        self.graph = graph
        self.codecs = codecs or DispatcherCodecs()
        self.link = link
        self.partition: Partition = partition(
            graph, num_nodes, strategy=strategy, link=link, cuts=cuts)
        self.nodes: list[ComputeNode] = [
            ComputeNode(i, self.codecs.data, queue_depth=queue_depth,
                        max_batch=max_batch, staged=staged,
                        shape_buckets=shape_buckets,
                        max_batch_cap=max_batch_cap)
            for i in range(num_nodes)]
        self.config_records: list[WireRecord] = []
        self.result_queue: queue.Queue = queue.Queue()
        for i in range(num_nodes - 1):
            self.nodes[i].next_inbox = self.nodes[i + 1].inbox
        self.nodes[-1].next_inbox = self.result_queue

        self.admission = _WeightedAdmissionQueue(admission_depth)
        # per-client admission quota: max in-flight (admitted, unresolved)
        # requests per client_id; None = unlimited
        self.client_quota = client_quota
        self._client_inflight: dict[Any, int] = defaultdict(int)
        # windowed stats (cleared by reset_stats): dispatcher-side encode
        # records and admission->result latencies
        self.feed_records: list[WireRecord] = []
        self.latencies: list[float] = []
        self._futures: dict[int, Future] = {}
        self._next_id = 0
        self._client_seq: dict[Any, int] = defaultdict(int)
        self._inflight = 0
        self._admitting = 0        # registered but not yet on the admission q
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._pump_thread: threading.Thread | None = None
        self._collect_thread: threading.Thread | None = None
        self._configured = False
        self._started = False
        self._closed = False
        # live-repartition state: reconfigure() is serialized, the epoch
        # counts committed migrations, and the event acknowledges the
        # marker's arrival at the tail (chain-wide swap complete)
        self.epoch = 0
        self.reconfig_records: list[dict] = []
        self._params: dict[str, Any] | None = None
        self._reconfig_lock = threading.Lock()
        self._reconfig_event: threading.Event | None = None
        self._reconfig_expect = 0      # epoch the pending event waits for

    # -- configuration step --------------------------------------------------
    def configure(self, params: dict[str, Any]) -> None:
        """Ship each partition's architecture + weights over the wire."""
        for node, (lo, hi) in zip(self.nodes, self.partition.ranges()):
            names = [n.name for n in self.graph.slice_nodes(lo, hi)]
            spec = {"layers": names,
                    "next": node.index + 1 if node.index + 1 < len(self.nodes)
                    else None}
            arch_blob = json.dumps(spec).encode()
            t0 = time.perf_counter()
            if self.codecs.architecture.compression == "lz4":
                from repro.core.codecs import Lz4Codec
                arch_wire = Lz4Codec().compress(arch_blob)
            else:
                arch_wire = arch_blob
            t1 = time.perf_counter()
            self.config_records.append(WireRecord(
                "architecture", len(arch_blob), len(arch_wire), t1 - t0))

            stage_params = {name: params[name] for name in names}
            weights_blob, rec = self.codecs.weights.encode_tree(
                stage_params, "weights")
            self.config_records.append(rec)
            node.configure(self.graph, lo, hi, arch_blob, weights_blob,
                           self.codecs.weights)
        # the dispatcher owns the full model (paper setting): retained so a
        # live repartition can ship the weight DIFF of shifted layers only
        self._params = params
        self._configured = True

    def precompile(self) -> None:
        """Compile every batch-size specialization on every node up front
        (see :meth:`ComputeNode.precompile`)."""
        assert self._configured, "configure() before precompile()"
        for node in self.nodes:
            node.precompile()

    # -- distributed inference step -------------------------------------------
    def start(self) -> None:
        assert self._configured, "configure() before start()"
        if self._started:
            return
        self._started = True
        for node in self.nodes:
            node.start()
        self._pump_thread = threading.Thread(target=self._pump, daemon=True)
        self._pump_thread.start()
        self._collect_thread = threading.Thread(target=self._collect,
                                                daemon=True)
        self._collect_thread.start()

    def _pump(self) -> None:
        """Admission queue -> head of the chain (the dispatcher's outbound
        socket).  Keeping this off the caller thread means submit() returns
        as soon as the request is *admitted*, not relayed."""
        head = self.nodes[0].inbox
        while True:
            env = self.admission.get()
            if env is _STOP:
                head.put(_STOP)
                return
            head.put(env)

    def _collect(self) -> None:
        """Tail of the chain -> per-request futures (FIFO per client).

        One decode per tail envelope; per-request rows are sliced back out
        of the stacked payload by the envelope's row-extent framing."""
        while True:
            item = self.result_queue.get()
            if item is _STOP:
                return
            if isinstance(item, ReconfigMarker):
                # the epoch fence cleared the whole chain: every node
                # swapped.  Ack by epoch — a stale fence from an earlier
                # timed-out reconfigure must not acknowledge a later one
                ev = self._reconfig_event
                if ev is not None and item.epoch >= self._reconfig_expect:
                    ev.set()
                continue
            env: BatchEnvelope = item
            if env.error is not None:
                self._finish_batch(env.extents, error=env.error)
                continue
            try:
                flat, _ = self.codecs.data.decode_tree(env.blob)
                flat = {k: np.asarray(v) for k, v in flat.items()}
                parts = slice_parts(flat, env.extents)
            except Exception:               # codec failure at the tail
                self._finish_batch(env.extents, error=traceback.format_exc())
                continue
            results = [(next(iter(p.values())) if len(p) == 1 else p)
                       for p in parts]
            self._finish_batch(env.extents, results=results)

    def _finish_batch(self, extents: list[RowExtent],
                      results: list | None = None,
                      error: str | None = None) -> None:
        now = time.perf_counter()
        done: list[tuple[Future, Any]] = []
        with self._lock:
            for idx, ext in enumerate(extents):
                fut = self._futures.pop(ext.request_id, None)
                if fut is None:
                    continue
                if error is None:
                    # failures resolve fast by construction — mixing their
                    # time-to-failure into the percentiles would *improve*
                    # reported latency as the error rate rises
                    self.latencies.append(now - ext.t_submit)
                self._inflight -= 1
                self._client_inflight[ext.client_id] -= 1
                done.append((fut, results[idx] if results is not None
                             else None))
            self._idle.notify_all()
        for fut, res in done:
            if error is not None:
                fut.set_exception(NodeError(
                    f"request failed inside the chain:\n{error}"))
            else:
                fut.set_result(res)

    # -- admission --------------------------------------------------------------
    def submit(self, x: np.ndarray, client_id: Any = 0,
               block: bool = True, timeout: float | None = None,
               priority: int = 0) -> Future:
        """Admit one request.  Returns a Future resolving to the output.

        When the bounded admission queue is full, blocks (``block=True``)
        or raises :class:`AdmissionFull` — that is the backpressure a
        front-end needs to shed load instead of queuing unboundedly.  A
        client at its in-flight quota (``client_quota``) is refused
        immediately with :class:`AdmissionFull` regardless of ``block`` —
        one greedy client can no longer monopolize the admission queue.

        ``priority`` selects the admission band: the pump dequeues bands
        weighted-fair (weight ``priority + 1``), so higher-priority
        backlogged clients drain proportionally faster without starving
        priority 0.
        """
        if not self._started:
            self.start()
        fut: Future = Future()
        # one locked section registers the request: any submit that passed
        # the closed check is visible to shutdown() via _admitting/_inflight,
        # so _STOP can never overtake a registered envelope
        with self._lock:
            if self._closed:
                raise RuntimeError("dispatcher is shut down")
            if self.client_quota is not None \
                    and self._client_inflight[client_id] >= self.client_quota:
                raise AdmissionFull(
                    f"client {client_id!r} at quota "
                    f"({self.client_quota} in flight)")
            rid = self._next_id
            self._next_id += 1
            seq = self._client_seq[client_id]
            self._client_seq[client_id] += 1
            self._futures[rid] = fut
            self._inflight += 1
            self._client_inflight[client_id] += 1
            self._admitting += 1
        try:
            arr = np.asarray(x)
            blob, rec = self.codecs.data.encode_tree(
                {"": arr}, "data", request_id=rid, client_id=client_id)
            rows = int(arr.shape[0]) if arr.ndim else 1
            env = BatchEnvelope(
                [RowExtent(rid, client_id, seq, rows,
                           t_submit=time.perf_counter())], blob)
            with self._lock:
                self.feed_records.append(rec)
            self.admission.put(env, block=block, timeout=timeout,
                               priority=priority)
        except queue.Full:
            self._unregister(rid, client_id)
            raise AdmissionFull(
                f"admission queue full ({self.admission.maxsize} deep)")
        except BaseException:
            self._unregister(rid, client_id)
            raise
        with self._lock:
            self._admitting -= 1
            self._idle.notify_all()
        return fut

    def _unregister(self, rid: int, client_id: Any) -> None:
        """Roll back a registration whose envelope never reached admission."""
        with self._lock:
            self._futures.pop(rid, None)
            self._inflight -= 1
            self._client_inflight[client_id] -= 1
            self._admitting -= 1
            self._idle.notify_all()

    def infer_stream(self, inputs: Iterable[np.ndarray],
                     client_id: Any = 0) -> list[np.ndarray]:
        """Blocking shim over submit(): feed all samples, collect in
        submission order (FIFO for this client by construction)."""
        futures = [self.submit(x, client_id=client_id) for x in inputs]
        return [f.result() for f in futures]

    # -- live reconfiguration (the controller's commit path) -------------------
    def reconfigure(self, cuts: Sequence[int],
                    timeout: float | None = 60.0) -> dict:
        """Hot-migrate partition boundaries on the RUNNING chain.

        Two-phase: (1) PREPARE — for each node whose range changes, build a
        :class:`NodePlan` carrying its new architecture spec and the wire-
        encoded weights of only the layers it GAINS (the weight diff; kept
        layers are reused in place); (2) COMMIT — inject one
        :class:`ReconfigMarker` at the head of the chain.  The marker rides
        the same FIFO queues as data envelopes, so each node swaps exactly
        when the fence passes its compute stage: every in-flight request is
        processed by a consistent partition end-to-end and none is dropped
        or recomputed.  Blocks until the tail collector acknowledges the
        fence (or ``timeout``).

        The fence rides in-process FIFO queues, so it cannot be lost: an
        un-acknowledged return (``acknowledged: False``) means the marker
        is still behind a backlog, not that the migration failed — the
        nodes WILL adopt the committed cuts when it clears, which is why
        ``partition``/``epoch`` are updated to the committed target either
        way.  Callers treat un-acked as migration-in-progress (the
        controller skips its post-swap precompile and rebaselines its
        telemetry window).

        Returns a summary record (also appended to ``reconfig_records``).
        """
        assert self._configured and self._params is not None, \
            "configure() before reconfigure()"
        assert self._started, "reconfigure() fences a running chain"
        with self._reconfig_lock:
            new_bounds = [0, *sorted(int(c) for c in cuts),
                          len(self.graph.nodes)]
            new_ranges = list(zip(new_bounds, new_bounds[1:]))
            old_ranges = [tuple(r) for r in self.partition.ranges()]
            if len(new_ranges) != len(self.nodes):
                raise ValueError(
                    f"cuts {tuple(cuts)} give {len(new_ranges)} stages for "
                    f"{len(self.nodes)} nodes")
            if any(hi <= lo for lo, hi in new_ranges):
                raise ValueError(f"cuts {tuple(cuts)} leave an empty stage")
            if [tuple(r) for r in new_ranges] == old_ranges:
                return {"epoch": self.epoch, "changed": False}

            epoch = self.epoch + 1
            plans: dict[int, NodePlan] = {}
            shipped = 0
            moved_layers = 0
            for i, ((lo, hi), (lo2, hi2)) in enumerate(
                    zip(old_ranges, new_ranges)):
                if (lo, hi) == (lo2, hi2):
                    continue               # untouched node: no plan, no bytes
                names = [n.name for n in self.graph.slice_nodes(lo2, hi2)]
                kept = {n.name for n in self.graph.slice_nodes(lo, hi)}
                gained = [nm for nm in names if nm not in kept]
                moved_layers += len(gained)
                spec = {"layers": names,
                        "next": i + 1 if i + 1 < len(self.nodes) else None}
                arch_blob = json.dumps(spec).encode()
                weights_blob = b""
                if gained:
                    weights_blob, rec = self.codecs.weights.encode_tree(
                        {nm: self._params[nm] for nm in gained}, "weights")
                    self.config_records.append(rec)
                plans[i] = NodePlan(lo2, hi2, arch_blob, weights_blob,
                                    self.codecs.weights,
                                    wire_bytes=len(arch_blob)
                                    + len(weights_blob))
                shipped += plans[i].wire_bytes

            ev = threading.Event()
            self._reconfig_expect = epoch
            self._reconfig_event = ev
            t0 = time.perf_counter()
            # the fence enters the head node's inbox like any envelope and
            # stays ordered behind everything already pumped
            self.nodes[0].inbox.put(ReconfigMarker(epoch, plans))
            acked = ev.wait(timeout)
            self._reconfig_event = None
            self.partition = partition(self.graph, len(self.nodes),
                                       link=self.link, cuts=new_bounds[1:-1])
            self.epoch = epoch
            record = {
                "epoch": epoch, "changed": True, "acknowledged": acked,
                "cuts": tuple(new_bounds[1:-1]),
                "moved_layers": moved_layers,
                "shipped_bytes": shipped,
                "migrate_s": time.perf_counter() - t0,
                "nodes_touched": sorted(plans),
            }
            self.reconfig_records.append(record)
            return record

    def set_node_knobs(self, index: int, max_batch: int | None = None,
                       coalesce_s: float | None = None) -> None:
        """Retune one node's serving knobs live (controller's actuator).
        ``max_batch`` is clamped to [1, max_batch_cap] so precompiled batch
        specializations stay authoritative."""
        node = self.nodes[index]
        if max_batch is not None:
            node.max_batch = min(max(1, int(max_batch)), node.max_batch_cap)
        if coalesce_s is not None:
            node.coalesce_s = max(0.0, float(coalesce_s))

    # -- teardown ---------------------------------------------------------------
    def drain(self, timeout: float | None = None) -> bool:
        """Block until no request is in flight.  True if drained."""
        with self._idle:
            return self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout)

    def reset_stats(self) -> None:
        with self._lock:
            self.latencies = []
            self.feed_records = []
        for node in self.nodes:
            node.reset_stats()

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Stop accepting requests; by default let in-flight ones finish.

        The _STOP token trails every admitted envelope through the FIFO
        chain, so even ``drain=False`` completes (not cancels) in-flight
        requests — drain merely waits for the results before teardown.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if not self._started:
            return
        # never let _STOP overtake a request that already passed the closed
        # check but has not reached the admission queue yet
        with self._idle:
            self._idle.wait_for(lambda: self._admitting == 0,
                                timeout=timeout)
        if drain:
            self.drain(timeout=timeout)
        self.admission.put(_STOP)
        if self._pump_thread:
            self._pump_thread.join()
        for node in self.nodes:
            node.join()
        if self._collect_thread:
            self._collect_thread.join()
