"""The DEFER dispatcher (paper Algorithm 1), topology-first, in-process.

The dispatcher builds whatever a :class:`~repro.runtime.topology.TopologySpec`
declares — stages x replicas x transports — instead of the original
hard-wired linear chain.  It partitions the model, ships architecture +
weights to every replica of every stage (configuration step), then serves a
*multi-client* inference stream: a bounded admission queue applies
backpressure at the front door, a pump thread feeds the first stage's
router, each stage's router spreads whole batches across its replicas
(round-robin or least-queue-depth), and a collector thread decodes each
tail envelope ONCE, slices per-request rows back out, and resolves the
per-request futures through a **sequence-numbered merge**: results are
released strictly in each client's submission order, so FIFO-per-client
holds even when replicated stages complete batches out of order (the
batching chain may still legally reorder across clients).  A batch that
failed inside a node arrives as an ``error`` envelope; the collector fails
exactly those futures with :class:`NodeError` while the chain keeps
serving.

Live mutation rides one mechanism, the epoch fence
(:class:`~repro.runtime.wire.ReconfigMarker` + per-stage router barriers):

* :meth:`reconfigure` moves the partition boundaries (weight-diff
  shipping, all replicas of a stage swap at the fence), and
* :meth:`scale` grows or drains a stage's replica count — spawn = ship
  the stage's weights to fresh replicas and fence them into the routing
  set; drain = fence them out, let them flush, retire.

Both guarantee zero dropped, duplicated, or per-client-reordered
responses; both are what the serving controller actuates.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import queue
import threading
import time
import traceback
from collections import defaultdict, deque
from concurrent.futures import Future, InvalidStateError
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.partitioner import LinkModel, Partition, partition
from repro.runtime.node import _STOP, ComputeNode
from repro.runtime.router import FenceTally, StageGroup
from repro.runtime.topology import TopologySpec
from repro.runtime.transport import Channel, ChannelClosed, get_transport
from repro.runtime.wire import (BatchEnvelope, NodePlan, ReconfigMarker,
                                RowExtent, WireCodec, WireRecord,
                                slice_parts, validate_client_id)


class AdmissionFull(Exception):
    """The bounded admission queue is full, or the submitting client hit its
    in-flight quota (backpressure reached the client)."""


class NodeError(RuntimeError):
    """A request's batch failed inside a compute node; carries the remote
    traceback.  The node survives and keeps serving other requests."""


class DeadlineExceeded(RuntimeError):
    """A request's end-to-end deadline (``submit(deadline_s=...)``) expired
    before its result was released to the client.  The future fails with
    this; any late result arriving afterwards is dropped by the collector's
    at-most-once rule, never delivered."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Replay policy for infrastructure failures (the request-reliability
    layer).  With a policy set, the dispatcher retains each in-flight
    request's encoded input and re-admits requests stranded by a replica
    crash / severed link / dead tail under an incremented ``attempt`` tag
    — application errors raised by user ``apply`` code are NEVER retried.

    ``max_attempts`` bounds TOTAL attempts (first admission included).
    ``backoff_s`` delays re-admission by ``backoff_s * backoff_factor **
    (attempt - 1)`` so a heal (respawn, rerouted link) has time to land.
    ``retry_budget`` is a token bucket (capacity ``retry_budget`` tokens,
    refilling at ``refill_per_s``): every replay spends one token, and
    when the bucket is dry the dispatcher degrades gracefully back to the
    PR 7 fail-fast semantics instead of amplifying a crash storm."""

    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    retry_budget: float = 32.0
    refill_per_s: float = 8.0


@dataclasses.dataclass
class ReplayStats:
    """Counters for the reliability layer (windowless, monotonic)."""

    replays: int = 0             # re-admissions actually scheduled
    stale_failures: int = 0      # failure reports for a superseded attempt
    budget_denied: int = 0       # replays refused: token bucket dry
    attempts_exhausted: int = 0  # replays refused: max_attempts reached
    deadline_denied: int = 0     # replays refused: not enough deadline left
    deadlines_expired: int = 0   # futures failed with DeadlineExceeded
    tail_revives: int = 0        # result channel rebuilt after a tail death


class _Retained:
    """Everything needed to re-admit one in-flight request: its encoded
    input blob plus the admission metadata.  ``attempt`` is the attempt
    currently in flight; a failure report carrying an older attempt is
    stale and absorbed without action."""

    __slots__ = ("blob", "client_id", "seq", "rows", "priority",
                 "t_submit", "deadline", "deadline_s", "attempt")

    def __init__(self, blob: bytes, client_id: Any, seq: int, rows: int,
                 priority: int, t_submit: float,
                 deadline: float | None, deadline_s: float | None):
        self.blob = blob
        self.client_id = client_id
        self.seq = seq
        self.rows = rows
        self.priority = priority
        self.t_submit = t_submit
        self.deadline = deadline        # monotonic-clock expiry, or None
        self.deadline_s = deadline_s    # original budget (error messages)
        self.attempt = 0


@dataclasses.dataclass
class DispatcherCodecs:
    """Per-payload-type codec choice (the paper's three socket configs)."""

    architecture: WireCodec = WireCodec("raw", "none")   # JSON spec, tiny
    weights: WireCodec = WireCodec("raw", "none")
    data: WireCodec = WireCodec("zfp", "none", zfp_rate=16)


class _WeightedAdmissionQueue:
    """Bounded admission queue with weighted-fair dequeue across priority
    bands.

    ``put`` files an item under its priority band (higher = more urgent)
    and applies the same bounded-capacity backpressure as a plain FIFO.
    ``get`` runs smooth weighted round-robin over the non-empty bands with
    weight ``priority + 1``: a priority-1 client is dequeued ~2x as often
    as a priority-0 client *when both are backlogged*, but low bands keep
    accumulating credit, so nothing starves.  Within a band, FIFO.

    ``put(_STOP)`` latches a stop flag instead of enqueueing, and ``get``
    surfaces _STOP only once every band is drained — the stop token can
    never overtake an admitted request (shutdown(drain=False) still
    completes in-flight work, exactly like the old FIFO)."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._bands: dict[int, deque] = {}
        self._credit: dict[int, float] = {}
        self._size = 0
        self._stopped = False
        self._mutex = threading.Lock()
        self._not_empty = threading.Condition(self._mutex)
        self._not_full = threading.Condition(self._mutex)

    def qsize(self) -> int:
        with self._mutex:
            return self._size

    def put(self, item: Any, block: bool = True,
            timeout: float | None = None, priority: int = 0) -> None:
        with self._not_full:
            if item is _STOP:
                self._stopped = True
                self._not_empty.notify_all()
                return
            if self._size >= self.maxsize:
                if not block or not self._not_full.wait_for(
                        lambda: self._size < self.maxsize, timeout=timeout):
                    raise queue.Full
            band = self._bands.setdefault(priority, deque())
            self._credit.setdefault(priority, 0.0)
            band.append(item)
            self._size += 1
            self._not_empty.notify()

    def get(self) -> Any:
        with self._not_empty:
            self._not_empty.wait_for(
                lambda: self._size > 0 or self._stopped)
            if self._size == 0:          # stopped AND fully drained
                return _STOP
            # smooth weighted round-robin: every backlogged band earns its
            # weight, the richest band is served and pays the round total
            total = 0.0
            for p, dq in self._bands.items():
                if dq:
                    w = max(1.0, p + 1.0)    # sub-zero priorities still run
                    self._credit[p] += w
                    total += w
            pick = max((p for p, dq in self._bands.items() if dq),
                       key=lambda p: (self._credit[p], p))
            self._credit[pick] -= total
            item = self._bands[pick].popleft()
            self._size -= 1
            self._not_full.notify()
            return item


class Dispatcher:
    """Owns the topology: planning, configuration, routing, and the
    admission stream."""

    def __init__(self, graph: LayerGraph, topology: TopologySpec,
                 codecs: DispatcherCodecs | None = None,
                 link: LinkModel | None = None,
                 max_batch: int = 8,
                 admission_depth: int = 64,
                 queue_depth: int = 8,
                 staged: bool = True,
                 client_quota: int | None = None,
                 shape_buckets: str = "exact",
                 max_batch_cap: int | None = None,
                 replica_factory=None,
                 retry_policy: RetryPolicy | None = None):
        if isinstance(topology, int):
            topology = TopologySpec.chain(graph, topology)
        topology.validate(graph)
        self.graph = graph
        self.topology = topology
        self.codecs = codecs or DispatcherCodecs()
        self.link = link
        self._defaults = dict(max_batch=max_batch, queue_depth=queue_depth,
                              staged=staged, shape_buckets=shape_buckets,
                              max_batch_cap=max_batch_cap)
        # optional replica provider: (dispatcher, stage, replica) -> a
        # ComputeNode-shaped object, or None to fall back to the in-process
        # default.  The process-per-replica supervisor plugs in here so
        # spawn (__init__ AND scale) builds worker-backed replicas through
        # the same path as in-process ones.
        self._replica_factory = replica_factory
        self.partition: Partition = partition(
            graph, topology.num_stages,
            link=link, cuts=list(topology.cuts) or None,
            replicas=topology.replicas)

        # wiring: per stage, an input channel (fed by the pump or by the
        # previous stage's replicas) and a router spreading it across the
        # stage's replicas; the last stage feeds the collector's channel.
        # Every channel this dispatcher opens is tracked so shutdown can
        # close it — returning it to its transport's live count (a
        # re-registration of the transport name is refused while channels
        # are live) and releasing socket/link resources
        self._channels: list[Channel] = []
        self._stage_inputs: list[Channel] = [
            self._open_channel(s.transport, queue_depth)
            for s in topology.stages]
        self.result_channel: Channel = self._open_channel(
            topology.stages[-1].transport, 0)
        self.stages: list[StageGroup] = []
        for i, spec in enumerate(topology.stages):
            replicas = [self._make_replica(i, r)
                        for r in range(spec.replicas)]
            group = StageGroup(i, spec, replicas, self._stage_inputs[i],
                               upstream=self.stages[i - 1] if i else None,
                               fail_batch=self._finish_batch,
                               note_displaced=self._note_displaced)
            self.stages.append(group)
        for i, group in enumerate(self.stages):
            nxt = (self._stage_inputs[i + 1] if i + 1 < len(self.stages)
                   else self.result_channel)
            for node in group.replicas:
                node.next_inbox = nxt

        self.config_records: list[WireRecord] = []
        self.admission = _WeightedAdmissionQueue(admission_depth)
        # per-client admission quota: max in-flight (admitted, unresolved)
        # requests per client_id; None = unlimited
        self.client_quota = client_quota
        self._client_inflight: dict[Any, int] = defaultdict(int)
        # windowed stats (cleared by reset_stats): dispatcher-side encode
        # records and admission->result latencies
        self.feed_records: list[WireRecord] = []
        self.latencies: list[float] = []
        self._futures: dict[int, Future] = {}
        self._next_id = 0
        self._client_seq: dict[Any, int] = defaultdict(int)
        # the sequenced merge: per client, results arriving out of
        # submission order (replicated stages complete out of order) are
        # held and released strictly by seq, so per-client responses are
        # never reordered; seqs whose submit failed before admission are
        # cancelled so the merge never stalls on a hole
        self._client_next: dict[Any, int] = defaultdict(int)
        self._client_hold: dict[Any, dict[int, tuple]] = defaultdict(dict)
        self._client_cancel: dict[Any, set[int]] = defaultdict(set)
        self._inflight = 0
        self._admitting = 0        # registered but not yet on the admission q
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        # request-reliability layer: input retention + replay + deadlines.
        # All timing here is MONOTONIC clock (deferlint DL103): deadlines
        # and backoff must never jump with wall-clock adjustments.
        self.retry_policy = retry_policy
        self.replay_stats = ReplayStats()
        self._retained: dict[int, _Retained] = {}
        self._retry_tokens = (float(retry_policy.retry_budget)
                              if retry_policy else 0.0)
        self._retry_refill_t = time.monotonic()
        # one timer thread services both event kinds off a heap of
        # (due_monotonic, kind, rid, attempt); kind 0 = deadline expiry,
        # kind 1 = backoff-delayed replay re-admission.  Started lazily on
        # the first submit that needs it; joined by shutdown.
        self._timer_heap: list[tuple[float, int, int, int]] = []
        self._timer_cv = threading.Condition(self._lock)
        self._reaper_thread: threading.Thread | None = None
        self._reaper_stop = False
        self._pump_thread: threading.Thread | None = None
        self._collect_thread: threading.Thread | None = None
        self._configured = False
        self._started = False
        self._closed = False
        self._tail_dead = False        # set when the result channel dies
        # live-mutation state: reconfigure()/scale() are serialized, the
        # epoch counts committed fences, and the event acknowledges the
        # fence barrier completing at the tail (chain-wide swap done)
        self.epoch = 0
        self.reconfig_records: list[dict] = []
        self._params: dict[str, Any] | None = None
        self._reconfig_lock = threading.Lock()
        self._reconfig_event: threading.Event | None = None
        self._reconfig_expect = 0      # epoch the pending event waits for
        # tail barrier state (collector thread only): the collector is the
        # degenerate downstream consumer of the last stage, sharing the
        # routers' FenceTally accounting
        self._tail = FenceTally(len(self.stages[-1].replicas))
        # decode-session bookkeeping: active session ids (registered by the
        # generate loop, unregistered on close — the per-client-GC
        # precedent, so ephemeral sessions can't grow this without bound)
        # and the displaced set (sessions whose sticky replica was drained
        # or died; the generate loop pops its id and re-prefills before the
        # next step instead of burning a step on a guaranteed SessionLost)
        self._active_sessions: set = set()
        self._displaced_sessions: set = set()

    def _open_channel(self, transport: str, capacity: int) -> Channel:
        ch = get_transport(transport).channel(capacity)
        self._channels.append(ch)
        return ch

    def _make_node(self, stage: int, replica: int) -> ComputeNode:
        """One replica of one stage, with the stage spec's overrides
        applied over the engine-wide defaults."""
        spec = self.topology.stages[stage]
        d = self._defaults
        node = ComputeNode(
            stage, self.codecs.data, replica=replica,
            queue_depth=d["queue_depth"],
            max_batch=spec.max_batch or d["max_batch"],
            staged=d["staged"],
            shape_buckets=spec.shape_buckets or d["shape_buckets"],
            max_batch_cap=spec.max_batch_cap or d["max_batch_cap"],
            inbox=self._open_channel(spec.transport, d["queue_depth"]),
            session_capacity=spec.session_capacity or 64)
        if spec.coalesce_s is not None:
            node.coalesce_s = spec.coalesce_s
        return node

    def _make_replica(self, stage: int, replica: int) -> ComputeNode:
        """One replica via the pluggable factory (process-backed workers)
        or the in-process default.  A factory may return None for stages
        it does not manage."""
        if self._replica_factory is not None:
            node = self._replica_factory(self, stage, replica)
            if node is not None:
                return node
        return self._make_node(stage, replica)

    @property
    def nodes(self) -> list[ComputeNode]:
        """Every live replica, stage-major (stats/report convenience);
        prunes dead retirees as a side effect (see live_replicas)."""
        return [r for g in self.stages for r in g.live_replicas()]

    @property
    def replicas(self) -> tuple[int, ...]:
        return tuple(len(g.live_replicas()) for g in self.stages)

    # -- configuration step --------------------------------------------------
    def _stage_blobs(self, stage: int, lo: int, hi: int,
                     record: bool = True) -> tuple[bytes, bytes]:
        """Wire-encode one stage's architecture spec + full weights."""
        names = [n.name for n in self.graph.slice_nodes(lo, hi)]
        spec = {"layers": names,
                "next": stage + 1 if stage + 1 < len(self.stages) else None}
        arch_blob = json.dumps(spec).encode()
        t0 = time.perf_counter()
        if self.codecs.architecture.compression == "lz4":
            from repro.core.codecs import Lz4Codec
            arch_wire = Lz4Codec().compress(arch_blob)
        else:
            arch_wire = arch_blob
        t1 = time.perf_counter()
        if record:
            self.config_records.append(WireRecord(
                "architecture", len(arch_blob), len(arch_wire), t1 - t0))
        stage_params = {name: self._params[name] for name in names}
        weights_blob, rec = self.codecs.weights.encode_tree(
            stage_params, "weights")
        if record:
            self.config_records.append(rec)
        return arch_blob, weights_blob

    def configure(self, params: dict[str, Any]) -> None:
        """Ship each stage's architecture + weights over the wire — once
        per replica (each replica holds the full stage)."""
        # the dispatcher owns the full model (paper setting): retained so a
        # live repartition can ship the weight DIFF of shifted layers only,
        # and so scale() can configure freshly spawned replicas
        self._params = params
        for group, (lo, hi) in zip(self.stages, self.partition.ranges()):
            arch_blob, weights_blob = self._stage_blobs(group.index, lo, hi)
            for node in group.replicas:
                node.configure(self.graph, lo, hi, arch_blob, weights_blob,
                               self.codecs.weights)
        self._configured = True

    def precompile(self) -> None:
        """Compile every batch-size specialization on every replica up
        front (see :meth:`ComputeNode.precompile`)."""
        assert self._configured, "configure() before precompile()"
        for node in self.nodes:
            node.precompile()

    # -- distributed inference step -------------------------------------------
    def start(self) -> None:
        assert self._configured, "configure() before start()"
        if self._started:
            return
        self._started = True
        for node in self.nodes:
            node.start()
        for group in self.stages:
            group.start()
        self._pump_thread = threading.Thread(target=self._pump, daemon=True)
        self._pump_thread.start()
        self._collect_thread = threading.Thread(target=self._collect,
                                                daemon=True)
        self._collect_thread.start()

    def _pump(self) -> None:
        """Admission queue -> first stage's router (the dispatcher's
        outbound socket).  Keeping this off the caller thread means
        submit() returns as soon as the request is *admitted*, not
        relayed."""
        head = self._stage_inputs[0]
        while True:
            env = self.admission.get()
            if env is _STOP:
                try:
                    head.send(_STOP)
                except (ChannelClosed, OSError):
                    pass                # head link dead: nothing to stop
                return
            try:
                head.send(env)
            except (ChannelClosed, OSError):
                # dead head link: an infrastructure failure — the replay
                # layer may re-admit once the chain heals.  Keep pumping
                # (mirrors the router's per-batch isolation).
                self._finish_batch(env.extents, error=traceback.format_exc(),
                                   retryable=True)
            except Exception:
                # anything else (encode/framing bug) is not healable
                self._finish_batch(env.extents, error=traceback.format_exc())

    def _collect(self) -> None:
        """Tail of the topology -> per-request futures, released in
        per-client seq order by the sequenced merge.

        One decode per tail envelope; per-request rows are sliced back out
        of the stacked payload by the envelope's row-extent framing.  The
        collector is also the tail end of every fence: it completes the
        marker barrier over the last stage's replicas and acknowledges the
        epoch chain-wide."""
        while True:
            try:
                item = self.result_channel.recv()
            except ChannelClosed:
                # tail link dead.  With a retry policy, rebuild the tail
                # channel in place and replay what was in flight (the
                # un-bricking path); without one, no result can ever
                # arrive again — fail every unresolved future NOW (a
                # silent return would hang every blocked client and
                # shutdown's drain forever) and refuse new admissions
                if self._try_revive_tail():
                    continue
                self._fail_all_pending(
                    "result channel closed: the chain's tail link died")
                return
            if item is _STOP:
                if self._tail.on_stop():
                    if not self._closed:
                        # a stop cascade the dispatcher did not initiate:
                        # a mid-chain link died and its router flushed the
                        # chain out.  Everything still in flight is
                        # undeliverable — fail it (and further submits)
                        # instead of exiting with clients left hanging
                        self._fail_all_pending(
                            "the chain stopped unexpectedly (a mid-chain "
                            "link died); request undeliverable")
                    return
                continue
            if isinstance(item, ReconfigMarker):
                e = item.epoch
                if not self._tail.on_marker(e, self.stages[-1]):
                    continue
                # the epoch fence cleared the whole topology: every replica
                # of every stage swapped.  Ack by epoch — a stale fence
                # from an earlier timed-out mutation must not acknowledge
                # a later one
                ev = self._reconfig_event
                if ev is not None and e >= self._reconfig_expect:
                    ev.set()
                if self._tail.stopped:
                    # shutdown raced an in-flight drain fence of the last
                    # stage (see FenceTally): the retired replica never
                    # stops, so the last live stop may precede this fence
                    if not self._closed:
                        self._fail_all_pending(
                            "the chain stopped unexpectedly (a mid-chain "
                            "link died); request undeliverable")
                    return
                continue
            env: BatchEnvelope = item
            if env.error is not None:
                self._finish_batch(env.extents, error=env.error,
                                   retryable=env.retryable)
                continue
            try:
                flat, _ = self.codecs.data.decode_tree(env.blob)
                flat = {k: np.asarray(v) for k, v in flat.items()}
                parts = slice_parts(flat, env.extents)
            except Exception:               # codec failure at the tail
                self._finish_batch(env.extents, error=traceback.format_exc())
                continue
            results = [(next(iter(p.values())) if len(p) == 1 else p)
                       for p in parts]
            self._finish_batch(env.extents, results=results)

    def _release_locked(self, client: Any, now: float) -> list[tuple]:
        """Pop every in-order (by seq) completed result for ``client``.
        Caller holds ``_lock``; resolves futures AFTER dropping it."""
        out: list[tuple] = []
        nxt = self._client_next[client]
        hold = self._client_hold[client]
        cancel = self._client_cancel[client]
        while True:
            if nxt in cancel:               # submit failed pre-admission:
                cancel.discard(nxt)         # the hole is not a lost result
                nxt += 1
                continue
            entry = hold.pop(nxt, None)
            if entry is None:
                break
            fut, res, err, ext = entry
            if err is None:
                # failures resolve fast by construction — mixing their
                # time-to-failure into the percentiles would *improve*
                # reported latency as the error rate rises
                self.latencies.append(now - ext.t_submit)
            self._inflight -= 1
            self._client_inflight[client] -= 1
            out.append((fut, res, err))
            nxt += 1
        self._client_next[client] = nxt
        if (self._client_inflight.get(client, 0) == 0 and not hold
                and not cancel):
            # idle client fully drained: drop its merge/quota/seq state so
            # ephemeral client ids (per-request UUIDs) can't grow these
            # maps without bound.  Seq and next are dropped TOGETHER — a
            # returning client restarts a consistent fresh sequence.
            for m in (self._client_hold, self._client_cancel,
                      self._client_next, self._client_seq,
                      self._client_inflight):
                m.pop(client, None)
        if out:
            self._idle.notify_all()
        return out

    @staticmethod
    def _resolve(done: list[tuple]) -> None:
        """Resolve released futures — called OUTSIDE the lock.  First-wins
        is structural: each rid's future is popped from ``_futures``
        exactly once, so a second resolution attempt cannot reach here."""
        for fut, res, err in done:
            if err is not None:
                exc = (err if isinstance(err, BaseException)
                       else NodeError(
                           f"request failed inside the chain:\n{err}"))
                fut.set_exception(exc)
            else:
                fut.set_result(res)

    def _fail_all_pending(self, reason: str) -> None:
        """Terminal failure path: the chain can no longer deliver results
        (tail link dead).  Every unresolved future — registered or held
        in the sequenced merge — fails with :class:`NodeError`, the merge
        state is cleared so ``drain``/``shutdown`` complete, and further
        submits are refused."""
        with self._lock:
            self._tail_dead = True
            failed = list(self._futures.values())
            self._futures.clear()
            for hold in self._client_hold.values():
                failed.extend(entry[0] for entry in hold.values())
            self._client_hold.clear()
            self._client_cancel.clear()
            self._client_next.clear()
            self._client_seq.clear()
            self._client_inflight.clear()
            self._inflight = 0
            self._retained.clear()
            self._timer_heap.clear()
            self._timer_cv.notify_all()
            self._idle.notify_all()
        for fut in failed:
            try:
                fut.set_exception(NodeError(reason))
            except InvalidStateError:
                pass                    # already resolved: nothing owed

    def _finish_batch(self, extents: list[RowExtent],
                      results: list | None = None,
                      error: str | BaseException | None = None,
                      retryable: bool = False) -> None:
        now = time.perf_counter()
        done: list[tuple] = []
        with self._lock:
            for idx, ext in enumerate(extents):
                if (error is not None and retryable
                        and self._absorb_failure_locked(ext)):
                    continue            # replay scheduled (or stale report)
                fut = self._futures.pop(ext.request_id, None)
                if fut is None:
                    continue            # at-most-once: already resolved
                self._retained.pop(ext.request_id, None)
                self._client_hold[ext.client_id][ext.seq] = (
                    fut, results[idx] if results is not None else None,
                    error, ext)
                done.extend(self._release_locked(ext.client_id, now))
        self._resolve(done)

    # -- request reliability: replay + deadlines --------------------------------
    def _absorb_failure_locked(self, ext: RowExtent) -> bool:
        """Decide one retryable failure's fate.  Caller holds ``_lock``.

        True means the failure is absorbed — either a replay was scheduled
        under an incremented attempt, or the report is stale (it names an
        attempt the dispatcher already superseded).  False means replay is
        refused (no policy, exhausted attempts, deadline too close, token
        bucket dry, shutting down) and the caller fails the future — the
        graceful degradation back to PR 7 fail-fast semantics."""
        pol = self.retry_policy
        if pol is None or self._closed or self._tail_dead:
            return False
        rec = self._retained.get(ext.request_id)
        if rec is None or not rec.blob:
            # nothing retained to replay (deadline-only metadata, or a
            # session step whose recovery belongs to the session layer)
            return False
        if ext.attempt != rec.attempt:
            # a failure report for an earlier attempt of a request that
            # was already re-admitted: the live attempt owns the outcome
            self.replay_stats.stale_failures += 1
            return True
        if rec.attempt + 1 >= pol.max_attempts:
            self.replay_stats.attempts_exhausted += 1
            return False
        backoff = pol.backoff_s * pol.backoff_factor ** rec.attempt
        if rec.deadline is not None and (
                time.monotonic() + backoff + self._latency_est_locked()
                >= rec.deadline):
            # not enough deadline budget left for another chain traversal:
            # fail now rather than burn a token on a doomed replay
            self.replay_stats.deadline_denied += 1
            return False
        if not self._take_retry_token_locked():
            self.replay_stats.budget_denied += 1
            return False
        rec.attempt += 1
        self.replay_stats.replays += 1
        heapq.heappush(self._timer_heap,
                       (time.monotonic() + backoff, 1,
                        ext.request_id, rec.attempt))
        self._ensure_reaper_locked()
        self._timer_cv.notify()
        return True

    def _take_retry_token_locked(self) -> bool:
        """Token bucket: one token per replay, refilled continuously."""
        pol = self.retry_policy
        now = time.monotonic()
        self._retry_tokens = min(
            float(pol.retry_budget),
            self._retry_tokens + (now - self._retry_refill_t)
            * pol.refill_per_s)
        self._retry_refill_t = now
        if self._retry_tokens < 1.0:
            return False
        self._retry_tokens -= 1.0
        return True

    def _latency_est_locked(self) -> float:
        """Calibrated end-to-end chain latency (median of the stats
        window) — the replay/deadline arbiter's cost model."""
        if not self.latencies:
            return 0.0
        return float(np.median(self.latencies[-256:]))

    def _ensure_reaper_locked(self) -> None:
        if self._reaper_thread is None and not self._reaper_stop:
            self._reaper_thread = threading.Thread(target=self._reaper,
                                                   daemon=True)
            self._reaper_thread.start()

    def _reaper(self) -> None:
        """Timer thread: fires deadline expiries and backoff-delayed
        replays off the monotonic-clock heap.  One thread serves both so
        ordering between a deadline and a replay of the same request is a
        heap comparison, not a thread race."""
        while True:
            with self._lock:
                while True:
                    if self._reaper_stop:
                        return
                    if self._timer_heap:
                        wait = self._timer_heap[0][0] - time.monotonic()
                        if wait <= 0:
                            break
                        self._timer_cv.wait(timeout=wait)
                    else:
                        self._timer_cv.wait()
                due, kind, rid, attempt = heapq.heappop(self._timer_heap)
            if kind == 0:
                self._expire_deadline(rid)
            else:
                self._replay_now(rid, attempt)

    def _expire_deadline(self, rid: int) -> None:
        """Fail one request with DeadlineExceeded — routed through the
        sequenced merge (NOT a bare set_exception) so the client's seq
        stream has no hole and later responses still release."""
        with self._lock:
            rec = self._retained.get(rid)
            if rec is None or rid not in self._futures:
                return                  # already resolved / cancelled
            ext = RowExtent(rid, rec.client_id, rec.seq, rec.rows,
                            t_submit=rec.t_submit, attempt=rec.attempt)
            self.replay_stats.deadlines_expired += 1
        self._finish_batch([ext], error=DeadlineExceeded(
            f"request {rid} missed its {rec.deadline_s:.3g}s deadline; "
            "any late result will be dropped, not delivered"))

    def _replay_now(self, rid: int, attempt: int) -> None:
        """Re-admit one stranded request through the NORMAL admission
        path (FIFO-per-client and the sequenced merge hold: the request
        keeps its original client_id/seq, only ``attempt`` moves)."""
        with self._lock:
            rec = self._retained.get(rid)
            if rec is None or rid not in self._futures \
                    or rec.attempt != attempt:
                return                  # resolved or superseded meanwhile
            if self._closed or self._tail_dead:
                abandon = True
            else:
                abandon = False
                # shutdown waits for _admitting == 0 before latching _STOP,
                # so a replay mid-put cannot be overtaken by the stop token
                self._admitting += 1
        if abandon:
            self._finish_batch([RowExtent(rid, rec.client_id, rec.seq,
                                          rec.rows, t_submit=rec.t_submit,
                                          attempt=rec.attempt)],
                               error="replay abandoned: dispatcher "
                                     "shutting down")
            return
        env = BatchEnvelope(
            [RowExtent(rid, rec.client_id, rec.seq, rec.rows,
                       t_submit=rec.t_submit, attempt=rec.attempt)],
            rec.blob)
        try:
            self.admission.put(env, block=True, timeout=5.0,
                               priority=rec.priority)
        except queue.Full:
            self._finish_batch(env.extents,
                               error="replay re-admission refused "
                                     "(admission queue full)")
        finally:
            with self._lock:
                self._admitting -= 1
                self._idle.notify_all()

    def _try_revive_tail(self) -> bool:
        """Un-brick a dead tail: open a fresh result channel, re-point the
        last stage's replicas at it, and push every in-flight request back
        through the replay arbiter.  Only with a retry policy — without
        one the PR 7 fail-fast path (``_fail_all_pending``) stands."""
        with self._lock:
            if (self.retry_policy is None or self._closed
                    or self._tail_dead):
                return False
            retained = [(rid, rec) for rid, rec in self._retained.items()
                        if rid in self._futures]
        old = self.result_channel
        ch = self._open_channel(self.topology.stages[-1].transport, 0)
        self.result_channel = ch
        # replicas' relay loops re-read next_inbox per item, so the swap
        # takes effect on their next send without restarting them
        for node in self.stages[-1].replicas:
            node.next_inbox = ch
        try:
            old.close()
        except Exception:  # deferlint: swallow(old tail channel already dead)
            pass
        self.replay_stats.tail_revives += 1
        if retained:
            # everything in flight may have died with the old channel;
            # replay it (first-wins drops any duplicate that did survive)
            self._finish_batch(
                [RowExtent(rid, rec.client_id, rec.seq, rec.rows,
                           t_submit=rec.t_submit, attempt=rec.attempt)
                 for rid, rec in retained],
                error="the chain's tail link died before this request's "
                      "result was delivered",
                retryable=True)
        return True

    # -- decode sessions --------------------------------------------------------
    def session_register(self, session: Any) -> None:
        """Track one active decode session (the generate loop calls this
        at open and :meth:`session_unregister` on close, so the displaced
        set only ever holds live sessions — bounded by construction)."""
        with self._lock:
            self._active_sessions.add(session)

    def session_unregister(self, session: Any) -> None:
        with self._lock:
            self._active_sessions.discard(session)
            self._displaced_sessions.discard(session)

    def session_displaced(self, session: Any) -> bool:
        """Check-and-clear: True once after the session's sticky replica
        was drained/died or a repartition invalidated every stage's cache
        — the generate loop then re-prefills from its retained history."""
        with self._lock:
            if session in self._displaced_sessions:
                self._displaced_sessions.discard(session)
                return True
            return False

    def _note_displaced(self, sessions: Iterable[Any]) -> None:
        """Router callback: these sessions' pinned replica left the
        routing set (drain at a fence, or death)."""
        with self._lock:
            self._displaced_sessions.update(
                s for s in sessions if s in self._active_sessions)

    # -- admission --------------------------------------------------------------
    def submit(self, x: np.ndarray, client_id: Any = 0,
               block: bool = True, timeout: float | None = None,
               priority: int = 0,
               deadline_s: float | None = None,
               session: Any = None, session_pos: int = 0,
               session_kind: int = 0) -> Future:
        """Admit one request.  Returns a Future resolving to the output.

        ``timeout`` vs ``deadline_s`` — they bound DIFFERENT phases:
        ``timeout`` only bounds how long this call may block waiting for
        admission-queue space (backpressure at the front door); once the
        request is admitted, ``timeout`` plays no further role.
        ``deadline_s`` is the end-to-end result deadline: if the future
        has not resolved ``deadline_s`` seconds (monotonic clock) after
        submission, it fails with :class:`DeadlineExceeded`, replay is
        skipped when the remaining budget is below the calibrated chain
        latency, and a late result is dropped by the at-most-once
        collector, never delivered.

        When the bounded admission queue is full, blocks (``block=True``)
        or raises :class:`AdmissionFull` — that is the backpressure a
        front-end needs to shed load instead of queuing unboundedly.  A
        client at its in-flight quota (``client_quota``) is refused
        immediately with :class:`AdmissionFull` regardless of ``block`` —
        one greedy client can no longer monopolize the admission queue.

        ``priority`` selects the admission band: the pump dequeues bands
        weighted-fair (weight ``priority + 1``), so higher-priority
        backlogged clients drain proportionally faster without starving
        priority 0.  A client's responses are still released in its own
        submission order (the sequenced merge), whatever the priorities
        or replica completion order did to the in-chain ordering.

        ``session``/``session_pos``/``session_kind`` tag decode-session
        traffic (see :mod:`repro.runtime.session`): stage routers pin the
        session to the replica holding its KV cache, and the blind replay
        layer is bypassed — a replayed decode step against a cache that
        died with its replica would silently corrupt the sequence, so
        session recovery is re-prefill from retained history at the
        session layer, never a wire-level replay.
        """
        if not self._started:
            self.start()
        # reject ids the byte framing can't carry HERE, not as a relay
        # failure mid-chain on whichever stage binds a socket transport
        validate_client_id(client_id)
        if session is not None:
            validate_client_id(session)
        fut: Future = Future()
        # one locked section registers the request: any submit that passed
        # the closed check is visible to shutdown() via _admitting/_inflight,
        # so _STOP can never overtake a registered envelope
        with self._lock:
            if self._closed:
                raise RuntimeError("dispatcher is shut down")
            if self._tail_dead:
                raise RuntimeError(
                    "the chain can no longer deliver results (a link "
                    "died); restart the engine")
            if self.client_quota is not None \
                    and self._client_inflight[client_id] >= self.client_quota:
                raise AdmissionFull(
                    f"client {client_id!r} at quota "
                    f"({self.client_quota} in flight)")
            rid = self._next_id
            self._next_id += 1
            seq = self._client_seq[client_id]
            self._client_seq[client_id] += 1
            self._futures[rid] = fut
            self._inflight += 1
            self._client_inflight[client_id] += 1
            self._admitting += 1
        try:
            arr = np.asarray(x)
            blob, rec = self.codecs.data.encode_tree(
                {"": arr}, "data", request_id=rid, client_id=client_id)
            rows = int(arr.shape[0]) if arr.ndim else 1
            t_sub = time.perf_counter()
            env = BatchEnvelope(
                [RowExtent(rid, client_id, seq, rows,
                           t_submit=t_sub, session=session,
                           pos=int(session_pos),
                           kind=int(session_kind))], blob)
            with self._lock:
                self.feed_records.append(rec)
                if ((self.retry_policy is not None and session is None)
                        or deadline_s is not None):
                    # retain the encoded input for replay; a deadline-only
                    # submit (no policy) — and ANY session-tagged submit,
                    # whose recovery is session-layer re-prefill — retains
                    # just the metadata the reaper needs, not the blob
                    ret = _Retained(
                        blob if (self.retry_policy is not None
                                 and session is None) else b"",
                        client_id, seq, rows, priority, t_sub,
                        deadline=(time.monotonic() + deadline_s
                                  if deadline_s is not None else None),
                        deadline_s=deadline_s)
                    self._retained[rid] = ret
                    if ret.deadline is not None:
                        heapq.heappush(self._timer_heap,
                                       (ret.deadline, 0, rid, 0))
                        self._ensure_reaper_locked()
                        self._timer_cv.notify()
            self.admission.put(env, block=block, timeout=timeout,
                               priority=priority)
        except queue.Full:
            self._unregister(rid, client_id, seq)
            raise AdmissionFull(
                f"admission queue full ({self.admission.maxsize} deep)")
        except BaseException:
            self._unregister(rid, client_id, seq)
            raise
        with self._lock:
            self._admitting -= 1
            self._idle.notify_all()
        return fut

    def _unregister(self, rid: int, client_id: Any, seq: int) -> None:
        """Roll back a registration whose envelope never reached admission.
        The seq is cancelled in the merge so later results can't stall
        behind the hole — and any later-seq results already held behind
        it are released now (nothing else would ever re-drain them)."""
        with self._lock:
            self._futures.pop(rid, None)
            self._retained.pop(rid, None)
            self._client_cancel[client_id].add(seq)
            self._inflight -= 1
            self._client_inflight[client_id] -= 1
            self._admitting -= 1
            done = self._release_locked(client_id, time.perf_counter())
            self._idle.notify_all()
        self._resolve(done)

    def infer_stream(self, inputs: Iterable[np.ndarray],
                     client_id: Any = 0) -> list[np.ndarray]:
        """Blocking shim over submit(): feed all samples, collect in
        submission order (FIFO for this client by construction)."""
        futures = [self.submit(x, client_id=client_id) for x in inputs]
        return [f.result() for f in futures]

    # -- live reconfiguration (the controller's commit path) -------------------
    def reconfigure(self, cuts: Sequence[int],
                    timeout: float | None = 60.0) -> dict:
        """Hot-migrate partition boundaries on the RUNNING topology.

        Two-phase: (1) PREPARE — for each stage whose range changes, build
        a :class:`NodePlan` carrying its new architecture spec and the
        wire-encoded weights of only the layers it GAINS (the weight diff;
        kept layers are reused in place; every replica of the stage applies
        the same plan); (2) COMMIT — inject one :class:`ReconfigMarker` at
        the head of the topology.  The marker rides the same FIFO channels
        as data envelopes; each stage's router barriers it over the
        upstream replicas and broadcasts it to its own, so every replica
        swaps exactly when the fence passes its compute stage: every
        in-flight request is processed by a consistent partition end-to-end
        and none is dropped or recomputed.  Blocks until the tail collector
        completes the final barrier (or ``timeout``).

        The fence rides FIFO channels, so it cannot be lost: an
        un-acknowledged return (``acknowledged: False``) means the marker
        is still behind a backlog, not that the migration failed — the
        replicas WILL adopt the committed cuts when it clears, which is why
        ``partition``/``epoch`` are updated to the committed target either
        way.  Callers treat un-acked as migration-in-progress (the
        controller skips its post-swap precompile and rebaselines its
        telemetry window).

        Returns a summary record (also appended to ``reconfig_records``).
        """
        assert self._configured and self._params is not None, \
            "configure() before reconfigure()"
        assert self._started, "reconfigure() fences a running chain"
        with self._reconfig_lock:
            new_bounds = [0, *sorted(int(c) for c in cuts),
                          len(self.graph.nodes)]
            new_ranges = list(zip(new_bounds, new_bounds[1:]))
            old_ranges = [tuple(r) for r in self.partition.ranges()]
            if len(new_ranges) != len(self.stages):
                raise ValueError(
                    f"cuts {tuple(cuts)} give {len(new_ranges)} stages for "
                    f"{len(self.stages)} stages")
            if any(hi <= lo for lo, hi in new_ranges):
                raise ValueError(f"cuts {tuple(cuts)} leave an empty stage")
            if [tuple(r) for r in new_ranges] == old_ranges:
                return {"epoch": self.epoch, "changed": False}

            epoch = self.epoch + 1
            plans: dict[int, NodePlan] = {}
            shipped = 0
            moved_layers = 0
            for i, ((lo, hi), (lo2, hi2)) in enumerate(
                    zip(old_ranges, new_ranges)):
                if (lo, hi) == (lo2, hi2):
                    continue               # untouched stage: no plan, no bytes
                names = [n.name for n in self.graph.slice_nodes(lo2, hi2)]
                kept = {n.name for n in self.graph.slice_nodes(lo, hi)}
                gained = [nm for nm in names if nm not in kept]
                moved_layers += len(gained)
                spec = {"layers": names,
                        "next": i + 1 if i + 1 < len(self.stages) else None}
                arch_blob = json.dumps(spec).encode()
                weights_blob = b""
                if gained:
                    weights_blob, rec = self.codecs.weights.encode_tree(
                        {nm: self._params[nm] for nm in gained}, "weights")
                    self.config_records.append(rec)
                plans[i] = NodePlan(lo2, hi2, arch_blob, weights_blob,
                                    self.codecs.weights,
                                    wire_bytes=len(arch_blob)
                                    + len(weights_blob))
                # the diff travels once per REPLICA of the stage
                shipped += plans[i].wire_bytes * len(
                    self.stages[i].live_replicas())

            ev = threading.Event()
            self._reconfig_expect = epoch
            self._reconfig_event = ev
            t0 = time.perf_counter()
            # the fence enters the first stage's router like any envelope
            # and stays ordered behind everything already pumped
            self._stage_inputs[0].send(ReconfigMarker(epoch, plans))
            acked = ev.wait(timeout)
            self._reconfig_event = None
            # a repartition invalidates per-stage KV caches (they are keyed
            # by the stage's layer slice, which just moved): every active
            # decode session is displaced — the generate loop re-prefills
            # from its retained history, so sessions survive the move
            with self._lock:
                self._displaced_sessions.update(self._active_sessions)
            self.topology = self.topology.with_layers(new_bounds)
            self.partition = partition(self.graph, len(self.stages),
                                       link=self.link, cuts=new_bounds[1:-1],
                                       replicas=self.replicas)
            self.epoch = epoch
            record = {
                "epoch": epoch, "changed": True, "acknowledged": acked,
                "cuts": tuple(new_bounds[1:-1]),
                "moved_layers": moved_layers,
                "shipped_bytes": shipped,
                "migrate_s": time.perf_counter() - t0,
                "nodes_touched": sorted(plans),
            }
            self.reconfig_records.append(record)
            return record

    # -- elastic membership (spawn / drain replicas) ---------------------------
    def scale(self, stage: int, replicas: int,
              timeout: float | None = 60.0,
              precompile: bool = False) -> dict:
        """Grow or shrink one stage's replica count on the RUNNING chain.

        Spawn (``replicas`` > current): fresh :class:`ComputeNode`
        replicas are built, configured over the wire with the stage's full
        weights, and started; the epoch fence then adds them to the
        stage's routing set — they only ever see post-fence work, so no
        request straddles the membership change.

        Drain (``replicas`` < current): the fence removes the
        highest-numbered replicas from the routing set; each draining
        replica still receives the fence (flushing everything already
        routed to it, which the downstream barrier then accounts for) and
        a trailing retire token, after which its threads exit without
        signaling downstream.  Zero requests are dropped, duplicated, or
        reordered per client.

        Blocks until the collector acknowledges the fence (or
        ``timeout``); un-acked means fence-in-flight, exactly as for
        :meth:`reconfigure`.  ``precompile=True`` traces spawned replicas'
        batch specializations before they join (no jit inside a serving
        window, at the cost of a slower scale-up).
        """
        assert self._configured and self._params is not None, \
            "configure() before scale()"
        assert self._started, "scale() fences a running chain"
        if not 0 <= stage < len(self.stages):
            raise ValueError(f"no stage {stage} in a "
                             f"{len(self.stages)}-stage topology")
        if replicas < 1:
            raise ValueError("a stage needs at least one replica")
        with self._reconfig_lock:
            group = self.stages[stage]
            # a replica drained by an earlier un-acked scale stays listed
            # while it flushes (telemetry/knobs/shutdown must see it);
            # live_replicas() prunes it once its threads exit
            live = [r for r in group.live_replicas() if not r.retiring]
            cur = len(live)
            if replicas == cur:
                return {"epoch": self.epoch, "changed": False,
                        "stage": stage, "replicas": cur}
            epoch = self.epoch + 1
            adds: list[ComputeNode] = []
            drops: list[ComputeNode] = []
            shipped = 0
            t0 = time.perf_counter()
            if replicas > cur:
                lo, hi = self.partition.ranges()[stage]
                arch_blob, weights_blob = self._stage_blobs(stage, lo, hi)
                next_r = max((n.replica for n in group.replicas),
                             default=-1) + 1
                nxt = (self._stage_inputs[stage + 1]
                       if stage + 1 < len(self.stages)
                       else self.result_channel)
                # inherit the stage's LIVE knobs, not the spec defaults:
                # the controller tunes knobs uniformly per stage and
                # compares against replica 0's values, so a default-knobbed
                # newcomer would never be corrected.  A stage whose every
                # replica crashed (supervisor respawn-from-zero) has no
                # live reference; newcomers then keep spec defaults.
                ref = live[0] if live else None
                for k in range(replicas - cur):
                    node = self._make_replica(stage, next_r + k)
                    if ref is not None:
                        node.max_batch = ref.max_batch
                        node.coalesce_s = ref.coalesce_s
                    node.configure(self.graph, lo, hi, arch_blob,
                                   weights_blob, self.codecs.weights)
                    node.next_inbox = nxt
                    if precompile:
                        node.precompile()
                    node.start()
                    adds.append(node)
                    shipped += len(arch_blob) + len(weights_blob)
            else:
                drops = live[replicas:]
            group.stage_membership(epoch, adds, drops)
            group.replicas.extend(adds)     # stats/report see them at once
            for node in drops:
                node.retiring = True

            ev = threading.Event()
            self._reconfig_expect = epoch
            self._reconfig_event = ev
            self._stage_inputs[0].send(ReconfigMarker(epoch, {}))
            acked = ev.wait(timeout)
            self._reconfig_event = None
            self.epoch = epoch
            if acked:
                # fence cleared chain-wide: the drops flushed everything
                # and their threads are exiting — join, then prune.
                # Un-acked drops stay visible until they exit (pruned by
                # any live_replicas() reader; shutdown joins them too).
                for node in drops:
                    node.join()
                group.live_replicas()
            self.topology = self.topology.with_replicas(stage, replicas)
            self.partition = partition(
                self.graph, len(self.stages), link=self.link,
                cuts=list(self.partition.cuts) or None,
                replicas=self.replicas)
            record = {
                "epoch": epoch, "changed": True, "acknowledged": acked,
                "kind": "scale", "stage": stage,
                "replicas_before": cur, "replicas_after": replicas,
                "spawned": len(adds), "retired": len(drops),
                "shipped_bytes": shipped,
                "scale_s": time.perf_counter() - t0,
            }
            self.reconfig_records.append(record)
            return record

    def set_stage_knobs(self, stage: int, max_batch: int | None = None,
                        coalesce_s: float | None = None) -> None:
        """Retune one stage's serving knobs live (controller's actuator),
        uniformly across its replicas.  ``max_batch`` is clamped to
        [1, max_batch_cap] so precompiled batch specializations stay
        authoritative."""
        for node in self.stages[stage].replicas:
            if max_batch is not None:
                node.max_batch = min(max(1, int(max_batch)),
                                     node.max_batch_cap)
            if coalesce_s is not None:
                node.coalesce_s = max(0.0, float(coalesce_s))

    # -- teardown ---------------------------------------------------------------
    def drain(self, timeout: float | None = None) -> bool:
        """Block until no request is in flight.  True if drained."""
        with self._idle:
            return self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout)

    def reset_stats(self) -> None:
        with self._lock:
            self.latencies = []
            self.feed_records = []
        for node in self.nodes:
            node.reset_stats()

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Stop accepting requests; by default let in-flight ones finish.

        The _STOP token trails every admitted envelope through the FIFO
        channels — each router broadcasts it to its replicas after
        receiving one copy per upstream replica — so even ``drain=False``
        completes (not cancels) in-flight requests; drain merely waits for
        the results before teardown.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if not self._started:
            return
        # never let _STOP overtake a request that already passed the closed
        # check but has not reached the admission queue yet
        with self._idle:
            self._idle.wait_for(lambda: self._admitting == 0,
                                timeout=timeout)
        if drain:
            self.drain(timeout=timeout)
        self.admission.put(_STOP)
        if self._pump_thread:
            self._pump_thread.join()
        for group in self.stages:
            group.join()
            for node in list(group.replicas):   # incl. flushing retirees
                node.join()
        if self._collect_thread:
            self._collect_thread.join()
        # the reaper outlives the drain (it must be able to fail pending
        # deadline/replay events during it); stop it after the collector
        with self._lock:
            self._reaper_stop = True
            self._timer_cv.notify_all()
        if self._reaper_thread:
            self._reaper_thread.join()
        # every thread is down: release the channels (sockets, link
        # clocks) and return them to their transports' live counts
        for ch in self._channels:
            try:
                ch.close()
            except Exception:  # deferlint: swallow(best-effort teardown of already-dead channels)
                pass
