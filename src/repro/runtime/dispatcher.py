"""The DEFER dispatcher (paper Algorithm 1), in-process.

Partitions the model, ships architecture + weights to each compute node
(configuration step), then streams inference data into the head of the
chain and collects FIFO results from the tail (distributed inference step).
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
from typing import Any, Iterable

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.partitioner import LinkModel, Partition, partition
from repro.runtime.node import ComputeNode
from repro.runtime.wire import WireCodec, WireRecord


@dataclasses.dataclass
class DispatcherCodecs:
    """Per-payload-type codec choice (the paper's three socket configs)."""

    architecture: WireCodec = WireCodec("raw", "none")   # JSON spec, tiny
    weights: WireCodec = WireCodec("raw", "none")
    data: WireCodec = WireCodec("zfp", "none", zfp_rate=16)


class Dispatcher:
    """Owns the chain: planning, configuration, and the inference stream."""

    def __init__(self, graph: LayerGraph, num_nodes: int,
                 codecs: DispatcherCodecs | None = None,
                 strategy: str = "equal_layers",
                 link: LinkModel | None = None):
        self.graph = graph
        self.codecs = codecs or DispatcherCodecs()
        self.partition: Partition = partition(
            graph, num_nodes, strategy=strategy, link=link)
        self.nodes: list[ComputeNode] = [
            ComputeNode(i, self.codecs.data) for i in range(num_nodes)]
        self.config_records: list[WireRecord] = []
        self.result_queue: queue.Queue = queue.Queue()
        for i in range(num_nodes - 1):
            self.nodes[i].next_inbox = self.nodes[i + 1].inbox
        self.nodes[-1].next_inbox = self.result_queue
        self._configured = False

    # -- configuration step --------------------------------------------------
    def configure(self, params: dict[str, Any]) -> None:
        """Ship each partition's architecture + weights over the wire."""
        for node, (lo, hi) in zip(self.nodes, self.partition.ranges()):
            names = [n.name for n in self.graph.slice_nodes(lo, hi)]
            spec = {"layers": names,
                    "next": node.index + 1 if node.index + 1 < len(self.nodes)
                    else None}
            arch_blob = json.dumps(spec).encode()
            t0 = time.perf_counter()
            if self.codecs.architecture.compression == "lz4":
                from repro.core.codecs import Lz4Codec
                arch_wire = Lz4Codec().compress(arch_blob)
            else:
                arch_wire = arch_blob
            t1 = time.perf_counter()
            self.config_records.append(WireRecord(
                "architecture", len(arch_blob), len(arch_wire), t1 - t0))

            stage_params = {name: params[name] for name in names}
            weights_blob, rec = self.codecs.weights.encode_tree(
                stage_params, "weights")
            self.config_records.append(rec)
            node.configure(self.graph, lo, hi, arch_blob, weights_blob,
                           self.codecs.weights)
        self._configured = True

    # -- distributed inference step ----------------------------------------------
    def start(self) -> None:
        assert self._configured, "configure() before start()"
        for node in self.nodes:
            node.start()

    def infer_stream(self, inputs: Iterable[np.ndarray]) -> list[np.ndarray]:
        """Feed samples FIFO into the chain; block for all results, in order."""
        self.start()
        n = 0
        feed_records = []
        for x in inputs:
            blob, rec = self.codecs.data.encode_tree({"": np.asarray(x)}, "data")
            feed_records.append(rec)
            self.nodes[0].inbox.put((n, blob))
            n += 1
        outputs: dict[int, np.ndarray] = {}
        order = []
        for _ in range(n):
            seq, blob = self.result_queue.get()
            flat, _ = self.codecs.data.decode_tree(blob)
            (out,) = flat.values()
            outputs[seq] = out
            order.append(seq)
        self.feed_records = feed_records
        assert order == sorted(order), f"FIFO order violated: {order}"
        return [outputs[i] for i in range(n)]

    def shutdown(self) -> None:
        self.nodes[0].stop()
        for node in self.nodes[1:]:
            if node._thread:
                node._thread.join()
