"""The DEFER dispatcher (paper Algorithm 1), in-process, async.

Partitions the model, ships architecture + weights to each compute node
(configuration step), then serves a *multi-client* inference stream: a
bounded admission queue applies backpressure at the front door, a pump
thread feeds the head of the chain, compute nodes continuously batch (and
relay whole batches as single :class:`BatchEnvelope` payloads), and a
collector thread decodes each tail envelope ONCE, slices per-request rows
back out, and resolves the per-request futures — FIFO per client (the
batching chain may legally reorder across clients).  A batch that failed
inside a node arrives as an ``error`` envelope; the collector fails exactly
those futures with :class:`NodeError` while the chain keeps serving.
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
import traceback
from collections import defaultdict
from concurrent.futures import Future
from typing import Any, Iterable

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.partitioner import LinkModel, Partition, partition
from repro.runtime.node import _STOP, ComputeNode
from repro.runtime.wire import (BatchEnvelope, RowExtent, WireCodec,
                                WireRecord, slice_parts)


class AdmissionFull(Exception):
    """The bounded admission queue is full (backpressure reached the client)."""


class NodeError(RuntimeError):
    """A request's batch failed inside a compute node; carries the remote
    traceback.  The node survives and keeps serving other requests."""


@dataclasses.dataclass
class DispatcherCodecs:
    """Per-payload-type codec choice (the paper's three socket configs)."""

    architecture: WireCodec = WireCodec("raw", "none")   # JSON spec, tiny
    weights: WireCodec = WireCodec("raw", "none")
    data: WireCodec = WireCodec("zfp", "none", zfp_rate=16)


class Dispatcher:
    """Owns the chain: planning, configuration, and the admission stream."""

    def __init__(self, graph: LayerGraph, num_nodes: int,
                 codecs: DispatcherCodecs | None = None,
                 strategy: str = "equal_layers",
                 link: LinkModel | None = None,
                 max_batch: int = 8,
                 admission_depth: int = 64,
                 queue_depth: int = 8,
                 staged: bool = True):
        self.graph = graph
        self.codecs = codecs or DispatcherCodecs()
        self.partition: Partition = partition(
            graph, num_nodes, strategy=strategy, link=link)
        self.nodes: list[ComputeNode] = [
            ComputeNode(i, self.codecs.data, queue_depth=queue_depth,
                        max_batch=max_batch, staged=staged)
            for i in range(num_nodes)]
        self.config_records: list[WireRecord] = []
        self.result_queue: queue.Queue = queue.Queue()
        for i in range(num_nodes - 1):
            self.nodes[i].next_inbox = self.nodes[i + 1].inbox
        self.nodes[-1].next_inbox = self.result_queue

        self.admission: queue.Queue = queue.Queue(maxsize=admission_depth)
        # windowed stats (cleared by reset_stats): dispatcher-side encode
        # records and admission->result latencies
        self.feed_records: list[WireRecord] = []
        self.latencies: list[float] = []
        self._futures: dict[int, Future] = {}
        self._next_id = 0
        self._client_seq: dict[Any, int] = defaultdict(int)
        self._inflight = 0
        self._admitting = 0        # registered but not yet on the admission q
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._pump_thread: threading.Thread | None = None
        self._collect_thread: threading.Thread | None = None
        self._configured = False
        self._started = False
        self._closed = False

    # -- configuration step --------------------------------------------------
    def configure(self, params: dict[str, Any]) -> None:
        """Ship each partition's architecture + weights over the wire."""
        for node, (lo, hi) in zip(self.nodes, self.partition.ranges()):
            names = [n.name for n in self.graph.slice_nodes(lo, hi)]
            spec = {"layers": names,
                    "next": node.index + 1 if node.index + 1 < len(self.nodes)
                    else None}
            arch_blob = json.dumps(spec).encode()
            t0 = time.perf_counter()
            if self.codecs.architecture.compression == "lz4":
                from repro.core.codecs import Lz4Codec
                arch_wire = Lz4Codec().compress(arch_blob)
            else:
                arch_wire = arch_blob
            t1 = time.perf_counter()
            self.config_records.append(WireRecord(
                "architecture", len(arch_blob), len(arch_wire), t1 - t0))

            stage_params = {name: params[name] for name in names}
            weights_blob, rec = self.codecs.weights.encode_tree(
                stage_params, "weights")
            self.config_records.append(rec)
            node.configure(self.graph, lo, hi, arch_blob, weights_blob,
                           self.codecs.weights)
        self._configured = True

    def precompile(self) -> None:
        """Compile every batch-size specialization on every node up front
        (see :meth:`ComputeNode.precompile`)."""
        assert self._configured, "configure() before precompile()"
        for node in self.nodes:
            node.precompile()

    # -- distributed inference step -------------------------------------------
    def start(self) -> None:
        assert self._configured, "configure() before start()"
        if self._started:
            return
        self._started = True
        for node in self.nodes:
            node.start()
        self._pump_thread = threading.Thread(target=self._pump, daemon=True)
        self._pump_thread.start()
        self._collect_thread = threading.Thread(target=self._collect,
                                                daemon=True)
        self._collect_thread.start()

    def _pump(self) -> None:
        """Admission queue -> head of the chain (the dispatcher's outbound
        socket).  Keeping this off the caller thread means submit() returns
        as soon as the request is *admitted*, not relayed."""
        head = self.nodes[0].inbox
        while True:
            env = self.admission.get()
            if env is _STOP:
                head.put(_STOP)
                return
            head.put(env)

    def _collect(self) -> None:
        """Tail of the chain -> per-request futures (FIFO per client).

        One decode per tail envelope; per-request rows are sliced back out
        of the stacked payload by the envelope's row-extent framing."""
        while True:
            item = self.result_queue.get()
            if item is _STOP:
                return
            env: BatchEnvelope = item
            if env.error is not None:
                self._finish_batch(env.extents, error=env.error)
                continue
            try:
                flat, _ = self.codecs.data.decode_tree(env.blob)
                flat = {k: np.asarray(v) for k, v in flat.items()}
                parts = slice_parts(flat, env.extents)
            except Exception:               # codec failure at the tail
                self._finish_batch(env.extents, error=traceback.format_exc())
                continue
            results = [(next(iter(p.values())) if len(p) == 1 else p)
                       for p in parts]
            self._finish_batch(env.extents, results=results)

    def _finish_batch(self, extents: list[RowExtent],
                      results: list | None = None,
                      error: str | None = None) -> None:
        now = time.perf_counter()
        done: list[tuple[Future, Any]] = []
        with self._lock:
            for idx, ext in enumerate(extents):
                fut = self._futures.pop(ext.request_id, None)
                if fut is None:
                    continue
                if error is None:
                    # failures resolve fast by construction — mixing their
                    # time-to-failure into the percentiles would *improve*
                    # reported latency as the error rate rises
                    self.latencies.append(now - ext.t_submit)
                self._inflight -= 1
                done.append((fut, results[idx] if results is not None
                             else None))
            self._idle.notify_all()
        for fut, res in done:
            if error is not None:
                fut.set_exception(NodeError(
                    f"request failed inside the chain:\n{error}"))
            else:
                fut.set_result(res)

    # -- admission --------------------------------------------------------------
    def submit(self, x: np.ndarray, client_id: Any = 0,
               block: bool = True, timeout: float | None = None) -> Future:
        """Admit one request.  Returns a Future resolving to the output.

        When the bounded admission queue is full, blocks (``block=True``)
        or raises :class:`AdmissionFull` — that is the backpressure a
        front-end needs to shed load instead of queuing unboundedly.
        """
        if not self._started:
            self.start()
        fut: Future = Future()
        # one locked section registers the request: any submit that passed
        # the closed check is visible to shutdown() via _admitting/_inflight,
        # so _STOP can never overtake a registered envelope
        with self._lock:
            if self._closed:
                raise RuntimeError("dispatcher is shut down")
            rid = self._next_id
            self._next_id += 1
            seq = self._client_seq[client_id]
            self._client_seq[client_id] += 1
            self._futures[rid] = fut
            self._inflight += 1
            self._admitting += 1
        try:
            arr = np.asarray(x)
            blob, rec = self.codecs.data.encode_tree(
                {"": arr}, "data", request_id=rid, client_id=client_id)
            rows = int(arr.shape[0]) if arr.ndim else 1
            env = BatchEnvelope(
                [RowExtent(rid, client_id, seq, rows,
                           t_submit=time.perf_counter())], blob)
            with self._lock:
                self.feed_records.append(rec)
            self.admission.put(env, block=block, timeout=timeout)
        except queue.Full:
            with self._lock:
                self._futures.pop(rid, None)
                self._inflight -= 1
                self._admitting -= 1
                self._idle.notify_all()
            raise AdmissionFull(
                f"admission queue full ({self.admission.maxsize} deep)")
        except BaseException:
            with self._lock:
                self._futures.pop(rid, None)
                self._inflight -= 1
                self._admitting -= 1
                self._idle.notify_all()
            raise
        with self._lock:
            self._admitting -= 1
            self._idle.notify_all()
        return fut

    def infer_stream(self, inputs: Iterable[np.ndarray],
                     client_id: Any = 0) -> list[np.ndarray]:
        """Blocking shim over submit(): feed all samples, collect in
        submission order (FIFO for this client by construction)."""
        futures = [self.submit(x, client_id=client_id) for x in inputs]
        return [f.result() for f in futures]

    # -- teardown ---------------------------------------------------------------
    def drain(self, timeout: float | None = None) -> bool:
        """Block until no request is in flight.  True if drained."""
        with self._idle:
            return self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout)

    def reset_stats(self) -> None:
        with self._lock:
            self.latencies = []
            self.feed_records = []
        for node in self.nodes:
            node.reset_stats()

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Stop accepting requests; by default let in-flight ones finish.

        The _STOP token trails every admitted envelope through the FIFO
        chain, so even ``drain=False`` completes (not cancels) in-flight
        requests — drain merely waits for the results before teardown.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if not self._started:
            return
        # never let _STOP overtake a request that already passed the closed
        # check but has not reached the admission queue yet
        with self._idle:
            self._idle.wait_for(lambda: self._admitting == 0,
                                timeout=timeout)
        if drain:
            self.drain(timeout=timeout)
        self.admission.put(_STOP)
        if self._pump_thread:
            self._pump_thread.join()
        for node in self.nodes:
            node.join()
        if self._collect_thread:
            self._collect_thread.join()
