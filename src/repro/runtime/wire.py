"""The wire format between DEFER nodes: serialize -> compress -> chunk.

Every payload that crosses a (simulated) socket goes through here, so byte
counts and encode/decode timings are measured in one place.  Mirrors the
paper: 512 kB chunking, {JSON, ZFP, Q8} serializers x {LZ4, none}
compression, independent codec choice per payload type (architecture /
weights / data).

Since the staged-relay runtime, inter-node data payloads are **batch-level**:
a compute node encodes the stacked output of a whole continuous batch ONCE
and ships it as a single :class:`BatchEnvelope` whose *envelope* (not blob)
carries per-request row extents.  One ZFP/LZ4/Q8 pass amortizes fixed codec
cost across the batch and lets LZ4 find cross-request matches; the receiving
node decodes once and only the tail collector slices rows back out
(:func:`slice_parts`).  The wire blob itself is the same framed pytree
stream as before — ``encode_tree``/``decode_tree`` — so batch payloads and
config payloads share one format:

    [u32 leaf_count] then per leaf:
    [u32 name_len][name][u64 body_len][body = serializer(+lz4) bytes]

``request_id`` is globally unique (admission order) and is what the
collector demuxes results by; continuous batching may legally reorder
requests of *different* clients, and a client's own results still come back
FIFO because ``stream()`` awaits futures in submission order.

**Channel-item framing.**  Everything that rides a runtime
:class:`~repro.runtime.transport.Channel` — data envelopes, the epoch
fence, and the ``_STOP``/``_RETIRE`` control tokens — round-trips through
:func:`frame`/:func:`unframe`, a versioned byte format with **no pickle**:
a socket or emulated-link transport moves exactly these bytes, so the
chain's control plane survives a real wire.  A truncated or corrupt buffer
raises :class:`WireFormatError` (never a bare ``struct.error``), which the
node stages surface as a per-batch failure while the chain keeps serving.
"""
from __future__ import annotations

import dataclasses
import io
import json
import struct
import time
import warnings
from typing import Any

import numpy as np

from repro.core import codecs

CHUNK_BYTES = 512 * 1024


class WireFormatError(ValueError):
    """A wire payload failed framing validation (truncated, corrupt, or
    version-mismatched).  Raised instead of leaking ``struct.error`` /
    bare ``ValueError`` from the codec internals, so a dropped socket or
    a bit-flipped blob fails exactly the affected batch as a
    :class:`~repro.runtime.dispatcher.NodeError` instead of killing a
    stage thread mid-loop."""


class _Token:
    """A chain control token (identity-compared singleton).  Framing maps
    each token to a dedicated frame type so ``unframe`` can return the
    very same singleton on the far side of a socket."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:          # pragma: no cover - debugging aid
        return f"<{self.name}>"


# the shutdown token: trails every admitted envelope through the FIFO
# channels; each consumer counts one copy per upstream member
_STOP = _Token("STOP")
# the single-replica drain token: flows through one replica's internal
# stages like _STOP but exits WITHOUT signaling downstream, so a retired
# replica never perturbs the next stage's stop accounting
_RETIRE = _Token("RETIRE")


@dataclasses.dataclass
class WireRecord:
    kind: str                   # "architecture" | "weights" | "data"
    raw_bytes: int
    wire_bytes: int
    encode_s: float
    decode_s: float = 0.0
    # request routing (None for config-step payloads): lets per-payload
    # metrics be correlated back to the admission stream
    request_id: int | None = None
    client_id: int | None = None

    @property
    def chunks(self) -> int:
        return max(1, -(-self.wire_bytes // CHUNK_BYTES))


@dataclasses.dataclass
class Envelope:
    """One in-flight request's payload between chain hops (PR 1 wire).

    Superseded by :class:`BatchEnvelope` inside the staged runtime; kept as
    a public single-request view for tooling and tests.
    """

    request_id: int
    client_id: int
    seq: int                    # submission index within client
    blob: bytes
    t_submit: float = 0.0       # admission timestamp (perf_counter)


@dataclasses.dataclass(frozen=True)
class RowExtent:
    """One request's slice of a batch payload: rows [offset..offset+rows)
    along axis 0 of every leaf, where offset is the sum of preceding
    extents' rows.  Routing metadata rides the envelope, not the blob."""

    request_id: int
    client_id: Any
    seq: int                    # submission index within client
    rows: int                   # this request's rows in the stacked tensor
    t_submit: float = 0.0       # admission timestamp (perf_counter)
    # set when bucketed pad-to-shape merged this request into a wider
    # bucket: the ORIGINAL middle-axis sizes (everything between axis 0
    # and the last axis) the collector trims results back to
    pad_trim: tuple | None = None
    # delivery attempt (0 = first admission).  The dispatcher's replay
    # path re-admits a request stranded by an infrastructure failure
    # under an incremented attempt so stale failure reports for an older
    # attempt can be told apart from the one currently in flight.
    attempt: int = 0
    # -- decode-session fields (wire v4) ------------------------------------
    # session id for autoregressive decode traffic (None for single-shot
    # requests).  A session-bearing envelope carries EXACTLY one extent:
    # stage routers pin the session to the replica holding its KV cache,
    # and a multi-session envelope could not route sticky.
    session: Any = None
    # sequence position of the token(s) this extent carries (the KV cache
    # slot a decode step writes); 0 for opens, which always prefill from
    # position 0
    pos: int = 0
    # 0 = plain single-shot row; 1 = session open (full-prompt prefill);
    # 2 = decode step (one new token); 3 = session close (evict KV)
    kind: int = 0


# RowExtent.kind values (module constants so call sites read as prose)
K_PLAIN = 0
K_OPEN = 1
K_STEP = 2
K_CLOSE = 3


@dataclasses.dataclass
class BatchEnvelope:
    """A whole continuous batch on the wire: ONE encoded stacked payload
    plus per-request row-extent framing.  ``error`` carries a formatted
    traceback instead of a payload when an upstream stage failed — the
    envelope still flows to the tail so the collector can fail exactly the
    affected futures while the chain keeps serving."""

    extents: list[RowExtent]
    blob: bytes
    error: str | None = None
    # failure classification for error envelopes: True means the failure
    # is an INFRASTRUCTURE one (severed link, killed replica, stranded
    # ledger) so the affected requests are safe to replay through the
    # healed chain; False (the default, and the only value application /
    # codec errors ever carry) means user code rejected the request and
    # retrying would just repeat the rejection.
    retryable: bool = False
    # partition epoch the producing stage was on when it encoded this
    # envelope.  With replicated stages the chain is no longer one global
    # FIFO: a fast replica can emit post-fence output while a slow sibling
    # still drains pre-fence work, so the next stage's router HOLDS any
    # envelope stamped ahead of its own epoch until the fence barrier
    # completes — no request ever sees a mixed-epoch chain.
    epoch: int = 0

    @property
    def n(self) -> int:
        return len(self.extents)

    @property
    def rows(self) -> int:
        return sum(e.rows for e in self.extents)


# one-shot flag for the pad_trim rank-mismatch warning below (tests reset)
_RANK_MISMATCH_WARNED = False


def slice_parts(flat: dict[str, np.ndarray],
                extents: list[RowExtent]) -> list[dict[str, np.ndarray]]:
    """Invert batch stacking: one {name: array} view per extent (no copy).

    An extent carrying ``pad_trim`` was zero-padded along its middle axes
    to merge into a wider shape bucket; its leaves are trimmed back to the
    original sizes here.  The trim only applies to rank-preserving layers:
    a leaf whose rank no longer matches the recorded trim (a rank-changing
    layer ran after the padded merge) is passed through untouched — and
    since its padded middle axes can no longer be located, the pass-through
    may contain padding.  That silent hazard is flagged with a ONE-SHOT
    ``RuntimeWarning`` (first occurrence per process) pointing at the fix:
    mark the rank-changing layer ``pad_safe=False`` so its segment falls
    back to exact bucketing."""
    global _RANK_MISMATCH_WARNED
    parts = []
    off = 0
    for e in extents:
        part = {k: v[off:off + e.rows] for k, v in flat.items()}
        if e.pad_trim is not None:
            trim = tuple(slice(0, s) for s in e.pad_trim)
            trimmed = {}
            for k, v in part.items():
                if v.ndim == len(e.pad_trim) + 2:
                    trimmed[k] = v[(slice(None),) + trim]
                else:
                    if not _RANK_MISMATCH_WARNED:
                        _RANK_MISMATCH_WARNED = True
                        warnings.warn(
                            f"slice_parts: leaf {k!r} has rank {v.ndim} but "
                            f"its pad_trim records {len(e.pad_trim)} middle "
                            f"axes (rank {len(e.pad_trim) + 2}); a "
                            "rank-changing layer ran after a padded shape-"
                            "bucket merge, so the trim cannot be applied "
                            "and the result may contain padding.  Mark the "
                            "rank-changing layer pad_safe=False (its "
                            "segment then uses exact bucketing).  Warning "
                            "only once per process.",
                            RuntimeWarning, stacklevel=2)
                    trimmed[k] = v
            part = trimmed
        parts.append(part)
        off += e.rows
    return parts


@dataclasses.dataclass
class NodePlan:
    """One node's share of a live repartition: its new layer range, the
    wire-encoded architecture spec, and the weights of only the layers it
    GAINS (weight-diff shipping — layers it keeps never travel again)."""

    lo: int
    hi: int
    arch_blob: bytes
    weights_blob: bytes                 # gained layers only; b"" if none
    weights_codec: "WireCodec"
    wire_bytes: int = 0                 # len(arch) + len(weights) on the wire


@dataclasses.dataclass
class ReconfigMarker:
    """The epoch fence for a live repartition.

    Injected at the head of the chain and relayed hop-by-hop IN ORDER with
    the data envelopes: every envelope ahead of the marker is processed by
    the old partition at every node, every envelope behind it by the new
    one — each node swaps exactly when the marker passes its compute
    stage, so no in-flight request ever sees a mixed chain and none is
    dropped or recomputed.  With replicated stages, each stage's router
    broadcasts the marker to every replica and the NEXT stage's router
    (or the tail collector) runs a counting barrier — the fence advances
    only once every replica has flushed it, and post-fence envelopes from
    fast replicas are held at the barrier (``BatchEnvelope.epoch``).
    Membership changes (spawn/drain of replicas) ride the same fence:
    the affected stage's router applies its pending membership exactly
    when the marker passes, so elasticity inherits the zero-loss
    guarantee.  The tail collector observes the completed barrier to
    acknowledge the epoch switch chain-wide."""

    epoch: int
    plans: dict[int, NodePlan]          # stage index -> its new assignment


@dataclasses.dataclass
class ControlFrame:
    """One supervisor <-> worker control-plane message (frame type
    ``_F_CONTROL``): heartbeats (``kind="hb"`` carrying a node snapshot),
    config/knob handoff, readiness acks, chaos injection, and the clean
    ``"bye"`` a worker sends before a deliberate exit (so the supervisor
    can tell a drained worker from a crashed one).  The payload is a
    JSON-able dict (tuple-tagged like client ids) — weights never ride a
    ControlFrame; they ship as the existing :class:`ReconfigMarker` /
    :class:`NodePlan` framing on the same byte stream."""

    kind: str
    payload: dict = dataclasses.field(default_factory=dict)


# small-payload bypass magic: a leaf at most `small_bypass` bytes is
# shipped as this prefix + raw .npy instead of going through the
# configured serializer/LZ4 (per-token decode frames are a few KB, where
# ZFP/LZ4 setup cost exceeds the transfer savings).  Checked on decode
# BEFORE LZ4, so the prefix must be distinguishable from every stream the
# codecs emit: ZFP starts b"ZFPR", Q8 b"Q8BQ", JSON b"{", .npy b"\\x93";
# an LZ4 block stream has no magic, so an 8-byte sentinel keeps the
# accidental-collision odds negligible.
_RAW_BYPASS_MAGIC = b"DWRAWNP1"


@dataclasses.dataclass(frozen=True)
class WireCodec:
    serializer: str = "zfp"     # "json" | "zfp" | "q8" | "raw"
    compression: str = "none"   # "lz4" | "none"
    zfp_rate: int = 24
    # vectorized=False selects the pure-Python/copying reference codec
    # implementations (the PR 1 hot path) — kept so serve_load can measure
    # the staged runtime against a faithful same-codec PR 1 baseline
    vectorized: bool = True
    # arrays at most this many bytes skip the serializer/LZ4 entirely and
    # ship as magic-prefixed raw .npy (lossless); 0 disables the bypass.
    # Decode auto-detects via the prefix, so mixed-size trees are fine.
    small_bypass: int = 0

    @property
    def label(self) -> str:
        comp = "LZ4" if self.compression == "lz4" else "Uncompressed"
        return f"{self.serializer.upper()}/{comp}"

    def error_bound(self, absmax: float) -> float:
        """Worst-case absolute error for one encode/decode pass over values
        with |x| <= absmax (0.0 for the lossless serializers)."""
        if self.serializer == "q8":
            return codecs.Q8Codec().error_bound(absmax)
        if self.serializer == "zfp":
            return codecs.ZfpCodec(rate=self.zfp_rate).error_bound(absmax)
        return 0.0

    # -- arrays (weights / activations) ------------------------------------
    def encode_array(self, arr: np.ndarray) -> bytes:
        if (self.small_bypass and arr.nbytes <= self.small_bypass
                and (self.serializer != "raw" or self.compression != "none")):
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            return _RAW_BYPASS_MAGIC + buf.getvalue()
        if self.serializer == "raw":
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            blob = buf.getvalue()
        elif self.serializer == "json":
            blob = codecs.JsonCodec().encode(arr)
        elif self.serializer == "q8":
            blob = codecs.Q8Codec().encode(arr)
        else:
            blob = codecs.ZfpCodec(rate=self.zfp_rate,
                                   vectorized=self.vectorized).encode(arr)
        if self.compression == "lz4":
            blob = codecs.Lz4Codec(vectorized=self.vectorized).compress(blob)
        return blob

    def decode_array(self, blob: bytes) -> np.ndarray:
        """Decode one leaf.  The blob is NOT trusted: a truncated or
        corrupt payload (reachable via a dropped socket mid-frame) raises
        :class:`WireFormatError` instead of leaking ``struct.error`` /
        bare ``ValueError`` from the codec internals — the node stages
        turn that into a per-batch failure, not a dead stage thread."""
        try:
            if blob.startswith(_RAW_BYPASS_MAGIC):
                return np.load(io.BytesIO(blob[len(_RAW_BYPASS_MAGIC):]),
                               allow_pickle=False)
            if self.compression == "lz4":
                blob = codecs.Lz4Codec(
                    vectorized=self.vectorized).decompress(blob)
            if self.serializer == "raw":
                return np.load(io.BytesIO(blob), allow_pickle=False)
            if self.serializer == "json":
                return codecs.JsonCodec().decode(blob)
            if self.serializer == "q8":
                return codecs.Q8Codec().decode(blob)
            return codecs.ZfpCodec(rate=self.zfp_rate,
                                   vectorized=self.vectorized).decode(blob)
        except WireFormatError:
            raise
        except (struct.error, ValueError, EOFError, OSError, IndexError,
                KeyError, UnicodeDecodeError, AssertionError) as e:
            # AssertionError: the codecs assert their stream magic/shape
            # invariants — on an untrusted blob that is corruption too
            raise WireFormatError(
                f"corrupt {self.label} array payload "
                f"({len(blob)} bytes): {e}") from e

    # -- structured payloads (pytrees of arrays) -----------------------------
    def encode_tree(self, tree: Any, kind: str,
                    request_id: int | None = None,
                    client_id: int | None = None) -> tuple[bytes, WireRecord]:
        """Flatten a {name: array} pytree into one framed stream."""
        import jax
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        t0 = time.perf_counter()
        parts: list[bytes] = []
        raw = 0
        for path, leaf in flat:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path).encode()
            arr = np.asarray(leaf)
            raw += arr.nbytes
            body = self.encode_array(arr)
            parts.append(struct.pack("<I", len(name)) + name
                         + struct.pack("<Q", len(body)) + body)
        blob = struct.pack("<I", len(parts)) + b"".join(parts)
        t1 = time.perf_counter()
        return blob, WireRecord(kind, raw, len(blob), t1 - t0,
                                request_id=request_id, client_id=client_id)

    def decode_tree(self, blob: bytes) -> tuple[dict, float]:
        """Decode a framed pytree stream.  Framing bounds are validated at
        every read — leaf count vs buffer size, name/body lengths vs the
        remaining bytes, and exact consumption of the buffer — so a
        truncated or corrupt blob raises :class:`WireFormatError` rather
        than returning silently-short garbage or a bare ``struct.error``."""
        t0 = time.perf_counter()
        end = len(blob)
        off = _checked(blob, 0, 4, "tree leaf count")
        (n,) = struct.unpack_from("<I", blob, 0)
        # each leaf needs at least its 4+8 length headers: a corrupt count
        # is rejected up front instead of looping until a read trips
        if n > (end - off) // 12:
            raise WireFormatError(
                f"corrupt tree header: {n} leaves cannot fit in "
                f"{end - off} payload bytes")
        out: dict[str, np.ndarray] = {}
        for _ in range(n):
            off = _checked(blob, off, 4, "leaf name length")
            (ln,) = struct.unpack_from("<I", blob, off - 4)
            off = _checked(blob, off, ln, "leaf name")
            try:
                name = blob[off - ln:off].decode()
            except UnicodeDecodeError as e:
                raise WireFormatError(f"corrupt leaf name: {e}") from e
            off = _checked(blob, off, 8, "leaf body length")
            (lb,) = struct.unpack_from("<Q", blob, off - 8)
            off = _checked(blob, off, lb, f"leaf {name!r} body")
            out[name] = self.decode_array(blob[off - lb:off])
        if off != end:
            raise WireFormatError(
                f"corrupt tree: {end - off} trailing bytes after "
                f"{n} leaves")
        return out, time.perf_counter() - t0


def _checked(blob: bytes, off: int, n: int, what: str) -> int:
    """Validate that ``n`` bytes exist at ``off``; return the new offset.
    The single bounds gate every framing read goes through."""
    if n < 0 or off + n > len(blob):
        raise WireFormatError(
            f"truncated wire payload: need {n} bytes for {what} at offset "
            f"{off}, have {len(blob) - off}")
    return off + n


# -- channel-item framing (the byte wire under every transport) ---------------
#
#   [2B magic "DW"] [u8 version] [u8 type] [type-specific body]
#
# Types: envelope / marker / stop / retire — exactly the items the runtime
# puts on a Channel.  Every multi-byte integer is little-endian; variable
# fields are length-prefixed; client ids are JSON with tuples tagged (the
# runtime hashes client ids, so a tuple must come back a tuple).  No pickle
# anywhere: a malicious or corrupt peer can at worst raise WireFormatError.

FRAME_MAGIC = b"DW"
# v2 added the control-plane frame type (_F_CONTROL: heartbeats, worker
# config/knob/bye messages); v3 added the reliability fields (a u32
# `attempt` tag on every extent header and a `retryable` flags byte on
# envelopes) for the dispatcher's replay path; v4 added the decode-session
# fields (a `kind` byte + i64 `pos` on the extent header and a
# length-prefixed session id) for token-step frames.  Readers reject any
# other version outright, so an old peer meets a clean WireFormatError
# instead of a silent misparse; :func:`unframe_compat` keeps the v2/v3
# decode paths alive for mixed-version tests and tooling.
FRAME_VERSION = 4
_COMPAT_VERSIONS = (2, 3, FRAME_VERSION)

_F_ENVELOPE = 1
_F_MARKER = 2
_F_STOP = 3
_F_RETIRE = 4
_F_CONTROL = 5

_NONE_U32 = 0xFFFFFFFF


def _jsonable(v: Any) -> Any:
    """Tuple-tagging JSON transform for client ids and knob values."""
    if isinstance(v, tuple):
        return {"__tuple__": [_jsonable(x) for x in v]}
    if isinstance(v, list):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    raise WireFormatError(
        f"client_id of type {type(v).__name__} is not wire-encodable "
        "(use int / str / float / tuples thereof)")


def _unjsonable(v: Any) -> Any:
    if isinstance(v, dict):
        if set(v) == {"__tuple__"}:
            return tuple(_unjsonable(x) for x in v["__tuple__"])
        return {k: _unjsonable(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_unjsonable(x) for x in v]
    return v


def _pack_obj(v: Any) -> bytes:
    return json.dumps(_jsonable(v), separators=(",", ":")).encode()


def _unpack_obj(blob: bytes) -> Any:
    try:
        return _unjsonable(json.loads(blob.decode()))
    except (ValueError, UnicodeDecodeError) as e:
        raise WireFormatError(f"corrupt framed object: {e}") from e


def validate_client_id(client_id: Any) -> None:
    """Raise :class:`WireFormatError` if ``client_id`` cannot cross a
    byte-framed transport (int / str / float / bool / None / tuples and
    lists thereof).  The dispatcher calls this at admission so a bad id
    is a clear submit-time error on ANY topology, never a mid-chain relay
    failure on the one stage that happens to bind a socket transport."""
    _pack_obj(client_id)


def _pack_bytes(b: bytes) -> bytes:
    return struct.pack("<I", len(b)) + b


def _pack_extent(e: RowExtent, version: int = FRAME_VERSION) -> bytes:
    cid = _pack_obj(e.client_id)
    trim = (struct.pack("<i", -1) if e.pad_trim is None
            else struct.pack(f"<i{len(e.pad_trim)}q", len(e.pad_trim),
                             *e.pad_trim))
    if version >= 4:
        head = struct.pack("<qqqdIBq", e.request_id, e.seq, e.rows,
                           e.t_submit, e.attempt, e.kind, e.pos)
        sess = _pack_bytes(_pack_obj(e.session))
        return head + _pack_bytes(cid) + sess + trim
    if e.kind or e.pos or e.session is not None:
        raise WireFormatError(
            f"session extent (kind={e.kind}, pos={e.pos}, "
            f"session={e.session!r}) is not representable in wire "
            f"v{version} (decode sessions need v4)")
    if version >= 3:
        head = struct.pack("<qqqdI", e.request_id, e.seq, e.rows,
                           e.t_submit, e.attempt)
    else:
        if e.attempt:
            raise WireFormatError(
                f"attempt={e.attempt} is not representable in wire "
                f"v{version} (replay needs v3)")
        head = struct.pack("<qqqd", e.request_id, e.seq, e.rows, e.t_submit)
    return head + _pack_bytes(cid) + trim


def _unpack_extent(blob: bytes, off: int,
                   version: int = FRAME_VERSION) -> tuple[RowExtent, int]:
    attempt, kind, pos = 0, 0, 0
    if version >= 4:
        off = _checked(blob, off, 45, "extent header")
        rid, seq, rows, t_submit, attempt, kind, pos = struct.unpack_from(
            "<qqqdIBq", blob, off - 45)
        if kind > K_CLOSE:
            raise WireFormatError(f"unknown extent kind {kind}")
    elif version >= 3:
        off = _checked(blob, off, 36, "extent header")
        rid, seq, rows, t_submit, attempt = struct.unpack_from(
            "<qqqdI", blob, off - 36)
    else:
        off = _checked(blob, off, 32, "extent header")
        rid, seq, rows, t_submit = struct.unpack_from("<qqqd", blob, off - 32)
    off = _checked(blob, off, 4, "extent client id length")
    (ln,) = struct.unpack_from("<I", blob, off - 4)
    off = _checked(blob, off, ln, "extent client id")
    cid = _unpack_obj(blob[off - ln:off])
    try:
        hash(cid)
    except TypeError as e:
        raise WireFormatError(f"unhashable client id on the wire: {e}") from e
    session = None
    if version >= 4:
        off = _checked(blob, off, 4, "extent session id length")
        (ls,) = struct.unpack_from("<I", blob, off - 4)
        off = _checked(blob, off, ls, "extent session id")
        session = _unpack_obj(blob[off - ls:off])
        try:
            hash(session)
        except TypeError as e:
            raise WireFormatError(
                f"unhashable session id on the wire: {e}") from e
    off = _checked(blob, off, 4, "extent pad_trim count")
    (nt,) = struct.unpack_from("<i", blob, off - 4)
    trim = None
    if nt >= 0:
        off = _checked(blob, off, 8 * nt, "extent pad_trim values")
        trim = struct.unpack_from(f"<{nt}q", blob, off - 8 * nt)
    return RowExtent(rid, cid, seq, rows, t_submit=t_submit,
                     pad_trim=trim, attempt=attempt,
                     session=session, pos=pos, kind=kind), off


def _codec_fields(c: "WireCodec") -> bytes:
    return _pack_obj([c.serializer, c.compression, c.zfp_rate, c.vectorized])


def _codec_from_fields(blob: bytes) -> "WireCodec":
    f = _unpack_obj(blob)
    if (not isinstance(f, list) or len(f) != 4
            or not all(isinstance(x, t) for x, t in
                       zip(f, (str, str, int, bool)))):
        raise WireFormatError(f"corrupt wire codec descriptor: {f!r}")
    return WireCodec(serializer=f[0], compression=f[1], zfp_rate=f[2],
                     vectorized=f[3])


def frame(item: Any, version: int = FRAME_VERSION) -> bytes:
    """Serialize one channel item to the versioned byte wire (no pickle).
    Accepts exactly what the runtime puts on channels: a
    :class:`BatchEnvelope`, a :class:`ReconfigMarker` (with its
    :class:`NodePlan` payloads), or the ``_STOP``/``_RETIRE`` tokens.
    ``version`` selects the wire revision to speak (current by default;
    v2/v3 are kept for compat tests and refuse items that carry fields
    introduced after them — v3-only reliability fields, v4-only decode
    session fields)."""
    if version not in _COMPAT_VERSIONS:
        raise WireFormatError(
            f"cannot speak frame version {version} "
            f"(supported: {_COMPAT_VERSIONS})")

    def head(ftype: int) -> bytes:
        return FRAME_MAGIC + struct.pack("<BB", version, ftype)

    if item is _STOP:
        return head(_F_STOP)
    if item is _RETIRE:
        return head(_F_RETIRE)
    if isinstance(item, BatchEnvelope):
        err = (struct.pack("<I", _NONE_U32) if item.error is None
               else _pack_bytes(item.error.encode()))
        if version >= 3:
            flags = struct.pack("<B", 1 if item.retryable else 0)
        elif item.retryable:
            raise WireFormatError(
                "retryable envelopes are not representable in wire "
                f"v{version} (replay needs v3)")
        else:
            flags = b""
        return (head(_F_ENVELOPE) + struct.pack("<q", item.epoch) + flags
                + err + struct.pack("<I", len(item.extents))
                + b"".join(_pack_extent(e, version) for e in item.extents)
                + struct.pack("<Q", len(item.blob)) + item.blob)
    if isinstance(item, ReconfigMarker):
        parts = [head(_F_MARKER), struct.pack("<q", item.epoch),
                 struct.pack("<I", len(item.plans))]
        for stage, plan in sorted(item.plans.items()):
            parts.append(struct.pack("<iqqq", stage, plan.lo, plan.hi,
                                     plan.wire_bytes))
            parts.append(_pack_bytes(plan.arch_blob))
            parts.append(struct.pack("<Q", len(plan.weights_blob)))
            parts.append(plan.weights_blob)
            parts.append(_pack_bytes(_codec_fields(plan.weights_codec)))
        return b"".join(parts)
    if isinstance(item, ControlFrame):
        return (head(_F_CONTROL) + _pack_bytes(item.kind.encode())
                + _pack_bytes(_pack_obj(item.payload)))
    raise WireFormatError(
        f"{type(item).__name__} is not a channel item (expected "
        "BatchEnvelope, ReconfigMarker, or a control token)")


def _unframe_envelope(blob: bytes, off: int,
                      version: int = FRAME_VERSION) -> BatchEnvelope:
    off = _checked(blob, off, 8, "envelope epoch")
    (epoch,) = struct.unpack_from("<q", blob, off - 8)
    retryable = False
    if version >= 3:
        off = _checked(blob, off, 1, "envelope flags")
        flags = blob[off - 1]
        if flags > 1:
            raise WireFormatError(f"corrupt envelope flags {flags:#x}")
        retryable = bool(flags)
    off = _checked(blob, off, 4, "envelope error length")
    (el,) = struct.unpack_from("<I", blob, off - 4)
    error = None
    if el != _NONE_U32:
        off = _checked(blob, off, el, "envelope error")
        try:
            error = blob[off - el:off].decode()
        except UnicodeDecodeError as e:
            raise WireFormatError(f"corrupt envelope error text: {e}") from e
    off = _checked(blob, off, 4, "envelope extent count")
    # min extent: the fixed header (45B in v4, 36B in v3, 32B in v2) plus
    # the cid-length / pad_trim-count u32s (v4 adds a session-length u32)
    min_extent = (45 + 12 if version >= 4
                  else (36 if version >= 3 else 32) + 8)
    (n,) = struct.unpack_from("<I", blob, off - 4)
    if n > (len(blob) - off) // min_extent:
        raise WireFormatError(
            f"corrupt envelope: {n} extents cannot fit in "
            f"{len(blob) - off} bytes")
    extents = []
    for _ in range(n):
        e, off = _unpack_extent(blob, off, version)
        extents.append(e)
    off = _checked(blob, off, 8, "envelope blob length")
    (lb,) = struct.unpack_from("<Q", blob, off - 8)
    off = _checked(blob, off, lb, "envelope blob")
    if off != len(blob):
        raise WireFormatError(
            f"corrupt envelope: {len(blob) - off} trailing bytes")
    return BatchEnvelope(extents, blob[off - lb:off], error=error,
                         retryable=retryable, epoch=epoch)


def _unframe_marker(blob: bytes, off: int) -> ReconfigMarker:
    off = _checked(blob, off, 8, "marker epoch")
    (epoch,) = struct.unpack_from("<q", blob, off - 8)
    off = _checked(blob, off, 4, "marker plan count")
    (n,) = struct.unpack_from("<I", blob, off - 4)
    if n > (len(blob) - off) // 28:      # min plan: 28B fixed header
        raise WireFormatError(
            f"corrupt marker: {n} plans cannot fit in "
            f"{len(blob) - off} bytes")
    plans: dict[int, NodePlan] = {}
    for _ in range(n):
        off = _checked(blob, off, 28, "plan header")
        stage, lo, hi, wire_bytes = struct.unpack_from(
            "<iqqq", blob, off - 28)
        off = _checked(blob, off, 4, "plan arch length")
        (la,) = struct.unpack_from("<I", blob, off - 4)
        off = _checked(blob, off, la, "plan arch blob")
        arch = blob[off - la:off]
        off = _checked(blob, off, 8, "plan weights length")
        (lw,) = struct.unpack_from("<Q", blob, off - 8)
        off = _checked(blob, off, lw, "plan weights blob")
        weights = blob[off - lw:off]
        off = _checked(blob, off, 4, "plan codec length")
        (lc,) = struct.unpack_from("<I", blob, off - 4)
        off = _checked(blob, off, lc, "plan codec descriptor")
        codec = _codec_from_fields(blob[off - lc:off])
        plans[stage] = NodePlan(lo, hi, arch, weights, codec,
                                wire_bytes=wire_bytes)
    if off != len(blob):
        raise WireFormatError(
            f"corrupt marker: {len(blob) - off} trailing bytes")
    return ReconfigMarker(epoch, plans)


def _unframe_control(blob: bytes, off: int) -> ControlFrame:
    off = _checked(blob, off, 4, "control kind length")
    (lk,) = struct.unpack_from("<I", blob, off - 4)
    off = _checked(blob, off, lk, "control kind")
    try:
        kind = blob[off - lk:off].decode()
    except UnicodeDecodeError as e:
        raise WireFormatError(f"corrupt control kind: {e}") from e
    off = _checked(blob, off, 4, "control payload length")
    (lp,) = struct.unpack_from("<I", blob, off - 4)
    off = _checked(blob, off, lp, "control payload")
    payload = _unpack_obj(blob[off - lp:off])
    if off != len(blob):
        raise WireFormatError(
            f"corrupt control frame: {len(blob) - off} trailing bytes")
    if not isinstance(payload, dict):
        raise WireFormatError(
            f"control payload must be a dict, got {type(payload).__name__}")
    return ControlFrame(kind, payload)


def _unframe_versions(blob: bytes, versions: tuple[int, ...]) -> Any:
    try:
        _checked(blob, 0, 4, "frame header")
        if blob[:2] != FRAME_MAGIC:
            raise WireFormatError(f"bad frame magic {blob[:2]!r}")
        version, ftype = struct.unpack_from("<BB", blob, 2)
        if version not in versions:
            raise WireFormatError(
                f"unsupported frame version {version} "
                f"(speaking {FRAME_VERSION})")
        if ftype == _F_STOP:
            return _STOP
        if ftype == _F_RETIRE:
            return _RETIRE
        if ftype == _F_ENVELOPE:
            return _unframe_envelope(blob, 4, version)
        if ftype == _F_MARKER:
            return _unframe_marker(blob, 4)
        if ftype == _F_CONTROL:
            return _unframe_control(blob, 4)
        raise WireFormatError(f"unknown frame type {ftype}")
    except WireFormatError:
        raise
    except Exception as e:      # any residual parse error is a wire fault
        raise WireFormatError(f"corrupt frame: {e}") from e


def unframe(blob: bytes) -> Any:
    """Parse one framed channel item.  Every read is bounds-checked; any
    malformation — short buffer, bad magic, unknown version or type,
    lengths past the end, trailing bytes — raises
    :class:`WireFormatError`.  Control tokens come back as the SAME
    singletons the in-process runtime identity-compares against.  Only
    the CURRENT wire version is accepted (the runtime assumes every peer
    speaks it); :func:`unframe_compat` additionally accepts v2 frames."""
    return _unframe_versions(blob, (FRAME_VERSION,))


def unframe_compat(blob: bytes) -> Any:
    """Like :func:`unframe` but accepts every supported wire revision
    (currently v2, v3 and v4).  v2 extents come back with ``attempt=0``
    and v2 envelopes with ``retryable=False``; pre-v4 extents come back
    with ``session=None``/``kind=0`` — exactly the semantics an older
    speaker meant.  For tooling and rolling-upgrade tests; the serving
    hot path stays strict."""
    return _unframe_versions(blob, _COMPAT_VERSIONS)


def tree_unflatten_paths(flat: dict[str, np.ndarray]) -> dict:
    """'a/b/c' path keys -> nested dicts (inverse of encode_tree's framing)."""
    root: dict = {}
    for path, arr in flat.items():
        keys = path.split("/")
        cur = root
        for k in keys[:-1]:
            cur = cur.setdefault(k, {})
        cur[keys[-1]] = arr
    return root
