"""The wire format between DEFER nodes: serialize -> compress -> chunk.

Every payload that crosses a (simulated) socket goes through here, so byte
counts and encode/decode timings are measured in one place.  Mirrors the
paper: 512 kB chunking, {JSON, ZFP, Q8} serializers x {LZ4, none}
compression, independent codec choice per payload type (architecture /
weights / data).

Since the staged-relay runtime, inter-node data payloads are **batch-level**:
a compute node encodes the stacked output of a whole continuous batch ONCE
and ships it as a single :class:`BatchEnvelope` whose *envelope* (not blob)
carries per-request row extents.  One ZFP/LZ4/Q8 pass amortizes fixed codec
cost across the batch and lets LZ4 find cross-request matches; the receiving
node decodes once and only the tail collector slices rows back out
(:func:`slice_parts`).  The wire blob itself is the same framed pytree
stream as before — ``encode_tree``/``decode_tree`` — so batch payloads and
config payloads share one format:

    [u32 leaf_count] then per leaf:
    [u32 name_len][name][u64 body_len][body = serializer(+lz4) bytes]

``request_id`` is globally unique (admission order) and is what the
collector demuxes results by; continuous batching may legally reorder
requests of *different* clients, and a client's own results still come back
FIFO because ``stream()`` awaits futures in submission order.
"""
from __future__ import annotations

import dataclasses
import io
import json
import struct
import time
from typing import Any

import numpy as np

from repro.core import codecs

CHUNK_BYTES = 512 * 1024


@dataclasses.dataclass
class WireRecord:
    kind: str                   # "architecture" | "weights" | "data"
    raw_bytes: int
    wire_bytes: int
    encode_s: float
    decode_s: float = 0.0
    # request routing (None for config-step payloads): lets per-payload
    # metrics be correlated back to the admission stream
    request_id: int | None = None
    client_id: int | None = None

    @property
    def chunks(self) -> int:
        return max(1, -(-self.wire_bytes // CHUNK_BYTES))


@dataclasses.dataclass
class Envelope:
    """One in-flight request's payload between chain hops (PR 1 wire).

    Superseded by :class:`BatchEnvelope` inside the staged runtime; kept as
    a public single-request view for tooling and tests.
    """

    request_id: int
    client_id: int
    seq: int                    # submission index within client
    blob: bytes
    t_submit: float = 0.0       # admission timestamp (perf_counter)


@dataclasses.dataclass(frozen=True)
class RowExtent:
    """One request's slice of a batch payload: rows [offset..offset+rows)
    along axis 0 of every leaf, where offset is the sum of preceding
    extents' rows.  Routing metadata rides the envelope, not the blob."""

    request_id: int
    client_id: Any
    seq: int                    # submission index within client
    rows: int                   # this request's rows in the stacked tensor
    t_submit: float = 0.0       # admission timestamp (perf_counter)
    # set when bucketed pad-to-shape merged this request into a wider
    # bucket: the ORIGINAL middle-axis sizes (everything between axis 0
    # and the last axis) the collector trims results back to
    pad_trim: tuple | None = None


@dataclasses.dataclass
class BatchEnvelope:
    """A whole continuous batch on the wire: ONE encoded stacked payload
    plus per-request row-extent framing.  ``error`` carries a formatted
    traceback instead of a payload when an upstream stage failed — the
    envelope still flows to the tail so the collector can fail exactly the
    affected futures while the chain keeps serving."""

    extents: list[RowExtent]
    blob: bytes
    error: str | None = None
    # partition epoch the producing stage was on when it encoded this
    # envelope.  With replicated stages the chain is no longer one global
    # FIFO: a fast replica can emit post-fence output while a slow sibling
    # still drains pre-fence work, so the next stage's router HOLDS any
    # envelope stamped ahead of its own epoch until the fence barrier
    # completes — no request ever sees a mixed-epoch chain.
    epoch: int = 0

    @property
    def n(self) -> int:
        return len(self.extents)

    @property
    def rows(self) -> int:
        return sum(e.rows for e in self.extents)


def slice_parts(flat: dict[str, np.ndarray],
                extents: list[RowExtent]) -> list[dict[str, np.ndarray]]:
    """Invert batch stacking: one {name: array} view per extent (no copy).

    An extent carrying ``pad_trim`` was zero-padded along its middle axes
    to merge into a wider shape bucket; its leaves are trimmed back to the
    original sizes here (rank-preserving layers only — a leaf whose rank
    no longer matches the recorded trim is passed through untouched)."""
    parts = []
    off = 0
    for e in extents:
        part = {k: v[off:off + e.rows] for k, v in flat.items()}
        if e.pad_trim is not None:
            trim = tuple(slice(0, s) for s in e.pad_trim)
            part = {k: (v[(slice(None),) + trim]
                        if v.ndim == len(e.pad_trim) + 2 else v)
                    for k, v in part.items()}
        parts.append(part)
        off += e.rows
    return parts


@dataclasses.dataclass
class NodePlan:
    """One node's share of a live repartition: its new layer range, the
    wire-encoded architecture spec, and the weights of only the layers it
    GAINS (weight-diff shipping — layers it keeps never travel again)."""

    lo: int
    hi: int
    arch_blob: bytes
    weights_blob: bytes                 # gained layers only; b"" if none
    weights_codec: "WireCodec"
    wire_bytes: int = 0                 # len(arch) + len(weights) on the wire


@dataclasses.dataclass
class ReconfigMarker:
    """The epoch fence for a live repartition.

    Injected at the head of the chain and relayed hop-by-hop IN ORDER with
    the data envelopes: every envelope ahead of the marker is processed by
    the old partition at every node, every envelope behind it by the new
    one — each node swaps exactly when the marker passes its compute
    stage, so no in-flight request ever sees a mixed chain and none is
    dropped or recomputed.  With replicated stages, each stage's router
    broadcasts the marker to every replica and the NEXT stage's router
    (or the tail collector) runs a counting barrier — the fence advances
    only once every replica has flushed it, and post-fence envelopes from
    fast replicas are held at the barrier (``BatchEnvelope.epoch``).
    Membership changes (spawn/drain of replicas) ride the same fence:
    the affected stage's router applies its pending membership exactly
    when the marker passes, so elasticity inherits the zero-loss
    guarantee.  The tail collector observes the completed barrier to
    acknowledge the epoch switch chain-wide."""

    epoch: int
    plans: dict[int, NodePlan]          # stage index -> its new assignment


@dataclasses.dataclass(frozen=True)
class WireCodec:
    serializer: str = "zfp"     # "json" | "zfp" | "q8" | "raw"
    compression: str = "none"   # "lz4" | "none"
    zfp_rate: int = 24
    # vectorized=False selects the pure-Python/copying reference codec
    # implementations (the PR 1 hot path) — kept so serve_load can measure
    # the staged runtime against a faithful same-codec PR 1 baseline
    vectorized: bool = True

    @property
    def label(self) -> str:
        comp = "LZ4" if self.compression == "lz4" else "Uncompressed"
        return f"{self.serializer.upper()}/{comp}"

    def error_bound(self, absmax: float) -> float:
        """Worst-case absolute error for one encode/decode pass over values
        with |x| <= absmax (0.0 for the lossless serializers)."""
        if self.serializer == "q8":
            return codecs.Q8Codec().error_bound(absmax)
        if self.serializer == "zfp":
            return codecs.ZfpCodec(rate=self.zfp_rate).error_bound(absmax)
        return 0.0

    # -- arrays (weights / activations) ------------------------------------
    def encode_array(self, arr: np.ndarray) -> bytes:
        if self.serializer == "raw":
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            blob = buf.getvalue()
        elif self.serializer == "json":
            blob = codecs.JsonCodec().encode(arr)
        elif self.serializer == "q8":
            blob = codecs.Q8Codec().encode(arr)
        else:
            blob = codecs.ZfpCodec(rate=self.zfp_rate,
                                   vectorized=self.vectorized).encode(arr)
        if self.compression == "lz4":
            blob = codecs.Lz4Codec(vectorized=self.vectorized).compress(blob)
        return blob

    def decode_array(self, blob: bytes) -> np.ndarray:
        if self.compression == "lz4":
            blob = codecs.Lz4Codec(vectorized=self.vectorized).decompress(blob)
        if self.serializer == "raw":
            return np.load(io.BytesIO(blob), allow_pickle=False)
        if self.serializer == "json":
            return codecs.JsonCodec().decode(blob)
        if self.serializer == "q8":
            return codecs.Q8Codec().decode(blob)
        return codecs.ZfpCodec(rate=self.zfp_rate,
                               vectorized=self.vectorized).decode(blob)

    # -- structured payloads (pytrees of arrays) -----------------------------
    def encode_tree(self, tree: Any, kind: str,
                    request_id: int | None = None,
                    client_id: int | None = None) -> tuple[bytes, WireRecord]:
        """Flatten a {name: array} pytree into one framed stream."""
        import jax
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        t0 = time.perf_counter()
        parts: list[bytes] = []
        raw = 0
        for path, leaf in flat:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path).encode()
            arr = np.asarray(leaf)
            raw += arr.nbytes
            body = self.encode_array(arr)
            parts.append(struct.pack("<I", len(name)) + name
                         + struct.pack("<Q", len(body)) + body)
        blob = struct.pack("<I", len(parts)) + b"".join(parts)
        t1 = time.perf_counter()
        return blob, WireRecord(kind, raw, len(blob), t1 - t0,
                                request_id=request_id, client_id=client_id)

    def decode_tree(self, blob: bytes) -> tuple[dict, float]:
        t0 = time.perf_counter()
        (n,) = struct.unpack_from("<I", blob, 0)
        off = 4
        out: dict[str, np.ndarray] = {}
        for _ in range(n):
            (ln,) = struct.unpack_from("<I", blob, off); off += 4
            name = blob[off:off + ln].decode(); off += ln
            (lb,) = struct.unpack_from("<Q", blob, off); off += 8
            out[name] = self.decode_array(blob[off:off + lb]); off += lb
        return out, time.perf_counter() - t0


def tree_unflatten_paths(flat: dict[str, np.ndarray]) -> dict:
    """'a/b/c' path keys -> nested dicts (inverse of encode_tree's framing)."""
    root: dict = {}
    for path, arr in flat.items():
        keys = path.split("/")
        cur = root
        for k in keys[:-1]:
            cur = cur.setdefault(k, {})
        cur[keys[-1]] = arr
    return root
