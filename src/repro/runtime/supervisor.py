"""Self-healing process-per-replica supervision.

The :class:`Supervisor` plugs into the dispatcher's ``replica_factory``
hook: every replica the engine builds — at construction AND through the
:meth:`~repro.runtime.dispatcher.Dispatcher.scale` spawn path — becomes a
:class:`WorkerHandle` fronting a real OS process running
``python -m repro.runtime.worker``.  The handle duck-types
:class:`~repro.runtime.node.ComputeNode` completely (configure /
precompile / start / retire / join, knobs, snapshot, trace telemetry), so
the dispatcher, routers, controller, and engine report code are unchanged:
a stage may be process-backed or in-process and nothing upstream can tell.

Wiring per worker (all on loopback, all byte-framed, no pickle):

* a **control socket** the worker dials at launch (token handshake) —
  carries the config handoff (graph factory name + a
  :class:`~repro.runtime.wire.NodePlan` with architecture + weights, the
  same framing a live repartition ships), knob updates, periodic
  ``"hb"`` heartbeats with the node's snapshot, and the clean ``"bye"``;
* two **data channels** completed against the supervisor's private
  :class:`~repro.runtime.transport.TcpTransport` listener
  (:meth:`~repro.runtime.transport.TcpTransport.expect_channel` /
  :func:`~repro.runtime.transport.dial_channel`): the worker's inbox
  (router -> worker) and its egress stream (worker -> relay thread ->
  next stage's input), with the credit-window backpressure contract
  intact across the process boundary.

Failure detection is layered: OS child reaping (``poll``), heartbeat age
(a dead or wedged *process*), and optional stall detection (heartbeats
flowing but the snapshot frozen with a backlog — a hung compute thread,
which heartbeat-age alone must NOT page on since the heartbeat thread is
healthy).  On a crash the monitor reuses the elastic heal path end to
end: sever the dead worker's channels (the routers' ``probe_members``
then retires it and fails exactly the stranded batches), nudge a
zero-extent envelope through the chain so even an idle router probes,
and respawn through ``dispatcher.scale`` with exponential backoff under
a bounded per-stage budget.  When the budget is exhausted the stage
**degrades** to its surviving replicas — the chain keeps serving — and a
quiet period (``stable_s``) refunds the budget.

``Supervisor.close()`` reaps every child it ever spawned (terminate ->
kill escalation), so no test or benchmark run can leak orphan processes.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import subprocess
import sys
import threading
import time

from repro.runtime.node import BatchTrace
from repro.runtime.transport import (ChannelClosed, TcpTransport,
                                     recv_framed, send_framed)
from repro.runtime.wire import (_RETIRE, _STOP, BatchEnvelope, ControlFrame,
                                NodePlan, ReconfigMarker, WireFormatError)


@dataclasses.dataclass
class SupervisorConfig:
    """Knobs for process supervision.

    ``graph_factory`` names how a *worker* rebuilds the layer graph
    locally: ``"pkg.module:fn"`` or ``"/path/to/file.py:fn"``, called as
    ``fn(**graph_args)`` — layer code is pre-installed on every node (the
    paper's deployment model); only topology and weights travel."""

    graph_factory: str
    graph_args: dict = dataclasses.field(default_factory=dict)
    heartbeat_s: float = 0.5            # worker hb period
    heartbeat_timeout_s: float = 5.0    # no hb this long -> declared dead
    stall_timeout_s: float | None = None    # hb alive but frozen + backlog
    spawn_timeout_s: float = 60.0       # hello/ready deadline per worker
    shutdown_grace_s: float = 10.0      # join/reap patience per worker
    backoff_initial_s: float = 0.25     # respawn backoff ladder
    backoff_max_s: float = 5.0
    backoff_factor: float = 2.0
    respawn_budget: int = 3             # per-stage crash allowance
    stable_s: float = 30.0              # quiet period refunding the budget
    allow_chaos: bool = False           # spawn workers with --chaos
    env: dict = dataclasses.field(default_factory=dict)
    python: str | None = None           # worker interpreter; None = ours


class WorkerHandle:
    """Supervisor-side stand-in for one process-backed replica.

    Duck-types :class:`~repro.runtime.node.ComputeNode` for everything
    the dispatcher, routers, controller, and engine report touch.  Its
    ``inbox`` is the send half of the worker's inbox channel (so router
    sends cross the socket), and a relay thread forwards the worker's
    egress stream into ``next_inbox`` — the one ComputeNode duty that
    must live supervisor-side, because the worker cannot reach the next
    stage's in-process channel directly.

    ``lost_on_death = True`` widens the router's heal path: a killed
    process loses batches it had already *consumed* (they were inside
    its pipeline), so the whole in-flight ledger fails, not just the
    channel's unconsumed tail.  Entries whose results already reached
    the collector resolve to no-ops there — at-most-once, never a hang.
    """

    lost_on_death = True
    staged = True

    def __init__(self, sup: "Supervisor", stage: int, replica: int,
                 inbox, outbox, in_cid: int, out_cid: int,
                 capacity: int, token: str, spec, codec):
        self._sup = sup
        self.index = stage
        self.replica = replica
        self.inbox = inbox              # send half: router -> worker
        self._outbox = outbox           # recv half: worker -> relay
        self._in_cid = in_cid
        self._out_cid = out_cid
        self._capacity = capacity
        self.token = token
        self._spec = spec               # the stage's StageSpec
        self._data_codec = codec
        self.retiring = False
        self.dead = False
        self.bye = False
        self.epoch = 0
        self.max_batch_cap = 1          # finalized in _spawn, like the knobs
        self._max_batch = 1
        self._coalesce_s = 0.005
        self._configured = False
        self._started_flag = False
        self.proc: subprocess.Popen | None = None
        self._csock: socket.socket | None = None
        self._send_lock = threading.Lock()
        self._hello = threading.Event()
        self._ready = threading.Event()
        self._creader: threading.Thread | None = None
        # telemetry, synthesized from heartbeat snapshot deltas so the
        # engine report and the controller read a worker exactly like an
        # in-process node
        self._stats_lock = threading.Lock()
        self.traces: list[BatchTrace] = []
        self.queue_depths: list[float] = []
        self.busy_decode_s = 0.0
        self.busy_compute_s = 0.0
        self.busy_encode_s = 0.0
        self.config_records: list = []
        self._nodes: list = []
        self._last_snap: dict = {}
        self._base_snap: dict = {}
        self._hb_at: float | None = None
        self._progress_n = -1
        self._progress_at = time.monotonic()
        self._fwd_tokens = 0            # control tokens relayed downstream
        self._relay_thread = threading.Thread(target=self._relay_loop,
                                              daemon=True)
        self._threads = [self._relay_thread]    # live_replicas() prunes on
        self._relay_thread.start()              # these, like a real node
        self.next_inbox = None

    # -- the egress relay ------------------------------------------------------
    def _relay_loop(self) -> None:
        """Worker egress -> next stage's input.  Envelopes, fence markers,
        and the _STOP cascade all pass through untouched, so downstream
        barrier counting sees exactly one copy per upstream replica —
        process-backed or not.  _RETIRE never arrives (the worker's own
        egress exits without forwarding it); a severed socket ends the
        loop without forwarding anything (the router proxies whatever the
        dead member still owed downstream)."""
        while True:
            try:
                item = self._outbox.recv()
            except ChannelClosed:
                return
            try:
                if self.next_inbox is not None:
                    self.next_inbox.send(item)
            except (ChannelClosed, OSError):
                if item is _STOP:
                    return
                continue        # downstream gone: its own death path owns it
            if not isinstance(item, BatchEnvelope):
                # count fence/stop copies that actually crossed into the
                # next stage: after a crash the router settles the SENT
                # minus FORWARDED difference so barrier counts stay exact
                self._fwd_tokens += 1
            if item is _STOP:
                return

    def forwarded_tokens(self) -> int:
        """How many control tokens (fence markers, _STOP) the relay has
        pushed downstream.  The router's settle path reads this after the
        member dies — with the relay thread joined, so the count is
        final — to proxy exactly the copies the worker was sent but never
        forwarded (lost in the dead process / its doomed socket buffer)."""
        return self._fwd_tokens

    # -- control plane ---------------------------------------------------------
    def _attach_control(self, conn: socket.socket) -> None:
        self._csock = conn
        self._hb_at = time.monotonic()
        self._creader = threading.Thread(target=self._control_loop,
                                         daemon=True)
        self._creader.start()
        self._hello.set()

    def _control_loop(self) -> None:
        sock = self._csock
        while True:
            try:
                item = recv_framed(sock)
            except (WireFormatError, OSError):
                return          # EOF: crash or post-bye close; monitor decides
            if not isinstance(item, ControlFrame):
                continue
            if item.kind == "hb":
                self._on_hb(item.payload)
            elif item.kind == "ready":
                self._hb_at = time.monotonic()
                self._ready.set()
            elif item.kind == "bye":
                self.bye = True
                return

    def _control_send(self, item, required: bool = False) -> None:
        sock = self._csock
        if sock is None:
            if required:
                raise ChannelClosed("worker control socket not attached")
            return
        try:
            send_framed(sock, item, lock=self._send_lock)
        except OSError as e:
            if required:
                raise ChannelClosed(f"worker control send failed: {e}") from e

    def _on_hb(self, payload: dict) -> None:
        snap = payload.get("snapshot") or {}

        def g(d: dict, k: str):
            return d.get(k, 0) or 0

        with self._stats_lock:
            self._hb_at = time.monotonic()
            prev, self._last_snap = self._last_snap, snap
            dn = int(g(snap, "n") - g(prev, "n"))
            if dn > 0:
                # one synthetic trace per heartbeat interval: totals
                # (requests, stage seconds, payload) aggregate exactly;
                # only per-wave shape (batch_mean) coarsens to per-interval
                self.traces.append(BatchTrace(
                    self.index, dn, 0,
                    g(snap, "deserialize_s") - g(prev, "deserialize_s"),
                    g(snap, "compute_s") - g(prev, "compute_s"),
                    g(snap, "serialize_s") - g(prev, "serialize_s"),
                    int(g(snap, "payload_bytes") - g(prev, "payload_bytes")),
                    encodes=int(g(snap, "encodes") - g(prev, "encodes"))))
            dc = g(snap, "depth_count") - g(prev, "depth_count")
            if dc > 0:
                self.queue_depths.append(
                    (g(snap, "depth_sum") - g(prev, "depth_sum")) / dc)
            base = self._base_snap
            self.busy_decode_s = g(snap, "busy_decode_s") \
                - g(base, "busy_decode_s")
            self.busy_compute_s = g(snap, "busy_compute_s") \
                - g(base, "busy_compute_s")
            self.busy_encode_s = g(snap, "busy_encode_s") \
                - g(base, "busy_encode_s")
            self.epoch = int(g(snap, "epoch"))
            if dn != 0:
                self._progress_n = int(g(snap, "n"))
                self._progress_at = self._hb_at

    # -- ComputeNode surface ---------------------------------------------------
    @property
    def max_batch(self) -> int:
        return self._max_batch

    @max_batch.setter
    def max_batch(self, v: int) -> None:
        self._max_batch = max(1, int(v))
        self._push_knobs()

    @property
    def coalesce_s(self) -> float:
        return self._coalesce_s

    @coalesce_s.setter
    def coalesce_s(self, v: float) -> None:
        self._coalesce_s = max(0.0, float(v))
        self._push_knobs()

    def _push_knobs(self) -> None:
        if self._configured:
            self._control_send(ControlFrame("knobs", {
                "max_batch": self._max_batch,
                "coalesce_s": self._coalesce_s}))

    def configure(self, graph, lo: int, hi: int, arch_blob: bytes,
                  weights_blob: bytes, weights_codec) -> None:
        """The configuration step, over the control socket: channel
        wiring + codec + knobs ride a ``"config"`` frame, then the
        architecture + weights ship as the standard NodePlan framing."""
        self._nodes = graph.slice_nodes(lo, hi)
        cfg = self._sup._cfg
        host, port = self._sup._transport.address
        c = self._data_codec
        self._control_send(ControlFrame("config", {
            "graph_factory": cfg.graph_factory,
            "graph_args": cfg.graph_args,
            "stage": self.index, "replica": self.replica,
            "data_codec": [c.serializer, c.compression, c.zfp_rate,
                           c.vectorized, c.small_bypass],
            "session_capacity": getattr(self._spec, "session_capacity",
                                        None) or 64,
            "max_batch": self._max_batch,
            "coalesce_s": self._coalesce_s,
            "max_batch_cap": self.max_batch_cap,
            "staged": self.staged,
            "shape_buckets": self._spec.shape_buckets
            or self._sup._defaults.get("shape_buckets", "exact"),
            "host": host, "port": port,
            "in_cid": self._in_cid, "in_capacity": self._capacity,
            "out_cid": self._out_cid, "out_capacity": self._capacity,
            "heartbeat_s": cfg.heartbeat_s,
        }), required=True)
        self._control_send(ReconfigMarker(0, {self.index: NodePlan(
            lo, hi, arch_blob, weights_blob, weights_codec,
            wire_bytes=len(arch_blob) + len(weights_blob))}),
            required=True)
        self._configured = True

    def precompile(self) -> None:
        # applied before any later control frame (the worker loop is
        # serial); best-effort on a dead socket — the monitor owns deaths
        self._control_send(ControlFrame("precompile"))

    def start(self) -> None:
        if self._started_flag:
            return
        self._control_send(ControlFrame("start"), required=True)
        if not self._ready.wait(self._sup._cfg.spawn_timeout_s):
            raise ChannelClosed(
                f"worker stage {self.index} replica {self.replica} not "
                f"ready within {self._sup._cfg.spawn_timeout_s}s")
        self._started_flag = True

    def retire(self) -> None:
        self.inbox.send(_RETIRE)

    def reset_stats(self) -> None:
        # local-only: rebaseline against the worker's lifetime counters
        # instead of round-tripping a reset (windowing stays exact)
        with self._stats_lock:
            self._base_snap = self._last_snap
            self.traces = []
            self.queue_depths = []
            self.busy_decode_s = 0.0
            self.busy_compute_s = 0.0
            self.busy_encode_s = 0.0

    def snapshot(self) -> dict:
        """Window telemetry (same keys as ComputeNode.snapshot), rebuilt
        from the last heartbeat relative to the reset baseline."""
        with self._stats_lock:
            last, base = self._last_snap, self._base_snap

            def d(k: str):
                return (last.get(k, 0) or 0) - (base.get(k, 0) or 0)

            waves = d("waves")
            depth_count = d("depth_count")
            return {
                "node": self.index, "replica": self.replica,
                "n": d("n"), "compute_s": d("compute_s"),
                "serialize_s": d("serialize_s"),
                "deserialize_s": d("deserialize_s"),
                "payload_bytes": d("payload_bytes"),
                "encodes": d("encodes"),
                "busy_decode_s": self.busy_decode_s,
                "busy_compute_s": self.busy_compute_s,
                "busy_encode_s": self.busy_encode_s,
                "queue_depth_mean": (d("depth_sum") / depth_count
                                     if depth_count else 0.0),
                "batch_mean": (d("n") / waves if waves else 0.0),
                "waves": waves,
                "depth_sum": d("depth_sum"),
                "depth_count": depth_count,
                "max_batch": self._max_batch,
                "coalesce_s": self._coalesce_s,
                "epoch": self.epoch,
                # a gauge, not a window counter: report it as-is
                "inflight_n": last.get("inflight_n", 0) or 0,
            }

    def kill_links(self) -> None:
        """Sever both data channels (the router's ``probe_members`` then
        heals the routing set; the relay thread wakes and exits)."""
        self.inbox.kill()
        self._outbox.kill()

    def reap(self, grace: float = 5.0) -> None:
        """Make sure the child is gone: wait, escalate to terminate, then
        kill.  Every shutdown path funnels through here, so a supervised
        run can never leave an orphan process behind."""
        proc = self.proc
        if proc is None:
            return
        if proc.poll() is None:
            try:
                proc.wait(timeout=grace)
            except subprocess.TimeoutExpired:
                proc.terminate()
                try:
                    proc.wait(timeout=grace)
                except subprocess.TimeoutExpired:
                    proc.kill()
                    proc.wait()

    def join(self) -> None:
        """Dispatcher-shutdown path: wait for the relay to flush, then
        reap the process.  Bounded — a wedged worker (hung compute, so
        _STOP never flushes) gets its links severed and the process
        forcibly reaped instead of hanging engine shutdown forever."""
        grace = self._sup._cfg.shutdown_grace_s
        t = self._relay_thread
        if t.is_alive():
            t.join(grace)
            if t.is_alive():
                self.kill_links()
                t.join(1.0)
        self.reap(grace)


class Supervisor:
    """Spawns, watches, heals, and reaps process-per-replica workers.

    Use :func:`supervised_engine`, or wire manually::

        sup = Supervisor(SupervisorConfig(graph_factory="my.models:mlp"))
        eng = InferenceEngine(graph, topology,
                              replica_factory=sup.replica_factory)
        ...
        eng.shutdown(); sup.close()

    Also usable as a context manager (``close`` on exit).  ``events`` is
    the audit trail: every spawn, death (with cause), respawn, degrade,
    and budget refund appends a record dict.
    """

    def __init__(self, config: SupervisorConfig):
        self._cfg = config
        self._transport = TcpTransport()    # private data-plane listener
        self._lock = threading.Lock()
        self._handles: list[WorkerHandle] = []
        self._by_token: dict[str, WorkerHandle] = {}
        self._dispatcher = None
        self._defaults: dict = {}
        self._closing = threading.Event()
        self._monitor: threading.Thread | None = None
        self._respawners: list[threading.Thread] = []
        # per-stage heal state
        self._budget: dict[int, int] = {}
        self._backoff: dict[int, float] = {}
        self._last_death: dict[int, float] = {}
        self._respawning: set[int] = set()
        self.events: list[dict] = []
        # test hook: called with the WorkerHandle right after a spawn
        # completes (used to inject faults during the spawn fence itself)
        self.on_spawned = None
        # control listener: workers dial back here with their spawn token
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            s.bind(("127.0.0.1", 0))
            s.listen(64)
        except BaseException:
            s.close()
            raise
        self._csock = s
        threading.Thread(target=self._accept_loop, daemon=True).start()

    # -- context manager -------------------------------------------------------
    def __enter__(self) -> "Supervisor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _record(self, kind: str, **fields) -> None:
        with self._lock:
            self.events.append({"kind": kind, **fields})

    # -- the replica factory (dispatcher hook) ---------------------------------
    def replica_factory(self, dispatcher, stage: int,
                        replica: int) -> WorkerHandle:
        """``Dispatcher(replica_factory=...)`` target: spawn one worker
        process for (stage, replica) and hand back its handle."""
        with self._lock:
            self._dispatcher = dispatcher
            self._defaults = dict(dispatcher._defaults)
            self._budget.setdefault(stage, self._cfg.respawn_budget)
            self._backoff.setdefault(stage, self._cfg.backoff_initial_s)
        handle = self._spawn(dispatcher, stage, replica)
        if self._monitor is None:
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True)
            self._monitor.start()
        hook = self.on_spawned
        if hook is not None:
            hook(handle)
        return handle

    def _spawn(self, dispatcher, stage: int, replica: int) -> WorkerHandle:
        cfg = self._cfg
        spec = dispatcher.topology.stages[stage]
        capacity = dispatcher._defaults["queue_depth"]
        token = os.urandom(8).hex()
        inbox, in_cid = self._transport.expect_channel(capacity, role="send")
        try:
            outbox, out_cid = self._transport.expect_channel(capacity,
                                                             role="recv")
        except BaseException:
            # the first half-channel must not stay registered forever: a
            # late dial with its cid would wire a connection onto a
            # channel no handle owns
            inbox.close()
            self._transport.unexpect_channel(in_cid)
            raise
        try:
            handle = WorkerHandle(self, stage, replica, inbox, outbox,
                                  in_cid, out_cid, capacity, token, spec,
                                  dispatcher.codecs.data)
        except BaseException:
            inbox.close()
            outbox.close()
            self._transport.unexpect_channel(in_cid)
            self._transport.unexpect_channel(out_cid)
            raise
        handle._max_batch = spec.max_batch \
            or dispatcher._defaults["max_batch"]
        handle.max_batch_cap = max(
            handle._max_batch,
            spec.max_batch_cap or dispatcher._defaults["max_batch_cap"] or 0)
        if spec.coalesce_s is not None:
            handle._coalesce_s = spec.coalesce_s
        with self._lock:
            self._by_token[token] = handle
            self._handles.append(handle)
        host, port = self._csock.getsockname()
        import repro
        # repro is a namespace package (__file__ is None): locate the
        # import root via __path__ so spawned workers can import it too
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env.update(cfg.env)
        cmd = [cfg.python or sys.executable, "-m", "repro.runtime.worker",
               "--connect", f"{host}:{port}", "--token", token]
        if cfg.allow_chaos:
            cmd.append("--chaos")
        try:
            handle.proc = subprocess.Popen(cmd, env=env)
        except BaseException:
            # exec failure (bad interpreter path, fork limits): unwind the
            # registrations exactly like a stillborn worker
            self._abort_spawn(handle)
            raise
        if not handle._hello.wait(cfg.spawn_timeout_s):
            # stillborn worker: unwind everything this spawn registered
            self._abort_spawn(handle)
            raise ChannelClosed(
                f"worker stage {stage} replica {replica} (pid "
                f"{handle.proc.pid}) never dialed back within "
                f"{cfg.spawn_timeout_s}s")
        self._record("spawn", stage=stage, replica=replica,
                     pid=handle.proc.pid)
        return handle

    def _abort_spawn(self, handle: WorkerHandle) -> None:
        """Unwind everything a failed spawn registered: pending
        half-channels, data links, the child (if any), the token slot."""
        self._transport.unexpect_channel(handle._in_cid)
        self._transport.unexpect_channel(handle._out_cid)
        handle.dead = True
        handle.retiring = True
        handle.kill_links()
        handle.reap(1.0)
        with self._lock:
            self._by_token.pop(handle.token, None)

    # -- control-plane accept ---------------------------------------------------
    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _ = self._csock.accept()
            except OSError:
                return
            try:
                # same half-open-hello guard as the data-plane listener: a
                # client that stalls mid-hello is dropped, not waited on
                conn.settimeout(self._transport.handshake_timeout_s)
                hello = recv_framed(conn)
                conn.settimeout(None)
            except (OSError, ConnectionError, WireFormatError):
                conn.close()
                continue
            token = ""
            if isinstance(hello, ControlFrame) and hello.kind == "hello":
                token = hello.payload.get("token", "")
            with self._lock:
                handle = self._by_token.get(token)
            if handle is None or handle._csock is not None:
                conn.close()
                continue
            handle._attach_control(conn)

    # -- failure detection ------------------------------------------------------
    def _monitor_loop(self) -> None:
        tick = max(0.05, self._cfg.heartbeat_s / 2)
        while not self._closing.wait(tick):
            self._sweep()

    def _sweep(self) -> None:
        cfg = self._cfg
        now = time.monotonic()
        with self._lock:
            handles = list(self._handles)
        for h in handles:
            if h.dead or h.proc is None:
                continue
            rc = h.proc.poll()
            if rc is not None:
                # exited: give the control reader a moment to deliver a
                # racing "bye" (the socket FIFO puts bye before EOF, so a
                # drained worker's bye is never misread as a crash)
                t = h._creader
                if t is not None:
                    t.join(1.0)
                if h.bye or h.retiring or self._closing.is_set():
                    h.dead = True
                    self._record("exit", stage=h.index, replica=h.replica,
                                 rc=rc)
                    continue
                self._on_death(h, f"process exited rc={rc}")
                continue
            if not h._started_flag:
                continue
            if (h.inbox.dead or h._outbox.dead) and not h.retiring:
                # data path severed while the process lives (flaky link):
                # the routers already failed over; the worker is
                # unreachable, so retire the orphan and respawn
                h.proc.kill()
                self._on_death(h, "data link severed")
                continue
            hb_at = h._hb_at
            if hb_at is not None and now - hb_at > cfg.heartbeat_timeout_s:
                h.proc.kill()
                self._on_death(h, "heartbeat timeout "
                               f"({cfg.heartbeat_timeout_s}s)")
                continue
            if cfg.stall_timeout_s is not None:
                with h._stats_lock:
                    # unconsumed channel items PLUS work trapped inside
                    # the worker's pipeline (the heartbeat's inflight
                    # gauge) — a wedged compute thread that swallowed its
                    # whole backlog shows qsize 0, credits long returned
                    backlog = h.inbox.qsize() \
                        + (h._last_snap.get("inflight_n", 0) or 0)
                    stuck_since = h._progress_at
                if backlog > 0 and now - stuck_since > cfg.stall_timeout_s:
                    h.proc.kill()
                    self._on_death(h, "stalled: heartbeats flowing but no "
                                   f"progress for {cfg.stall_timeout_s}s "
                                   f"with {backlog} queued")
                    continue
        # a quiet stage earns its crash budget back
        for stage, at in list(self._last_death.items()):
            if now - at > cfg.stable_s \
                    and self._budget.get(stage, 0) < cfg.respawn_budget:
                self._budget[stage] = cfg.respawn_budget
                self._backoff[stage] = cfg.backoff_initial_s
                self._record("budget_refund", stage=stage)
                self._last_death.pop(stage, None)

    # -- the heal path ----------------------------------------------------------
    def _on_death(self, h: WorkerHandle, why: str) -> None:
        h.dead = True
        h.retiring = True       # live_replicas() prunes once the relay exits
        h.kill_links()          # routers probe .dead and heal + fail stranded
        with self._lock:
            self._by_token.pop(h.token, None)
        h.reap(1.0)
        self._record("death", stage=h.index, replica=h.replica, why=why)
        self._last_death[h.index] = time.monotonic()
        self._nudge()
        d = self._dispatcher
        if (self._closing.is_set() or d is None or d._closed
                or not d._started):
            return
        with self._lock:
            if h.index in self._respawning:
                return          # an active respawner will see the deficit
            self._respawning.add(h.index)
        t = threading.Thread(target=self._respawn_loop, args=(h.index,),
                             daemon=True)
        with self._lock:
            self._respawners.append(t)
        t.start()

    def _nudge(self) -> None:
        """Push one zero-extent error envelope through the chain so every
        stage's router runs its dead-member probe even when the chain is
        idle (all clients blocked on stranded futures, nothing arriving
        to trigger a probe).  The envelope resolves to a no-op at the
        collector (no extents, no futures)."""
        d = self._dispatcher
        if d is None or d._closed or not d._started:
            return

        def poke() -> None:
            try:
                d._stage_inputs[0].send(BatchEnvelope(
                    [], b"", error="supervisor probe (a worker died)"))
            except (ChannelClosed, OSError):
                pass        # head link gone: the chain is already failing over

        # fire-and-forget: the head channel is bounded, and the monitor
        # must never block behind a backlogged chain
        threading.Thread(target=poke, daemon=True).start()

    def _respawn_loop(self, stage: int) -> None:
        """Re-grow ``stage`` to its topology target through the standard
        ``dispatcher.scale`` spawn path, with exponential backoff, until
        healed / budget exhausted / closing."""
        cfg = self._cfg
        try:
            while not self._closing.is_set():
                d = self._dispatcher
                if d is None or d._closed:
                    return
                target = d.topology.stages[stage].replicas
                live = len([r for r in d.stages[stage].live_replicas()
                            if not r.retiring])
                if live >= target:
                    return
                with self._lock:
                    if self._budget.get(stage, 0) <= 0:
                        degraded = True
                    else:
                        degraded = False
                        self._budget[stage] -= 1
                if degraded:
                    self._record("degraded", stage=stage, surviving=live,
                                 target=target)
                    return
                delay = self._backoff.get(stage, cfg.backoff_initial_s)
                self._backoff[stage] = min(delay * cfg.backoff_factor,
                                           cfg.backoff_max_s)
                if self._closing.wait(delay):
                    return
                try:
                    rec = d.scale(stage, target)
                    self._record("respawn", stage=stage, target=target,
                                 epoch=rec.get("epoch"))
                except Exception as e:  # deferlint: swallow(respawn retries with backoff; failure recorded in events)
                    self._record("respawn_failed", stage=stage,
                                 error=repr(e))
        finally:
            with self._lock:
                self._respawning.discard(stage)

    # -- teardown ---------------------------------------------------------------
    def close(self) -> None:
        """Stop monitoring and reap every child ever spawned.  Call after
        ``engine.shutdown()`` — a supervised run must end with zero
        orphan processes and zero lingering respawners."""
        self._closing.set()
        if self._monitor is not None:
            self._monitor.join(self._cfg.shutdown_grace_s)
        with self._lock:
            respawners = list(self._respawners)
            handles = list(self._handles)
        for t in respawners:
            t.join(self._cfg.shutdown_grace_s)
        for h in handles:
            h.kill_links()
            h.reap(self._cfg.shutdown_grace_s)
            t = h._relay_thread
            t.join(1.0)
        try:
            self._csock.close()
        except OSError:
            pass
        self._transport.close()


def supervised_engine(graph, params, topology, config: SupervisorConfig,
                      **engine_kw):
    """Build a configured :class:`~repro.runtime.engine.InferenceEngine`
    whose replicas are supervised worker processes.  Returns
    ``(engine, supervisor)``; shut down the engine first, then
    ``supervisor.close()``."""
    from repro.runtime.engine import InferenceEngine
    sup = Supervisor(config)
    try:
        eng = InferenceEngine(graph, topology,
                              replica_factory=sup.replica_factory,
                              **engine_kw)
        eng.configure(params)
    except BaseException:
        sup.close()
        raise
    return eng, sup
