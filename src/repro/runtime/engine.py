"""Top-level DEFER inference engine + measured metrics report.

``InferenceEngine`` is the public API the examples use: build from a layer
graph, run a stream of inputs through the emulated chain with *real*
compute and *real* wire codecs, and report the paper's four metrics —
throughput, per-node energy, overhead, payload — from measured timings
(compute, serialize) plus the link model for wire time/energy (the part
CORE emulates in the original).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.metrics import EDGE, HardwareProfile, compute_energy_j, network_energy_j
from repro.core.partitioner import LinkModel
from repro.runtime.dispatcher import Dispatcher, DispatcherCodecs
from repro.runtime.wire import CHUNK_BYTES


@dataclasses.dataclass
class EngineReport:
    model: str
    num_nodes: int
    codec: str
    samples: int
    wall_s: float
    throughput_cps: float              # measured inference cycles / second
    modeled_throughput_cps: float      # incl. modeled wire time (paper setting)
    per_node_energy_j: float
    overhead_s: float                  # serialize+deserialize per cycle
    payload_mb: float                  # inter-node payload per cycle
    per_node: list[dict]


class InferenceEngine:
    def __init__(self, graph: LayerGraph, num_nodes: int,
                 codecs: DispatcherCodecs | None = None,
                 strategy: str = "equal_layers",
                 hw: HardwareProfile = EDGE,
                 link: LinkModel | None = None):
        self.graph = graph
        self.hw = hw
        self.link = link or LinkModel(bandwidth_bytes_per_s=hw.link_bw,
                                      energy_per_bit_j=hw.energy_per_bit_j)
        self.dispatcher = Dispatcher(graph, num_nodes, codecs, strategy,
                                     self.link)

    def configure(self, params: dict) -> None:
        self.dispatcher.configure(params)

    def run(self, inputs: Iterable[np.ndarray]) -> tuple[list[np.ndarray], EngineReport]:
        xs = list(inputs)
        t0 = time.perf_counter()
        outs = self.dispatcher.infer_stream(xs)
        wall = time.perf_counter() - t0
        report = self._report(len(xs), wall)
        return outs, report

    def shutdown(self) -> None:
        self.dispatcher.shutdown()

    def _report(self, n: int, wall: float) -> EngineReport:
        d = self.dispatcher
        per_node = []
        bottleneck = 0.0
        total_payload = 0.0
        total_overhead = 0.0
        total_energy = 0.0
        for node in d.nodes:
            tr = node.traces[-n:]
            compute = float(np.mean([t.compute_s for t in tr]))
            ser = float(np.mean([t.serialize_s for t in tr]))
            des = float(np.mean([t.deserialize_s for t in tr]))
            payload = float(np.mean([t.payload_bytes for t in tr]))
            chunks = max(1.0, np.ceil(payload / CHUNK_BYTES))
            wire_s = self.link.latency_s * chunks \
                + payload / self.link.bandwidth_bytes_per_s
            service = compute + ser + des + wire_s
            energy = compute_energy_j(compute + ser + des, self.hw) \
                + network_energy_j(payload, self.hw)
            per_node.append({
                "node": node.index, "compute_s": compute, "serialize_s": ser,
                "deserialize_s": des, "wire_s": wire_s, "service_s": service,
                "payload_bytes": payload, "energy_j": energy,
            })
            bottleneck = max(bottleneck, service)
            total_payload += payload
            total_overhead += ser + des
            total_energy += energy
        return EngineReport(
            model=d.graph.name,
            num_nodes=len(d.nodes),
            codec=d.codecs.data.label,
            samples=n,
            wall_s=wall,
            throughput_cps=n / wall,
            modeled_throughput_cps=1.0 / bottleneck,
            per_node_energy_j=total_energy / len(d.nodes),
            overhead_s=total_overhead,
            payload_mb=total_payload / 1e6,
            per_node=per_node,
        )
