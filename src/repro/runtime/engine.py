"""Top-level DEFER inference engine + measured metrics report.

``InferenceEngine`` is the public API the examples use: build from a layer
graph, then either

* ``submit(x, client_id)`` / ``stream(xs, client_id)`` — the async serving
  path: many clients admit requests concurrently, compute nodes batch them
  continuously, results come back as futures (FIFO per client), or
* ``run(xs)`` — the original blocking single-stream call, now a shim over
  submit().

The report carries the paper's four metrics — throughput, per-node energy,
overhead, payload — from measured timings plus the link model for wire
time/energy (the part CORE emulates in the original), and the serving
ones: per-node *per-stage* utilization (decode / compute / encode busy
fractions of the measurement-window wall clock, so the staged codec/compute
overlap is visible), queue depth, batch occupancy, and p50/p99 request
latency, so the paper's ``1/max_i service_i`` law is observable under real
multi-client load.

Utilizations come in two flavors per stage: the clamped ``util_*`` (a
fraction of the window, capped at 1.0 for dashboard sanity) and the raw
``util_*_raw`` (busy / wall, uncapped).  On an oversubscribed host a busy
counter can legitimately exceed the wall clock — stage threads count
runnable-but-descheduled time — and the serving controller needs to SEE
that oversubscription honestly to avoid tuning against a saturated lie.

With ``controller=ControllerConfig(...)`` the engine runs the serving-time
feedback loop (:mod:`repro.runtime.controller`): online cost calibration
from this report's raw telemetry, periodic re-planning of the partition on
measured costs, hot repartitioning behind an epoch fence, and adaptive
``max_batch`` / ``coalesce_s`` per node.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Any, Iterable, Iterator, Sequence

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.metrics import (EDGE, HardwareProfile, LatencySummary,
                                compute_energy_j, network_energy_j)
from repro.core.partitioner import LinkModel
from repro.runtime.controller import Controller, ControllerConfig
from repro.runtime.dispatcher import Dispatcher, DispatcherCodecs
from repro.runtime.wire import CHUNK_BYTES


@dataclasses.dataclass
class EngineReport:
    model: str
    num_nodes: int
    codec: str
    samples: int
    wall_s: float
    throughput_cps: float              # measured inference cycles / second
    modeled_throughput_cps: float      # incl. modeled wire time (paper setting)
    per_node_energy_j: float
    overhead_s: float                  # serialize+deserialize per cycle
    payload_mb: float                  # inter-node payload per cycle
    p50_latency_s: float               # admission -> result, this window
    p99_latency_s: float
    per_node: list[dict]
    cuts: tuple = ()                   # live partition cut indices
    epoch: int = 0                     # committed live repartitions so far


class InferenceEngine:
    def __init__(self, graph: LayerGraph, num_nodes: int,
                 codecs: DispatcherCodecs | None = None,
                 strategy: str = "equal_layers",
                 hw: HardwareProfile = EDGE,
                 link: LinkModel | None = None,
                 max_batch: int = 8,
                 admission_depth: int = 64,
                 queue_depth: int = 8,
                 staged: bool = True,
                 cuts: Sequence[int] | None = None,
                 client_quota: int | None = None,
                 shape_buckets: str = "exact",
                 max_batch_cap: int | None = None,
                 controller: ControllerConfig | None = None):
        self.graph = graph
        self.hw = hw
        self.link = link or LinkModel(bandwidth_bytes_per_s=hw.link_bw,
                                      energy_per_bit_j=hw.energy_per_bit_j)
        self.dispatcher = Dispatcher(graph, num_nodes, codecs, strategy,
                                     self.link, max_batch=max_batch,
                                     admission_depth=admission_depth,
                                     queue_depth=queue_depth, staged=staged,
                                     cuts=cuts, client_quota=client_quota,
                                     shape_buckets=shape_buckets,
                                     max_batch_cap=max_batch_cap)
        # the serving-time feedback loop (opt-in): calibrate costs online,
        # repartition behind an epoch fence, adapt batching knobs
        self.controller = (Controller(self.dispatcher, controller)
                           if controller is not None else None)
        self._window_t0 = time.perf_counter()

    def configure(self, params: dict) -> None:
        self.dispatcher.configure(params)

    def precompile(self) -> None:
        """Compile all power-of-two batch specializations (apply + codec)
        before serving, so no jit compile lands inside a latency window."""
        self.dispatcher.precompile()

    def start(self) -> None:
        self.dispatcher.start()
        if self.controller is not None:
            self.controller.start()
        self._window_t0 = time.perf_counter()

    # -- async serving path ---------------------------------------------------
    def submit(self, x: np.ndarray, client_id: Any = 0,
               block: bool = True, timeout: float | None = None,
               priority: int = 0) -> Future:
        """Admit one request; backpressure per Dispatcher.submit().
        ``priority`` weights the admission dequeue (band weight
        ``priority + 1``) — see :meth:`Dispatcher.submit`."""
        return self.dispatcher.submit(x, client_id=client_id, block=block,
                                      timeout=timeout, priority=priority)

    def stream(self, inputs: Iterable[np.ndarray], client_id: Any = 0,
               timeout: float | None = None) -> Iterator[np.ndarray]:
        """Admit a client's stream; yield results in submission order.

        Admission of sample i+1 overlaps compute of sample i — the yield
        order (this client's FIFO) is guaranteed by awaiting futures in
        submission order, independent of cross-client batching.  With a
        ``timeout``, admission raises :class:`AdmissionFull` instead of
        blocking past it (load shedding).
        """
        pending: list[Future] = []
        for x in inputs:
            pending.append(self.submit(x, client_id=client_id,
                                       timeout=timeout))
        for fut in pending:
            yield fut.result()

    # -- blocking shim (the original API) ------------------------------------
    def run(self, inputs: Iterable[np.ndarray]) -> tuple[list[np.ndarray], EngineReport]:
        xs = list(inputs)
        self.reset_window()
        t0 = time.perf_counter()
        outs = self.dispatcher.infer_stream(xs)
        wall = time.perf_counter() - t0
        report = self.report(samples=len(xs), wall_s=wall)
        return outs, report

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        if self.controller is not None:
            self.controller.stop()       # no fence may enter a closing chain
        self.dispatcher.shutdown(drain=drain, timeout=timeout)

    # -- metrics ---------------------------------------------------------------
    def reset_window(self) -> None:
        """Start a fresh measurement window (stats are windowed, not
        lifetime, so long-running servers can report per-interval)."""
        self.dispatcher.reset_stats()
        self._window_t0 = time.perf_counter()

    def report(self, samples: int | None = None,
               wall_s: float | None = None) -> EngineReport:
        d = self.dispatcher
        wall = (wall_s if wall_s is not None
                else time.perf_counter() - self._window_t0)
        # utilization denominators use the measurement-window wall clock
        # (reset_stats -> now): with three overlapping stages per node, any
        # sum-of-busy / load-wall ratio would exceed 1.0 by construction
        util_wall = max(time.perf_counter() - self._window_t0, 1e-9)
        lat = LatencySummary.from_values(d.latencies)
        n = samples if samples is not None else lat.count
        per_node = []
        bottleneck = 0.0
        total_payload = 0.0
        total_overhead = 0.0
        total_energy = 0.0
        for node in d.nodes:
            with node._stats_lock:
                tr = list(node.traces)
                depths = list(node.queue_depths)
                busy_dec = node.busy_decode_s
                busy_cmp = node.busy_compute_s
                busy_enc = node.busy_encode_s
            n_req = sum(t.n for t in tr) or 1
            compute = sum(t.compute_s for t in tr) / n_req
            ser = sum(t.serialize_s for t in tr) / n_req
            des = sum(t.deserialize_s for t in tr) / n_req
            payload = sum(t.payload_bytes for t in tr) / n_req
            chunks = max(1.0, np.ceil(payload / CHUNK_BYTES))
            wire_s = self.link.latency_s * chunks \
                + payload / self.link.bandwidth_bytes_per_s
            # per-request service time: staged nodes overlap decode /
            # compute / encode, so the pipelined bottleneck is the max
            # stage, not the sum (paper: throughput = 1 / max_i service_i)
            if node.staged:
                service = max(compute, ser, des, wire_s)
            else:
                service = compute + ser + des + wire_s
            energy = compute_energy_j(compute + ser + des, self.hw) \
                + network_energy_j(payload, self.hw)
            per_node.append({
                "node": node.index, "compute_s": compute, "serialize_s": ser,
                "deserialize_s": des, "wire_s": wire_s, "service_s": service,
                "payload_bytes": payload, "energy_j": energy,
                # the node's saturation = its busiest stage's fraction of
                # the window (stages overlap, so summing them would let the
                # old total-busy metric exceed 1.0 and get clamped)
                "utilization": min(1.0, max(busy_dec, busy_cmp, busy_enc)
                                   / util_wall),
                "util_decode": min(1.0, busy_dec / util_wall),
                "util_compute": min(1.0, busy_cmp / util_wall),
                "util_encode": min(1.0, busy_enc / util_wall),
                # raw (unclamped) busy fractions: can exceed 1.0 on an
                # oversubscribed host (runnable-but-descheduled time books
                # as busy) — the controller and BENCH notes read these to
                # see oversubscription honestly; the clamped ones above
                # stay for dashboards
                "util_decode_raw": busy_dec / util_wall,
                "util_compute_raw": busy_cmp / util_wall,
                "util_encode_raw": busy_enc / util_wall,
                "busy_decode_s": busy_dec,
                "busy_compute_s": busy_cmp,
                "busy_encode_s": busy_enc,
                "max_batch": node.max_batch,
                "coalesce_s": node.coalesce_s,
                "layers": [n.name for n in node._nodes],
                "queue_depth_mean": (float(np.mean(depths)) if depths
                                     else 0.0),
                "queue_depth_max": max(depths) if depths else 0,
                "batch_mean": (float(np.mean([t.n for t in tr])) if tr
                               else 0.0),
                "encodes_per_batch": (float(np.mean([t.encodes for t in tr]))
                                      if tr else 0.0),
            })
            bottleneck = max(bottleneck, service)
            total_payload += payload
            total_overhead += ser + des
            total_energy += energy
        return EngineReport(
            model=d.graph.name,
            num_nodes=len(d.nodes),
            codec=d.codecs.data.label,
            samples=n,
            wall_s=wall,
            throughput_cps=n / wall if wall > 0 else 0.0,
            modeled_throughput_cps=(1.0 / bottleneck if bottleneck > 0
                                    else 0.0),
            per_node_energy_j=total_energy / len(d.nodes),
            overhead_s=total_overhead,
            payload_mb=total_payload / 1e6,
            p50_latency_s=lat.p50_s,
            p99_latency_s=lat.p99_s,
            per_node=per_node,
            cuts=tuple(d.partition.cuts),
            epoch=d.epoch,
        )
