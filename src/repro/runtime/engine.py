"""Top-level DEFER inference engine + measured metrics report.

``InferenceEngine`` is the public, topology-first API the examples use:
declare the serving shape as a :class:`~repro.runtime.topology.TopologySpec`
(stages x replicas x transports — or pass an int for the classic
one-replica chain), build the engine from a layer graph, then either

* ``submit(x, client_id)`` / ``submit_stream(xs, client_id)`` — the async
  serving path: many clients admit requests concurrently, compute replicas
  batch them continuously, results come back as futures (FIFO per client —
  the collector's sequenced merge holds replica-reordered completions),
* ``generate(prompt, max_new_tokens)`` — autoregressive decode serving:
  one session's tokens stream back as they exit the tail, with per-stage
  KV caches resident on the replicas (see :mod:`repro.runtime.session`), or
* ``run(xs)`` — the original blocking single-stream call, now a shim over
  submit().

Topology is LIVE: ``scale(stage, n)`` grows or drains a stage's replica
count behind the epoch fence with zero dropped or per-client-reordered
responses — the node-count elasticity the chain-shaped API could not
express.

The report carries the paper's four metrics — throughput, per-node energy,
overhead, payload — from measured timings plus the link model for wire
time/energy (the part CORE emulates in the original), and the serving
ones: per-replica *per-stage* utilization (decode / compute / encode busy
fractions of the measurement-window wall clock, so the staged codec/compute
overlap is visible), queue depth, batch occupancy, and p50/p99 request
latency, so the paper's ``1/max_i service_i`` law — amortized by replica
counts — is observable under real multi-client load.

Utilizations come in two flavors per stage: the clamped ``util_*`` (a
fraction of the window, capped at 1.0 for dashboard sanity) and the raw
``util_*_raw`` (busy / wall, uncapped).  On an oversubscribed host a busy
counter can legitimately exceed the wall clock — stage threads count
runnable-but-descheduled time — and the serving controller needs to SEE
that oversubscription honestly to avoid tuning against a saturated lie.

With ``controller=ControllerConfig(...)`` the engine runs the serving-time
feedback loop (:mod:`repro.runtime.controller`): online cost calibration
from this report's raw telemetry, periodic re-planning of the partition on
measured costs, hot repartitioning behind an epoch fence, adaptive
``max_batch`` / ``coalesce_s`` per stage — and, when enabled, the replica
dimension: scale recommendations (or executions) for bottleneck stages the
calibrated DP cannot fix by moving cuts.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from concurrent.futures import Future
from typing import Any, Iterable, Iterator

import numpy as np

from repro.core.graph import LayerGraph
from repro.core.metrics import (EDGE, HardwareProfile, LatencySummary,
                                compute_energy_j, idle_energy_j,
                                network_energy_j)
from repro.core.partitioner import LinkModel
from repro.runtime.controller import Controller, ControllerConfig
from repro.runtime.dispatcher import (Dispatcher, DispatcherCodecs,
                                      RetryPolicy)
from repro.runtime.session import generate_tokens
from repro.runtime.topology import TopologySpec
from repro.runtime.wire import CHUNK_BYTES


@dataclasses.dataclass
class EngineReport:
    model: str
    num_nodes: int                     # total live replicas across stages
    codec: str
    samples: int
    wall_s: float
    throughput_cps: float              # measured inference cycles / second
    modeled_throughput_cps: float      # incl. modeled wire time (paper setting)
    per_node_energy_j: float
    overhead_s: float                  # serialize+deserialize per cycle
    payload_mb: float                  # inter-node payload per cycle
    p50_latency_s: float               # admission -> result, this window
    p99_latency_s: float
    per_node: list[dict]               # one entry per replica, stage-major
    cuts: tuple = ()                   # live partition cut indices
    replicas: tuple = ()               # live per-stage replica counts
    epoch: int = 0                     # committed live fences so far


class InferenceEngine:
    def __init__(self, graph: LayerGraph,
                 topology: TopologySpec | int,
                 codecs: DispatcherCodecs | None = None,
                 hw: HardwareProfile = EDGE,
                 link: LinkModel | None = None,
                 max_batch: int = 8,
                 admission_depth: int = 64,
                 queue_depth: int = 8,
                 staged: bool = True,
                 client_quota: int | None = None,
                 shape_buckets: str = "exact",
                 max_batch_cap: int | None = None,
                 controller: ControllerConfig | None = None,
                 replica_factory=None,
                 retry_policy: RetryPolicy | None = None):
        """``topology`` is the serving shape: a
        :class:`~repro.runtime.topology.TopologySpec`, or an int ``n`` as
        shorthand for ``TopologySpec.chain(graph, n)`` (the paper's
        one-replica equal-layers chain).  Strategy, explicit cuts, and
        per-stage overrides all live on the spec, not here."""
        if isinstance(topology, int):
            topology = TopologySpec.chain(graph, topology)
        self.graph = graph
        self.hw = hw
        self.link = link or LinkModel(bandwidth_bytes_per_s=hw.link_bw,
                                      energy_per_bit_j=hw.energy_per_bit_j)
        self.dispatcher = Dispatcher(graph, topology, codecs,
                                     link=self.link, max_batch=max_batch,
                                     admission_depth=admission_depth,
                                     queue_depth=queue_depth, staged=staged,
                                     client_quota=client_quota,
                                     shape_buckets=shape_buckets,
                                     max_batch_cap=max_batch_cap,
                                     replica_factory=replica_factory,
                                     retry_policy=retry_policy)
        # the serving-time feedback loop (opt-in): calibrate costs online,
        # repartition / scale behind an epoch fence, adapt batching knobs
        self.controller = (Controller(self.dispatcher, controller)
                           if controller is not None else None)
        self._window_t0 = time.perf_counter()

    @property
    def topology(self) -> TopologySpec:
        """The LIVE topology (tracks repartitions and scale events)."""
        return self.dispatcher.topology

    def configure(self, params: dict) -> None:
        self.dispatcher.configure(params)

    def precompile(self) -> None:
        """Compile all power-of-two batch specializations (apply + codec)
        before serving, so no jit compile lands inside a latency window."""
        self.dispatcher.precompile()

    def start(self) -> None:
        self.dispatcher.start()
        if self.controller is not None:
            self.controller.start()
        self._window_t0 = time.perf_counter()

    # -- async serving path ---------------------------------------------------
    def submit(self, x: np.ndarray, client_id: Any = 0,
               block: bool = True, timeout: float | None = None,
               priority: int = 0,
               deadline_s: float | None = None) -> Future:
        """Admit one request; backpressure per Dispatcher.submit().
        ``timeout`` bounds admission-queue blocking ONLY; ``deadline_s``
        is the end-to-end result deadline (the future fails with
        :class:`~repro.runtime.dispatcher.DeadlineExceeded` when it
        expires, and late results are dropped).  ``priority`` weights the
        admission dequeue (band weight ``priority + 1``) — see
        :meth:`Dispatcher.submit`."""
        return self.dispatcher.submit(x, client_id=client_id, block=block,
                                      timeout=timeout, priority=priority,
                                      deadline_s=deadline_s)

    def submit_stream(self, inputs: Iterable[np.ndarray], client_id: Any = 0,
                      timeout: float | None = None) -> Iterator[np.ndarray]:
        """Admit a client's stream of INDEPENDENT inputs; yield one result
        per input, in submission order.  (Formerly ``stream()`` — renamed
        so the request-stream sugar cannot be confused with
        :meth:`generate`'s token stream, which yields the TOKENS of one
        autoregressive session.)

        Admission of sample i+1 overlaps compute of sample i — the yield
        order (this client's FIFO) is guaranteed twice over: futures are
        awaited in submission order AND the collector's sequenced merge
        resolves them in that order, replicated stages or not.  With a
        ``timeout``, admission raises :class:`AdmissionFull` instead of
        blocking past it (load shedding).
        """
        pending: list[Future] = []
        for x in inputs:
            pending.append(self.submit(x, client_id=client_id,
                                       timeout=timeout))
        for fut in pending:
            yield fut.result()

    def stream(self, inputs: Iterable[np.ndarray], client_id: Any = 0,
               timeout: float | None = None) -> Iterator[np.ndarray]:
        """Deprecated alias for :meth:`submit_stream` (one result per
        independent input).  For token streaming of one autoregressive
        session, use :meth:`generate`."""
        warnings.warn(
            "InferenceEngine.stream() is now submit_stream() (one result "
            "per independent input); for autoregressive token streaming "
            "use generate()", DeprecationWarning, stacklevel=2)
        return self.submit_stream(inputs, client_id=client_id,
                                  timeout=timeout)

    # -- autoregressive decode serving ----------------------------------------
    def generate(self, prompt, max_new_tokens: int, *,
                 session_id: str | None = None,
                 client_id: Any = None,
                 restart: str = "auto",
                 deadline_s: float | None = None,
                 step_timeout: float | None = 60.0) -> Iterator[int]:
        """Greedy-decode one session through the chain, yielding each token
        as it exits the tail.

        The prompt is prefilled ONCE (per-stage KV caches stay resident on
        the replicas that computed them, routed sticky); each subsequent
        step ships only the newest token per hop.  Loss of residency —
        replica death, drain at a scale fence, repartition, LRU eviction —
        is recovered by re-prefilling the retained history when ``restart``
        permits ('always', or 'auto' with a retry policy set), else the
        iterator raises :class:`~repro.runtime.session.SessionLost`
        (``retryable=False``).  Greedy decode is deterministic, so a
        recovered session's tokens are bit-identical to an undisturbed
        run.  See :func:`repro.runtime.session.generate_tokens`."""
        return generate_tokens(
            self.dispatcher, prompt, max_new_tokens,
            session_id=session_id, client_id=client_id, restart=restart,
            deadline_s=deadline_s, step_timeout=step_timeout)

    # -- elastic membership ----------------------------------------------------
    def scale(self, stage: int, replicas: int,
              timeout: float | None = 60.0,
              precompile: bool = False) -> dict:
        """Grow or drain one stage's replica count on the RUNNING engine.

        Rides the epoch fence: spawn ships the stage's weights to fresh
        replicas and fences them into the routing set; drain fences them
        out, flushes their in-flight work, and retires them.  Zero
        requests are dropped or reordered per client either way.  Returns
        the scale record (see :meth:`Dispatcher.scale`)."""
        return self.dispatcher.scale(stage, replicas, timeout=timeout,
                                     precompile=precompile)

    # -- blocking shim (the original API) ------------------------------------
    def run(self, inputs: Iterable[np.ndarray]) -> tuple[list[np.ndarray], EngineReport]:
        xs = list(inputs)
        self.reset_window()
        t0 = time.perf_counter()
        outs = self.dispatcher.infer_stream(xs)
        wall = time.perf_counter() - t0
        report = self.report(samples=len(xs), wall_s=wall)
        return outs, report

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        if self.controller is not None:
            self.controller.stop()       # no fence may enter a closing chain
        self.dispatcher.shutdown(drain=drain, timeout=timeout)

    # -- metrics ---------------------------------------------------------------
    def reset_window(self) -> None:
        """Start a fresh measurement window (stats are windowed, not
        lifetime, so long-running servers can report per-interval)."""
        self.dispatcher.reset_stats()
        self._window_t0 = time.perf_counter()

    def report(self, samples: int | None = None,
               wall_s: float | None = None) -> EngineReport:
        d = self.dispatcher
        wall = (wall_s if wall_s is not None
                else time.perf_counter() - self._window_t0)
        # utilization denominators use the measurement-window wall clock
        # (reset_stats -> now): with three overlapping stages per node, any
        # sum-of-busy / load-wall ratio would exceed 1.0 by construction
        util_wall = max(time.perf_counter() - self._window_t0, 1e-9)
        lat = LatencySummary.from_values(d.latencies)
        n = samples if samples is not None else lat.count
        per_node = []
        bottleneck = 0.0
        total_payload = 0.0
        total_overhead = 0.0
        total_energy = 0.0
        num_nodes = 0
        for group in d.stages:
            stage_service = 0.0
            live = group.live_replicas()
            for node in live:
                num_nodes += 1
                with node._stats_lock:
                    tr = list(node.traces)
                    depths = list(node.queue_depths)
                    busy_dec = node.busy_decode_s
                    busy_cmp = node.busy_compute_s
                    busy_enc = node.busy_encode_s
                n_req_raw = sum(t.n for t in tr)
                n_req = n_req_raw or 1
                compute = sum(t.compute_s for t in tr) / n_req
                ser = sum(t.serialize_s for t in tr) / n_req
                des = sum(t.deserialize_s for t in tr) / n_req
                payload = sum(t.payload_bytes for t in tr) / n_req
                chunks = max(1.0, np.ceil(payload / CHUNK_BYTES))
                wire_s = self.link.latency_s * chunks \
                    + payload / self.link.bandwidth_bytes_per_s
                # per-request service time: staged nodes overlap decode /
                # compute / encode, so the pipelined per-replica bottleneck
                # is the max stage, not the sum (paper: throughput =
                # 1 / max_i service_i)
                if node.staged:
                    service = max(compute, ser, des, wire_s)
                else:
                    service = compute + ser + des + wire_s
                energy = compute_energy_j(compute + ser + des, self.hw) \
                    + network_energy_j(payload, self.hw)
                # replica-aware idle burn: a powered-on replica draws the
                # profile's baseline for every second of the window it is
                # NOT doing work — the cost an over-provisioned stage pays
                # per node that active-energy accounting alone hides.
                # Amortized per inference cycle (the window's request
                # count) so it adds in the same per-cycle units as the
                # active energy above; busy time is capped at the window
                # (three overlapped stage threads can book more than wall
                # on an oversubscribed host).  idle_w defaults to 0, so
                # every pre-replica energy figure is unchanged.
                busy_total = busy_dec + busy_cmp + busy_enc
                idle_energy = idle_energy_j(
                    util_wall - min(busy_total, util_wall),
                    self.hw) / max(1, n)
                per_node.append({
                    "node": node.index, "stage": node.index,
                    "replica": node.replica,
                    "compute_s": compute, "serialize_s": ser,
                    "deserialize_s": des, "wire_s": wire_s,
                    "service_s": service,
                    "payload_bytes": payload, "energy_j": energy,
                    "idle_energy_j": idle_energy,
                    "requests": n_req_raw,
                    # the replica's saturation = its busiest stage's
                    # fraction of the window (stages overlap, so summing
                    # them would let the old total-busy metric exceed 1.0
                    # and get clamped)
                    "utilization": min(1.0, max(busy_dec, busy_cmp, busy_enc)
                                       / util_wall),
                    "util_decode": min(1.0, busy_dec / util_wall),
                    "util_compute": min(1.0, busy_cmp / util_wall),
                    "util_encode": min(1.0, busy_enc / util_wall),
                    # raw (unclamped) busy fractions: can exceed 1.0 on an
                    # oversubscribed host (runnable-but-descheduled time
                    # books as busy) — the controller and BENCH notes read
                    # these to see oversubscription honestly; the clamped
                    # ones above stay for dashboards
                    "util_decode_raw": busy_dec / util_wall,
                    "util_compute_raw": busy_cmp / util_wall,
                    "util_encode_raw": busy_enc / util_wall,
                    "busy_decode_s": busy_dec,
                    "busy_compute_s": busy_cmp,
                    "busy_encode_s": busy_enc,
                    "max_batch": node.max_batch,
                    "coalesce_s": node.coalesce_s,
                    "layers": [ln.name for ln in node._nodes],
                    "queue_depth_mean": (float(np.mean(depths)) if depths
                                         else 0.0),
                    "queue_depth_max": max(depths) if depths else 0,
                    "batch_mean": (float(np.mean([t.n for t in tr])) if tr
                                   else 0.0),
                    "encodes_per_batch": (float(np.mean(
                        [t.encodes for t in tr])) if tr else 0.0),
                })
                stage_service = max(stage_service, service)
                total_payload += payload
                total_overhead += ser + des
                # per-CYCLE units: a replica's energy_j is per request IT
                # processed, and a replicated stage's replicas each see
                # only a share of the window's cycles — weight by that
                # share so the chain total prices each cycle's work once
                # (a 1-replica stage sees every request: share = 1,
                # figures unchanged).  idle_energy is already per cycle.
                total_energy += energy * (n_req_raw / max(1, n)) \
                    + idle_energy
            # a replicated stage's contribution to the modeled pipeline
            # bottleneck amortizes by its replica count (rate, not latency)
            bottleneck = max(bottleneck,
                             stage_service / max(1, len(live)))
        return EngineReport(
            model=d.graph.name,
            num_nodes=num_nodes,
            codec=d.codecs.data.label,
            samples=n,
            wall_s=wall,
            throughput_cps=n / wall if wall > 0 else 0.0,
            modeled_throughput_cps=(1.0 / bottleneck if bottleneck > 0
                                    else 0.0),
            per_node_energy_j=total_energy / max(1, num_nodes),
            overhead_s=total_overhead,
            payload_mb=total_payload / 1e6,
            p50_latency_s=lat.p50_s,
            p99_latency_s=lat.p99_s,
            per_node=per_node,
            cuts=tuple(d.partition.cuts),
            replicas=d.replicas,
            epoch=d.epoch,
        )
